"""End-to-end simulator behaviour: the paper's §VI claims, directionally."""

import numpy as np
import pytest

from repro.core import SimConfig, make_workload, simulate
from repro.core import control as ctl
from repro.core.sim import SimResult

T = 1600  # 80 s at dt=50 ms — enough for several bursts


@pytest.fixture(scope="module")
def bursty_results():
    wl = make_workload("bursty", T=T, m=8, seed=1)
    out = {}
    for policy in ("round_robin", "power_of_d"):
        out[policy] = simulate(SimConfig(m=8, policy=policy), wl,
                               do_warmup=False)
    return out


def test_power_of_d_reduces_mean_queue(bursty_results):
    rr = bursty_results["round_robin"]
    pod = bursty_results["power_of_d"]
    assert pod.mean_queue() < rr.mean_queue() * 0.9  # >= 10% better


def test_power_of_d_mitigates_worst_case(bursty_results):
    """Paper: 50–80% shorter queues in worst cases."""
    rr = bursty_results["round_robin"]
    pod = bursty_results["power_of_d"]
    assert pod.worst_case_queue() < rr.worst_case_queue() * 0.5


def test_power_of_d_reduces_dispersion(bursty_results):
    rr = bursty_results["round_robin"]
    pod = bursty_results["power_of_d"]
    assert pod.dispersion() < rr.dispersion()
    assert pod.dispersion() < 0.43     # paper: MIDAS stays <= ~43%


def test_queue_timeline_shape_and_nonneg(bursty_results):
    q = bursty_results["round_robin"].queue_timeline
    assert q.shape == (T, 8)
    assert (q >= 0).all()
    assert np.isfinite(q).all()


def test_latency_quantiles_q100_stays_in_bounds():
    """Regression: fp rounding can leave cum[-1] < 1.0; q=100 must not
    index past the end (np.searchsorted returns len on such inputs)."""
    T_, m_ = 1, 10
    lat = np.arange(m_, dtype=np.float64).reshape(T_, m_)
    w = np.full((T_, m_), 0.1)          # cumsum(w)/sum(w) ends below 1.0
    r = SimResult(
        queue_timeline=np.zeros((T_, m_)), arrivals=w, lat_pred=lat,
        d_timeline=np.zeros(T_), delta_l_timeline=np.zeros(T_),
        pressure=np.zeros(T_), steered=np.zeros(T_), eligible=np.zeros(T_),
        cache_hits=np.zeros(T_), final_cache=None, config=SimConfig())
    (q100,) = r.latency_quantiles(qs=(100,))
    assert q100 == lat.max()


def test_midas_full_stability_and_bounded_steering():
    wl = make_workload("bursty", T=T, m=8, seed=2)
    cfg = SimConfig(m=8, policy="midas", cache_enabled=True,
                    cache_mode="lease")
    res = simulate(cfg, wl)
    # knobs stay in their paper bounds (f_max is adaptive within its band)
    assert res.d_timeline.min() >= 1 and res.d_timeline.max() <= 4
    assert res.delta_l_timeline.min() >= 2 and res.delta_l_timeline.max() <= 8
    assert res.f_max_timeline.min() >= ctl.F_CAP - 1e-6
    assert res.f_max_timeline.max() <= ctl.F_MAX_HIGH + 1e-6
    # leaky bucket, time-local: each tick's steering respects the cap the
    # controller had granted at routing time (f_max_timeline is recorded
    # post-update, so shift by one) against the sliding eligible window.
    # The wave window (w_ticks slots, G waves/tick) ends within the last
    # K+1 ticks, so that rolling sum upper-bounds any window's eligible.
    K = -(-res.config.w_ticks // res.config.n_groups)
    T_ = res.eligible.shape[0]
    elig_ub = np.convolve(res.eligible, np.ones(K + 1), mode="full")[:T_]
    f_prev = np.concatenate([[ctl.F_CAP], res.f_max_timeline[:-1]])
    assert (res.steered <= f_prev * elig_ub + 1.0 + 1e-6).all()
    # zero stale serves in lease mode (never serve past validity horizon)
    assert int(res.final_cache.stale_serves) == 0


def test_midas_beats_static_hash_placement():
    wl = make_workload("skewed", T=T, m=8, seed=3)
    hash_res = simulate(SimConfig(m=8, policy="hash"), wl, do_warmup=False)
    midas_res = simulate(SimConfig(m=8, policy="midas", cache_enabled=True,
                                   cache_mode="lease"), wl)
    assert midas_res.mean_queue() < hash_res.mean_queue() * 0.7


def test_cache_absorbs_hot_keys():
    wl = make_workload("skewed", T=T, m=8, seed=4)
    with_cache = simulate(SimConfig(m=8, policy="midas", cache_enabled=True,
                                    cache_mode="lease"), wl)
    assert with_cache.cache_hits.sum() > 0.2 * wl.mask.sum()


def test_workload_shapes():
    for name in ("light", "bursty", "periodic", "diurnal", "skewed",
                 "storm", "uniform_heavy"):
        wl = make_workload(name, T=100, m=8, seed=0)
        assert wl.keys.shape == wl.mask.shape == wl.is_write.shape
        assert (np.asarray(wl.keys) >= 0).all()
        assert (np.asarray(wl.keys) < wl.N).all()
        # writes only on valid slots
        assert not np.any(np.asarray(wl.is_write) & ~np.asarray(wl.mask))


def test_deterministic_given_seed():
    wl = make_workload("bursty", T=200, m=4, seed=7)
    cfg = SimConfig(m=4, policy="power_of_d")
    r1 = simulate(cfg, wl, do_warmup=False)
    r2 = simulate(cfg, wl, do_warmup=False)
    np.testing.assert_array_equal(r1.queue_timeline, r2.queue_timeline)
