"""The declarative sweep engine: SweepSpec validation, SweepResult
accessors, the simulate_sweep deprecation shim, and the sharded-vs-
single-device bit-for-bit parity contract (DESIGN.md §12).

Multi-device tests run in subprocesses with
``XLA_FLAGS=--xla_force_host_platform_device_count`` set before jax
initializes (same pattern as tests/test_distribution.py).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core import (SimConfig, SweepSpec, make_workload, run_sweep,
                        simulate_sweep)
from repro.core import sim

SRC = str(Path(__file__).resolve().parents[1] / "src")

T, M = 40, 4


def _wl(name="bursty", t=T, seed=0, **kw):
    return make_workload(name, T=t, m=M, seed=seed, **kw)


def _rows_equal(ra, rb) -> bool:
    names = (
        ra._fields if hasattr(ra, "_fields")
        else tuple(f.name for f in dataclasses.fields(ra))
    )
    for n in names:
        if n in ("config", "final_cache"):
            continue
        a, b = getattr(ra, n), getattr(rb, n)
        if a is None or b is None:
            if a is not b:
                return False
            continue
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            return False
    return True


# ---------------------------------------------------------------------------
# SweepSpec validation
# ---------------------------------------------------------------------------


def test_spec_defaults_and_coercion():
    wl = _wl()
    spec = SweepSpec(config=SimConfig(m=M), workloads=wl)
    # single workload coerced to a tuple; axes default to the config
    assert spec.workloads == (wl,)
    assert spec.policies == (spec.config.policy,)
    assert spec.controllers == (spec.config.controller,)
    assert spec.workload_names == ("bursty",)
    assert spec.n_cells == 1
    assert list(spec.coords()) == [("midas", "hysteresis", "bursty", 0)]


def test_spec_rejects_empty_and_mismatched_grids():
    with pytest.raises(ValueError, match="at least one workload"):
        SweepSpec(config=SimConfig(m=M), workloads=())
    with pytest.raises(ValueError, match="grid shape"):
        SweepSpec(config=SimConfig(m=M),
                  workloads=(_wl(), _wl(t=T + 8)))
    with pytest.raises(ValueError, match="unique"):
        SweepSpec(config=SimConfig(m=M), workloads=(_wl(), _wl(seed=1)))
    with pytest.raises(ValueError, match="at least one seed"):
        SweepSpec(config=SimConfig(m=M), workloads=_wl(), seeds=())


def test_spec_validates_axes_with_alternatives():
    with pytest.raises(ValueError, match="available.*round_robin"):
        SweepSpec(config=SimConfig(m=M), workloads=_wl(),
                  policies=("nope",))
    with pytest.raises(ValueError, match="available.*hysteresis"):
        SweepSpec(config=SimConfig(m=M), workloads=_wl(),
                  controllers=("nope",))
    with pytest.raises(ValueError, match="metrics"):
        SweepSpec(config=SimConfig(m=M), workloads=_wl(),
                  metrics="nope")
    with pytest.raises(ValueError, match="devices"):
        SweepSpec(config=SimConfig(m=M), workloads=_wl(), devices=0)


def test_spec_folds_fault_override_into_config():
    from repro.core import FaultEvent

    ev = (FaultEvent("proxy_crash", t0=10, duration=5, target=0),)
    spec = SweepSpec(config=SimConfig(m=M), workloads=_wl(), faults=ev)
    assert spec.config.faults is not None
    assert spec.config.faults[0].kind == "proxy_crash"


def test_devices_beyond_visible_raises_with_hint():
    spec = SweepSpec(config=SimConfig(m=M), workloads=_wl(),
                     devices=4096)
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        run_sweep(spec)


# ---------------------------------------------------------------------------
# run_sweep + SweepResult accessors
# ---------------------------------------------------------------------------


def test_result_accessors_and_ambiguity():
    spec = SweepSpec(
        config=SimConfig(m=M), workloads=(_wl(), _wl("light")),
        policies=("midas", "round_robin"), seeds=(0, 1),
        metrics="summary", do_warmup=False)
    res = run_sweep(spec)
    assert len(res.cells) == spec.n_cells == 8
    rows = res.rows(policy="midas", workload="bursty")
    assert len(rows) == 2  # one per seed
    r = res.row(policy="midas", workload="bursty", seed=1)
    assert _rows_equal(r, rows[1])
    # singleton controller axis may be omitted; multi-valued must be named
    with pytest.raises(ValueError, match="ambiguous policy"):
        res.rows(workload="bursty")
    with pytest.raises(ValueError, match="available"):
        res.rows(policy="nope", workload="bursty")
    assert len(dict(res.items())) == 8


def test_to_legacy_shapes_and_controller_guard():
    spec = SweepSpec(
        config=SimConfig(m=M), workloads=_wl(),
        policies=("midas",), seeds=(0,), metrics="summary",
        do_warmup=False)
    legacy = run_sweep(spec).to_legacy(single=True)
    assert set(legacy) == {"midas"}
    assert len(legacy["midas"]) == 1  # single workload: rows directly
    multi = SweepSpec(
        config=SimConfig(m=M), workloads=(_wl(), _wl("light")),
        seeds=(0,), metrics="summary", do_warmup=False)
    out = run_sweep(multi).to_legacy(single=False)
    assert set(out["midas"]) == {"bursty", "light"}
    two = SweepSpec(
        config=SimConfig(m=M), workloads=_wl(),
        controllers=("hysteresis", "static"), seeds=(0,),
        metrics="summary", do_warmup=False)
    with pytest.raises(ValueError, match="controller axis"):
        run_sweep(two).to_legacy(single=True)


def test_controller_axis_matches_single_controller_runs():
    """A 2-controller spec reproduces each single-controller sweep
    bit-for-bit (the controller axis is an outer loop, not a remix)."""
    both = run_sweep(SweepSpec(
        config=SimConfig(m=M), workloads=_wl(),
        controllers=("hysteresis", "static"), seeds=(0,),
        metrics="summary", do_warmup=False))
    for ctrl in ("hysteresis", "static"):
        solo = run_sweep(SweepSpec(
            config=SimConfig(m=M, controller=ctrl), workloads=_wl(),
            seeds=(0,), metrics="summary", do_warmup=False))
        assert _rows_equal(both.row(controller=ctrl), solo.row())


# ---------------------------------------------------------------------------
# simulate_sweep deprecation shim
# ---------------------------------------------------------------------------


def test_simulate_sweep_shim_warns_and_matches_run_sweep():
    cfg = SimConfig(m=M)
    wl = _wl()
    # the warning fires once per process: reset the guard so this test
    # observes it regardless of execution order
    sim._SWEEP_DEPRECATION_WARNED[0] = False
    with pytest.warns(DeprecationWarning, match="SweepSpec"):
        legacy = simulate_sweep(cfg, wl, seeds=(0, 1), do_warmup=False,
                                metrics="summary")
    res = run_sweep(SweepSpec(
        config=cfg, workloads=wl, seeds=(0, 1), metrics="summary",
        do_warmup=False))
    # single-workload legacy shape: {policy: rows}
    assert set(legacy) == {"midas"}
    for got, want in zip(legacy["midas"], res.rows()):
        assert _rows_equal(got, want)


def test_simulate_sweep_shim_multi_workload_full_metrics():
    cfg = SimConfig(m=M)
    wls = [_wl(), _wl("light")]
    sim._SWEEP_DEPRECATION_WARNED[0] = False
    with pytest.warns(DeprecationWarning):
        legacy = simulate_sweep(
            cfg, wls, policies=("midas", "round_robin"), seeds=(0,),
            do_warmup=False)
    assert set(legacy) == {"midas", "round_robin"}
    assert set(legacy["midas"]) == {"bursty", "light"}
    row = legacy["midas"]["bursty"][0]
    assert row.queue_timeline.shape == (T, M)


def test_simulate_sweep_deprecation_warns_exactly_once_per_process():
    """The module-level guard: repeated shim calls nag exactly once."""
    import warnings

    cfg = SimConfig(m=M)
    wl = _wl()
    sim._SWEEP_DEPRECATION_WARNED[0] = False
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        for _ in range(3):
            simulate_sweep(cfg, wl, seeds=(0,), do_warmup=False,
                           metrics="summary")
    dep = [w for w in caught
           if issubclass(w.category, DeprecationWarning)
           and "SweepSpec" in str(w.message)]
    assert len(dep) == 1
    assert sim._SWEEP_DEPRECATION_WARNED[0]


# ---------------------------------------------------------------------------
# Sharded parity (subprocess: device count locks at first jax init)
# ---------------------------------------------------------------------------


def _run(code: str, devices: int = 8, timeout: int = 480) -> str:
    env = dict(
        os.environ, PYTHONPATH=SRC,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_matches_single_device_bitwise():
    """devices=8 reproduces devices=1 bit-for-bit for both metrics
    modes, for seed counts that divide the mesh and ones that need the
    padding path — and the sharded jit compiles once per metrics mode."""
    out = _run("""
        import dataclasses
        import numpy as np
        from repro.core import SimConfig, SweepSpec, run_sweep
        from repro.core import make_workload
        from repro.core.sweep import _SHARD_TRACES

        wls = tuple(make_workload(n, T=24, m=4, seed=0)
                    for n in ("bursty", "light"))

        def rows_equal(ra, rb):
            names = (ra._fields if hasattr(ra, "_fields")
                     else tuple(f.name for f in dataclasses.fields(ra)))
            for n in names:
                if n in ("config", "final_cache"):
                    continue
                a, b = getattr(ra, n), getattr(rb, n)
                if a is None or b is None:
                    assert a is b, n
                    continue
                assert np.array_equal(np.asarray(a), np.asarray(b)), n
            return True

        for metrics in ("summary", "full"):
            for seeds in (tuple(range(8)), (0, 1, 2)):  # 3 pads to 8
                kw = dict(config=SimConfig(m=4), workloads=wls,
                          seeds=seeds, metrics=metrics, do_warmup=False)
                single = run_sweep(SweepSpec(devices=1, **kw))
                sharded = run_sweep(SweepSpec(devices=8, **kw))
                assert set(single.cells) == set(sharded.cells)
                for c in single.cells:
                    rows_equal(single.cells[c], sharded.cells[c])
                print(f"OK {metrics} seeds={len(seeds)}")
        # one (re)compile per metrics mode, not per seed count
        assert _SHARD_TRACES[0] == 2, _SHARD_TRACES
        print("TRACES_OK")
    """)
    assert out.count("OK") == 5 and "TRACES_OK" in out
