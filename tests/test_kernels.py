"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import kernel as fa_kernel
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.decode_attention import kernel as da_kernel
from repro.kernels.decode_attention import ref as da_ref
from repro.kernels.ssm_scan import kernel as ssm_kernel
from repro.kernels.ssm_scan import ops as ssm_ops
from repro.kernels.ssm_scan import ref as ssm_ref
from repro.kernels.midas_route import kernel as mr_kernel
from repro.kernels.midas_route import ref as mr_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_CASES = [
    # (B, S, H, KV, D, window, softcap, dtype)
    (1, 128, 4, 2, 64, 0, 0.0, jnp.float32),
    (2, 256, 8, 8, 64, 0, 0.0, jnp.float32),
    (1, 256, 4, 1, 128, 0, 0.0, jnp.bfloat16),
    (1, 256, 8, 2, 64, 64, 0.0, jnp.float32),     # sliding window
    (1, 128, 4, 4, 64, 0, 50.0, jnp.float32),     # softcap (gemma2)
    (1, 256, 2, 2, 256, 128, 30.0, jnp.bfloat16),  # window + softcap
]


@pytest.mark.parametrize("B,S,H,KV,D,window,softcap,dtype", FA_CASES)
def test_flash_attention_matches_ref(B, S, H, KV, D, window, softcap, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, S, H, D), dtype)
    k = jax.random.normal(k2, (B, S, KV, D), dtype)
    v = jax.random.normal(k3, (B, S, KV, D), dtype)
    want = fa_ref.mha(q, k, v, causal=True, window=window, softcap=softcap)
    got = fa_kernel.flash_attention(q, k, v, causal=True, window=window,
                                    softcap=softcap, block_q=64, block_k=64,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_block_size_invariance():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (1, 256, 4, 64))
    k = jax.random.normal(k2, (1, 256, 2, 64))
    v = jax.random.normal(k3, (1, 256, 2, 64))
    outs = [fa_kernel.flash_attention(q, k, v, block_q=bq, block_k=bk,
                                      interpret=True)
            for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DA_CASES = [
    # (B, S, H, KV, D, window, softcap, dtype)
    (2, 256, 8, 2, 64, 0, 0.0, jnp.float32),
    (1, 512, 4, 4, 64, 0, 0.0, jnp.bfloat16),
    (2, 256, 8, 8, 128, 0, 0.0, jnp.float32),
    (2, 256, 4, 2, 64, 128, 0.0, jnp.float32),
    (1, 256, 8, 4, 64, 0, 50.0, jnp.float32),
]


@pytest.mark.parametrize("B,S,H,KV,D,window,softcap,dtype", DA_CASES)
def test_decode_attention_matches_ref(B, S, H, KV, D, window, softcap,
                                      dtype):
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(keys[0], (B, H, D), dtype)
    kc = jax.random.normal(keys[1], (B, S, KV, D), dtype)
    vc = jax.random.normal(keys[2], (B, S, KV, D), dtype)
    pos = jax.random.randint(keys[3], (B,), 1, S - 1)
    want = da_ref.decode_attention(q, kc, vc, pos, window=window,
                                   softcap=softcap)
    got = da_kernel.decode_attention(q, kc, vc, pos, window=window,
                                     softcap=softcap, block_k=64,
                                     interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

SSM_CASES = [
    # (Bt, S, DI, ST, chunk, dtype)
    (2, 64, 32, 8, 16, jnp.float32),
    (1, 128, 64, 16, 32, jnp.float32),
    (2, 96, 32, 8, 32, jnp.bfloat16),     # S not a chunk multiple
]


@pytest.mark.parametrize("Bt,S,DI,ST,chunk,dtype", SSM_CASES)
def test_chunked_scan_matches_sequential_ref(Bt, S, DI, ST, chunk, dtype):
    keys = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(keys[0], (Bt, S, DI), dtype)
    dt = jax.nn.softplus(jax.random.normal(keys[1], (Bt, S, DI), dtype))
    A = -jnp.exp(jax.random.normal(keys[2], (DI, ST)) * 0.5)
    B = jax.random.normal(keys[3], (Bt, S, ST), dtype)
    C = jax.random.normal(keys[4], (Bt, S, ST), dtype)
    D = jnp.ones((DI,))
    y_ref, h_ref = ssm_ref.selective_scan(x, dt, A, B, C, D)
    y_fast, h_fast = ssm_ops.selective_scan(x, dt, A, B, C, D, chunk=chunk,
                                            impl="jnp_chunked")
    np.testing.assert_allclose(np.asarray(y_fast, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)
    np.testing.assert_allclose(np.asarray(h_fast), np.asarray(h_ref),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("Bt,S,DI,ST,chunk", [(2, 64, 32, 8, 16),
                                              (1, 96, 16, 4, 32)])
def test_parallel_scan_matches_sequential_ref(Bt, S, DI, ST, chunk):
    keys = jax.random.split(jax.random.PRNGKey(9), 5)
    x = jax.random.normal(keys[0], (Bt, S, DI))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (Bt, S, DI)))
    A = -jnp.exp(jax.random.normal(keys[2], (DI, ST)) * 0.5)
    B = jax.random.normal(keys[3], (Bt, S, ST))
    C = jax.random.normal(keys[4], (Bt, S, ST))
    D = jnp.ones((DI,))
    y_ref, h_ref = ssm_ref.selective_scan(x, dt, A, B, C, D)
    y_p, h_p = ssm_ops.selective_scan(x, dt, A, B, C, D, chunk=chunk,
                                      impl="parallel")
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("Bt,Q,DI,ST,tile", [
    (2, 16, 32, 8, 16),
    (1, 32, 64, 16, 32),
    (2, 16, 32, 8, 32),
])
def test_pallas_chunk_scan_matches_ref(Bt, Q, DI, ST, tile):
    keys = jax.random.split(jax.random.PRNGKey(4), 6)
    x = jax.random.normal(keys[0], (Bt, Q, DI))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (Bt, Q, DI)))
    A = -jnp.exp(jax.random.normal(keys[2], (DI, ST)) * 0.5)
    B = jax.random.normal(keys[3], (Bt, Q, ST))
    C = jax.random.normal(keys[4], (Bt, Q, ST))
    h0 = jax.random.normal(keys[5], (Bt, DI, ST))
    # oracle: sequential scan from h0, minus the D*x skip (kernel contract)
    y_ref, h_ref = ssm_ref.selective_scan(x, dt, A, B, C,
                                          jnp.zeros((DI,)), h0=h0)
    y_k, h_k = ssm_kernel.chunk_scan(h0, x, dt, A, B, C, tile=tile,
                                     interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssm_decode_step_matches_scan():
    keys = jax.random.split(jax.random.PRNGKey(5), 5)
    Bt, S, DI, ST = 2, 8, 16, 4
    x = jax.random.normal(keys[0], (Bt, S, DI))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (Bt, S, DI)))
    A = -jnp.exp(jax.random.normal(keys[2], (DI, ST)) * 0.5)
    B = jax.random.normal(keys[3], (Bt, S, ST))
    C = jax.random.normal(keys[4], (Bt, S, ST))
    D = jnp.ones((DI,))
    y_ref, h_ref = ssm_ref.selective_scan(x, dt, A, B, C, D)
    h = jnp.zeros((Bt, DI, ST))
    ys = []
    for t in range(S):
        y, h = ssm_ref.selective_step(x[:, t], dt[:, t], A, B[:, t],
                                      C[:, t], D, h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# midas route
# ---------------------------------------------------------------------------

MR_CASES = [
    # (T, E, k, d)
    (256, 8, 2, 2),
    (256, 16, 4, 2),
    (512, 128, 8, 4),
    (256, 4, 2, 2),
]


@pytest.mark.parametrize("T,E,k,d", MR_CASES)
def test_midas_route_kernel_matches_ref(T, E, k, d):
    keys = jax.random.split(jax.random.PRNGKey(6), 2)
    logits = jax.random.normal(keys[0], (T, E)) * 2.0
    load = jnp.abs(jax.random.normal(keys[1], (E,))) * 3.0
    # f_max=1.0: margin-governed variant on both paths
    e_ref, w_ref, s_ref = mr_ref.midas_dispatch(
        logits, load, k, d, delta_l=2.0, gate_slack=1.0, f_max=1.0)
    e_k, w_k, s_k = mr_kernel.midas_dispatch(
        logits, load, k, d, delta_l=2.0, gate_slack=1.0, tile=128,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(e_k), np.asarray(e_ref))
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_ref))


def test_midas_route_reduces_load_dispersion():
    """Steering must push the realized expert load toward balance when
    telemetry is imbalanced — the paper's claim at the MoE layer."""
    keys = jax.random.split(jax.random.PRNGKey(7), 2)
    T, E, k = 4096, 16, 4
    logits = jax.random.normal(keys[0], (T, E)) * 2.0
    # pretend experts 0..3 are hot
    load = jnp.asarray([5.0] * 4 + [0.5] * 12)
    e_van, _ = mr_ref.topk_dispatch(logits, k)
    e_mid, _, steered = mr_ref.midas_dispatch(logits, load, k, d=4,
                                              delta_l=2.0, f_max=1.0)
    def hot_share(e):
        return float((np.asarray(e) < 4).mean())
    assert steered.sum() > 0
    assert hot_share(e_mid) < hot_share(e_van)


def test_midas_route_respects_fmax_zero():
    keys = jax.random.split(jax.random.PRNGKey(8), 2)
    logits = jax.random.normal(keys[0], (256, 8))
    load = jnp.abs(jax.random.normal(keys[1], (8,))) * 5.0
    e0, _, s0 = mr_ref.midas_dispatch(logits, load, 2, 2, f_max=0.0)
    e_van, _ = mr_ref.topk_dispatch(logits, 2)
    assert not bool(s0.any())
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e_van))


def _mr_inputs(T, E, seed=6):
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    logits = jax.random.normal(keys[0], (T, E)) * 2.0
    load = jnp.abs(jax.random.normal(keys[1], (E,))) * 3.0
    return logits, load


MR_FMAX_CASES = [
    # (T, E, k, d, f_max, tile) — capped variant + edge tiles/padding
    (256, 16, 4, 2, 0.5, 8),        # tiny tile
    (256, 16, 4, 2, 0.5, 256),      # one-tile grid
    (250, 16, 4, 2, 0.25, 128),     # T % tile != 0 (padding path)
    (37, 8, 2, 2, 0.5, 8),          # padding + tiny tile
    (512, 128, 8, 4, 0.25, 256),
    (250, 16, 4, 2, 1.0, 128),      # padding on the margin-only kernel
]


@pytest.mark.parametrize("T,E,k,d,f_max,tile", MR_FMAX_CASES)
def test_midas_route_fmax_capped_matches_ref(T, E, k, d, f_max, tile):
    """The f_max-capped two-pass kernel (and the ragged-T padding) must
    be bit-for-bit against the pure-jnp reference."""
    logits, load = _mr_inputs(T, E)
    e_ref, w_ref, s_ref = mr_ref.midas_dispatch(
        logits, load, k, d, delta_l=2.0, gate_slack=1.0, f_max=f_max)
    e_k, w_k, s_k = mr_kernel.midas_dispatch(
        logits, load, k, d, delta_l=2.0, gate_slack=1.0, f_max=f_max,
        tile=tile, interpret=True)
    np.testing.assert_array_equal(np.asarray(e_k), np.asarray(e_ref))
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_ref))


def test_midas_route_kernel_deff_zero_falls_back():
    """d_eff <= 0 (k + d spans all experts) collapses to plain top-k on
    every path — kernel, ref, and the ops wrapper agree."""
    logits, load = _mr_inputs(128, 4)
    e_van, w_van = mr_ref.topk_dispatch(logits, 4)
    for fn in (mr_kernel.midas_dispatch, mr_ref.midas_dispatch):
        e, w, s = fn(logits, load, 4, 2)
        np.testing.assert_array_equal(np.asarray(e), np.asarray(e_van))
        np.testing.assert_allclose(np.asarray(w), np.asarray(w_van),
                                   rtol=1e-6, atol=1e-6)
        assert not bool(np.asarray(s).any())


def test_midas_route_ops_env_forces_both_directions(monkeypatch):
    """REPRO_KERNEL_IMPL must force the ops wrapper onto either path —
    including pallas with f_max < 1, which used to silently decline."""
    from repro.kernels.midas_route import kernel as kernel_mod
    from repro.kernels.midas_route import ops as mr_ops

    logits, load = _mr_inputs(64, 8)
    calls = []
    real = kernel_mod.midas_dispatch

    def spy(*a, **kw):
        calls.append(kw)
        return real(*a, **kw)

    monkeypatch.setattr(kernel_mod, "midas_dispatch", spy)
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
    e_p, w_p, s_p = mr_ops.midas_dispatch(logits, load, 2, 2, f_max=0.5)
    assert len(calls) == 1  # pallas path taken despite f_max < 1

    monkeypatch.setenv("REPRO_KERNEL_IMPL", "ref")
    e_r, w_r, s_r = mr_ops.midas_dispatch(logits, load, 2, 2, f_max=0.5)
    assert len(calls) == 1  # ref forced: kernel not touched again
    np.testing.assert_array_equal(np.asarray(e_p), np.asarray(e_r))
    np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_r))
    np.testing.assert_allclose(np.asarray(w_p), np.asarray(w_r),
                               rtol=1e-6, atol=1e-6)


def test_midas_route_ops_warns_once_when_pallas_declined(monkeypatch):
    """impl='pallas' with no kernel work (d_eff <= 0) is surfaced by a
    one-time RuntimeWarning, not silently rerouted."""
    import warnings as warnings_mod

    from repro.kernels.midas_route import ops as mr_ops

    logits, load = _mr_inputs(64, 4)
    monkeypatch.setattr(mr_ops, "_DECLINED_WARNED", False)
    with warnings_mod.catch_warnings(record=True) as w:
        warnings_mod.simplefilter("always")
        mr_ops.midas_dispatch(logits, load, 4, 2, impl="pallas")
        mr_ops.midas_dispatch(logits, load, 4, 2, impl="pallas")
    declined = [x for x in w if "declined" in str(x.message)]
    assert len(declined) == 1


# ---------------------------------------------------------------------------
# route_select: the engine's wave-routing kernel vs the jnp policies
# ---------------------------------------------------------------------------

RS_CASES = [
    # (R, m, d_max, tile) — includes R % tile != 0 (padding)
    (256, 8, 4, 128),
    (100, 8, 4, 128),
    (64, 32, 8, 8),
    (7, 4, 2, 256),
]


def _rs_inputs(R, m, d_max, seed=11):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    feas = jax.random.randint(keys[0], (R, d_max), 0, m, jnp.int32)
    load = jnp.abs(jax.random.normal(keys[1], (m,))) * 3.0
    p50 = jnp.abs(jax.random.normal(keys[2], (m,))) * 50.0
    rng = keys[3]
    return feas, load, p50, rng


@pytest.mark.parametrize("R,m,d_max,tile", RS_CASES)
def test_route_select_power_of_d_matches_jnp(R, m, d_max, tile):
    from repro.core.policies.base import sample_candidates

    feas, load, _, rng = _rs_inputs(R, m, d_max)
    sampled = sample_candidates(rng, feas, 2)
    tie = jax.random.uniform(jax.random.fold_in(rng, 1), feas.shape) * 1e-3
    loadv = jnp.where(sampled, load[feas], jnp.inf)
    best = jnp.argmin(loadv + tie, axis=1)
    want = jnp.take_along_axis(feas, best[:, None], axis=1)[:, 0]
    got, _ = mr_kernel.route_select(
        feas, load, load, sampled.astype(jnp.int32), tie,
        jnp.zeros((1, 4), jnp.float32), mode="power_of_d", tile=tile,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("R,m,d_max,tile", RS_CASES)
def test_route_select_midas_matches_jnp(R, m, d_max, tile):
    from repro.core.policies.base import sample_candidates

    feas, load, p50, rng = _rs_inputs(R, m, d_max)
    delta_l, delta_t = 0.5, 10.0
    sampled = sample_candidates(rng, feas, 3).at[:, 0].set(False)
    tie = jax.random.uniform(jax.random.fold_in(rng, 2), feas.shape) * 1e-3
    Lp = load[feas[:, 0]][:, None]
    p50p = p50[feas[:, 0]][:, None]
    ok = (sampled & (load[feas] <= Lp - delta_l)
          & (p50[feas] <= p50p - delta_t))
    loadv = jnp.where(ok, load[feas], jnp.inf)
    slot = jnp.argmin(loadv + tie, axis=1)
    want = jnp.take_along_axis(feas, slot[:, None], axis=1)[:, 0]
    want_any = jnp.any(ok, axis=1)
    scal = jnp.asarray([[delta_l, delta_t, 0.0, 0.0]], jnp.float32)
    got, got_any = mr_kernel.route_select(
        feas, load, p50, sampled.astype(jnp.int32), tie, scal,
        mode="midas", tile=tile, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_any), np.asarray(want_any))


@pytest.mark.parametrize("R,m,d_max,tile", RS_CASES)
def test_route_select_chbl_matches_jnp(R, m, d_max, tile):
    feas, load, _, _ = _rs_inputs(R, m, d_max)
    cap = 1.25 * (jnp.mean(load) + 1.0)
    Lf = load[feas]
    under = Lf <= cap
    slot = jnp.where(jnp.any(under, axis=1), jnp.argmax(under, axis=1),
                     jnp.argmin(Lf, axis=1))
    want = jnp.take_along_axis(feas, slot[:, None], axis=1)[:, 0]
    scal = jnp.stack([jnp.zeros(()), jnp.zeros(()), cap,
                      jnp.zeros(())]).reshape(1, 4)
    got, _ = mr_kernel.route_select(
        feas, load, load, jnp.zeros(feas.shape, jnp.int32),
        jnp.zeros(feas.shape, jnp.float32), scal, mode="chbl", tile=tile,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_route_select_rejects_unknown_mode():
    feas, load, _, _ = _rs_inputs(8, 4, 2)
    with pytest.raises(ValueError, match="unknown route mode"):
        mr_kernel.route_select(
            feas, load, load, jnp.zeros(feas.shape, jnp.int32),
            jnp.zeros(feas.shape, jnp.float32),
            jnp.zeros((1, 4), jnp.float32), mode="nope", interpret=True)
