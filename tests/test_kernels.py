"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import kernel as fa_kernel
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.decode_attention import kernel as da_kernel
from repro.kernels.decode_attention import ref as da_ref
from repro.kernels.ssm_scan import kernel as ssm_kernel
from repro.kernels.ssm_scan import ops as ssm_ops
from repro.kernels.ssm_scan import ref as ssm_ref
from repro.kernels.midas_route import kernel as mr_kernel
from repro.kernels.midas_route import ref as mr_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_CASES = [
    # (B, S, H, KV, D, window, softcap, dtype)
    (1, 128, 4, 2, 64, 0, 0.0, jnp.float32),
    (2, 256, 8, 8, 64, 0, 0.0, jnp.float32),
    (1, 256, 4, 1, 128, 0, 0.0, jnp.bfloat16),
    (1, 256, 8, 2, 64, 64, 0.0, jnp.float32),     # sliding window
    (1, 128, 4, 4, 64, 0, 50.0, jnp.float32),     # softcap (gemma2)
    (1, 256, 2, 2, 256, 128, 30.0, jnp.bfloat16),  # window + softcap
]


@pytest.mark.parametrize("B,S,H,KV,D,window,softcap,dtype", FA_CASES)
def test_flash_attention_matches_ref(B, S, H, KV, D, window, softcap, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, S, H, D), dtype)
    k = jax.random.normal(k2, (B, S, KV, D), dtype)
    v = jax.random.normal(k3, (B, S, KV, D), dtype)
    want = fa_ref.mha(q, k, v, causal=True, window=window, softcap=softcap)
    got = fa_kernel.flash_attention(q, k, v, causal=True, window=window,
                                    softcap=softcap, block_q=64, block_k=64,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_block_size_invariance():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (1, 256, 4, 64))
    k = jax.random.normal(k2, (1, 256, 2, 64))
    v = jax.random.normal(k3, (1, 256, 2, 64))
    outs = [fa_kernel.flash_attention(q, k, v, block_q=bq, block_k=bk,
                                      interpret=True)
            for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DA_CASES = [
    # (B, S, H, KV, D, window, softcap, dtype)
    (2, 256, 8, 2, 64, 0, 0.0, jnp.float32),
    (1, 512, 4, 4, 64, 0, 0.0, jnp.bfloat16),
    (2, 256, 8, 8, 128, 0, 0.0, jnp.float32),
    (2, 256, 4, 2, 64, 128, 0.0, jnp.float32),
    (1, 256, 8, 4, 64, 0, 50.0, jnp.float32),
]


@pytest.mark.parametrize("B,S,H,KV,D,window,softcap,dtype", DA_CASES)
def test_decode_attention_matches_ref(B, S, H, KV, D, window, softcap,
                                      dtype):
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(keys[0], (B, H, D), dtype)
    kc = jax.random.normal(keys[1], (B, S, KV, D), dtype)
    vc = jax.random.normal(keys[2], (B, S, KV, D), dtype)
    pos = jax.random.randint(keys[3], (B,), 1, S - 1)
    want = da_ref.decode_attention(q, kc, vc, pos, window=window,
                                   softcap=softcap)
    got = da_kernel.decode_attention(q, kc, vc, pos, window=window,
                                     softcap=softcap, block_k=64,
                                     interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

SSM_CASES = [
    # (Bt, S, DI, ST, chunk, dtype)
    (2, 64, 32, 8, 16, jnp.float32),
    (1, 128, 64, 16, 32, jnp.float32),
    (2, 96, 32, 8, 32, jnp.bfloat16),     # S not a chunk multiple
]


@pytest.mark.parametrize("Bt,S,DI,ST,chunk,dtype", SSM_CASES)
def test_chunked_scan_matches_sequential_ref(Bt, S, DI, ST, chunk, dtype):
    keys = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(keys[0], (Bt, S, DI), dtype)
    dt = jax.nn.softplus(jax.random.normal(keys[1], (Bt, S, DI), dtype))
    A = -jnp.exp(jax.random.normal(keys[2], (DI, ST)) * 0.5)
    B = jax.random.normal(keys[3], (Bt, S, ST), dtype)
    C = jax.random.normal(keys[4], (Bt, S, ST), dtype)
    D = jnp.ones((DI,))
    y_ref, h_ref = ssm_ref.selective_scan(x, dt, A, B, C, D)
    y_fast, h_fast = ssm_ops.selective_scan(x, dt, A, B, C, D, chunk=chunk,
                                            impl="jnp_chunked")
    np.testing.assert_allclose(np.asarray(y_fast, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)
    np.testing.assert_allclose(np.asarray(h_fast), np.asarray(h_ref),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("Bt,S,DI,ST,chunk", [(2, 64, 32, 8, 16),
                                              (1, 96, 16, 4, 32)])
def test_parallel_scan_matches_sequential_ref(Bt, S, DI, ST, chunk):
    keys = jax.random.split(jax.random.PRNGKey(9), 5)
    x = jax.random.normal(keys[0], (Bt, S, DI))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (Bt, S, DI)))
    A = -jnp.exp(jax.random.normal(keys[2], (DI, ST)) * 0.5)
    B = jax.random.normal(keys[3], (Bt, S, ST))
    C = jax.random.normal(keys[4], (Bt, S, ST))
    D = jnp.ones((DI,))
    y_ref, h_ref = ssm_ref.selective_scan(x, dt, A, B, C, D)
    y_p, h_p = ssm_ops.selective_scan(x, dt, A, B, C, D, chunk=chunk,
                                      impl="parallel")
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("Bt,Q,DI,ST,tile", [
    (2, 16, 32, 8, 16),
    (1, 32, 64, 16, 32),
    (2, 16, 32, 8, 32),
])
def test_pallas_chunk_scan_matches_ref(Bt, Q, DI, ST, tile):
    keys = jax.random.split(jax.random.PRNGKey(4), 6)
    x = jax.random.normal(keys[0], (Bt, Q, DI))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (Bt, Q, DI)))
    A = -jnp.exp(jax.random.normal(keys[2], (DI, ST)) * 0.5)
    B = jax.random.normal(keys[3], (Bt, Q, ST))
    C = jax.random.normal(keys[4], (Bt, Q, ST))
    h0 = jax.random.normal(keys[5], (Bt, DI, ST))
    # oracle: sequential scan from h0, minus the D*x skip (kernel contract)
    y_ref, h_ref = ssm_ref.selective_scan(x, dt, A, B, C,
                                          jnp.zeros((DI,)), h0=h0)
    y_k, h_k = ssm_kernel.chunk_scan(h0, x, dt, A, B, C, tile=tile,
                                     interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssm_decode_step_matches_scan():
    keys = jax.random.split(jax.random.PRNGKey(5), 5)
    Bt, S, DI, ST = 2, 8, 16, 4
    x = jax.random.normal(keys[0], (Bt, S, DI))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (Bt, S, DI)))
    A = -jnp.exp(jax.random.normal(keys[2], (DI, ST)) * 0.5)
    B = jax.random.normal(keys[3], (Bt, S, ST))
    C = jax.random.normal(keys[4], (Bt, S, ST))
    D = jnp.ones((DI,))
    y_ref, h_ref = ssm_ref.selective_scan(x, dt, A, B, C, D)
    h = jnp.zeros((Bt, DI, ST))
    ys = []
    for t in range(S):
        y, h = ssm_ref.selective_step(x[:, t], dt[:, t], A, B[:, t],
                                      C[:, t], D, h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# midas route
# ---------------------------------------------------------------------------

MR_CASES = [
    # (T, E, k, d)
    (256, 8, 2, 2),
    (256, 16, 4, 2),
    (512, 128, 8, 4),
    (256, 4, 2, 2),
]


@pytest.mark.parametrize("T,E,k,d", MR_CASES)
def test_midas_route_kernel_matches_ref(T, E, k, d):
    keys = jax.random.split(jax.random.PRNGKey(6), 2)
    logits = jax.random.normal(keys[0], (T, E)) * 2.0
    load = jnp.abs(jax.random.normal(keys[1], (E,))) * 3.0
    # f_max=1.0: margin-governed variant on both paths
    e_ref, w_ref, s_ref = mr_ref.midas_dispatch(
        logits, load, k, d, delta_l=2.0, gate_slack=1.0, f_max=1.0)
    e_k, w_k, s_k = mr_kernel.midas_dispatch(
        logits, load, k, d, delta_l=2.0, gate_slack=1.0, tile=128,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(e_k), np.asarray(e_ref))
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_ref))


def test_midas_route_reduces_load_dispersion():
    """Steering must push the realized expert load toward balance when
    telemetry is imbalanced — the paper's claim at the MoE layer."""
    keys = jax.random.split(jax.random.PRNGKey(7), 2)
    T, E, k = 4096, 16, 4
    logits = jax.random.normal(keys[0], (T, E)) * 2.0
    # pretend experts 0..3 are hot
    load = jnp.asarray([5.0] * 4 + [0.5] * 12)
    e_van, _ = mr_ref.topk_dispatch(logits, k)
    e_mid, _, steered = mr_ref.midas_dispatch(logits, load, k, d=4,
                                              delta_l=2.0, f_max=1.0)
    def hot_share(e):
        return float((np.asarray(e) < 4).mean())
    assert steered.sum() > 0
    assert hot_share(e_mid) < hot_share(e_van)


def test_midas_route_respects_fmax_zero():
    keys = jax.random.split(jax.random.PRNGKey(8), 2)
    logits = jax.random.normal(keys[0], (256, 8))
    load = jnp.abs(jax.random.normal(keys[1], (8,))) * 5.0
    e0, _, s0 = mr_ref.midas_dispatch(logits, load, 2, 2, f_max=0.0)
    e_van, _ = mr_ref.topk_dispatch(logits, 2)
    assert not bool(s0.any())
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e_van))
