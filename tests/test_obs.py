"""The flight-recorder observability plane (DESIGN.md §13).

Four contracts:

* **Recorder** — spans land as Chrome-``trace_event`` JSONL (write-
  through sink, torn-final-line tolerance), the Chrome export opens as
  ``{"traceEvents": [...]}``, and a disabled recorder records nothing.
* **Windowing** — ``0 <= begin <= end <= T`` for arbitrary series
  (hypothesis property when available), constant-load traces open at
  the warmup bound, pure-transient traces censor instead of crashing,
  and censored windows fall back to whole-run statistics.
* **Parity** — engine results are bit-for-bit identical with the
  recorder enabled or disabled (the spans are host-side only), and the
  summary-mode ``q_mean_timeline`` equals the full-metrics
  ``queue_timeline.mean(axis=1)`` bitwise.
* **repro-report** — round-trips artifacts + traces, and ``--check``
  flags malformed traces and invariant-violating window blocks.
"""
import json

import numpy as np
import pytest

from repro.core import SimConfig, SweepSpec, make_workload, run_sweep
from repro.obs import trace as trace_lib
from repro.obs import windows
from repro.obs.report import check_paths, main as report_main

T, M = 40, 4


def _wl(name="bursty"):
    return make_workload(name, T=T, m=M, seed=0, N=256)


# ---------------------------------------------------------------------------
# Recorder / trace schema
# ---------------------------------------------------------------------------


def test_span_records_chrome_complete_event(tmp_path):
    rec = trace_lib.Recorder(enabled=True)
    rec.configure(path=tmp_path / "t.trace.jsonl", fresh=True)
    with rec.span("phase/x", cat="execute", policy="midas") as sp:
        sp["compiled"] = True
    events = trace_lib.read_trace(tmp_path / "t.trace.jsonl")
    assert trace_lib.validate_events(events) == []
    span = events[-1]
    assert span["ph"] == "X" and span["cat"] == "execute"
    assert span["args"] == {"policy": "midas", "compiled": True}
    assert span["dur"] >= 0
    # the meta event carries the wall-clock epoch for joining artifacts
    meta = [e for e in events if e["ph"] == "M"]
    assert meta and "epoch_unix" in meta[0]["args"]


def test_span_records_exception_and_reraises():
    rec = trace_lib.Recorder(enabled=True)
    with pytest.raises(RuntimeError):
        with rec.span("boom"):
            raise RuntimeError("x")
    assert rec.events[-1]["args"]["error"] == "RuntimeError"


def test_disabled_recorder_records_nothing(tmp_path):
    rec = trace_lib.Recorder(enabled=False)
    rec.configure(path=tmp_path / "off.trace.jsonl", fresh=True)
    with rec.span("phase/x"):
        pass
    rec.instant("mark")
    assert rec.events == []
    assert (tmp_path / "off.trace.jsonl").read_text() == ""


def test_write_chrome_is_loadable_trace_doc(tmp_path):
    rec = trace_lib.Recorder(enabled=True)
    rec.configure(fresh=True)
    with rec.span("a"):
        pass
    rec.instant("b")
    out = rec.write_chrome(tmp_path / "t.trace.json")
    doc = json.loads(out.read_text())
    assert {e["name"] for e in doc["traceEvents"]} >= {"a", "b"}


def test_read_trace_tolerates_torn_final_line_only(tmp_path):
    good = json.dumps({"name": "a", "cat": "c", "ph": "i", "ts": 1.0,
                       "pid": 1, "tid": 1})
    p = tmp_path / "torn.trace.jsonl"
    p.write_text(good + "\n" + good[: len(good) // 2])
    assert len(trace_lib.read_trace(p)) == 1  # torn tail dropped
    p2 = tmp_path / "bad.trace.jsonl"
    p2.write_text(good[: len(good) // 2] + "\n" + good + "\n")
    with pytest.raises(ValueError, match="malformed JSONL"):
        trace_lib.read_trace(p2)


def test_validate_events_flags_schema_problems():
    probs = trace_lib.validate_events(
        [{"name": "a"}, {"name": "b", "cat": "c", "ph": "Z", "ts": 0.0,
          "pid": 1, "tid": 1}])
    assert len(probs) == 2


# ---------------------------------------------------------------------------
# Windowing contract
# ---------------------------------------------------------------------------


def test_constant_series_opens_at_warmup_bound():
    w = windows.detect(np.full(200, 3.5))
    assert w.method == "ewma_plateau"
    assert w.begin <= windows.HOLD  # no transient -> no warmup cut
    assert w.end == w.T == 200


def test_pure_transient_shorter_than_warmup_is_censored():
    w = windows.detect(np.arange(2 * windows.HOLD - 1, dtype=float))
    assert w.censored and w.begin == w.end == w.T
    # censored windows still serialize and fall back to raw stats
    stats = windows.windowed_stats(np.arange(5.0), w)
    assert stats["stable"] == stats["raw"] and stats["shift"] == 0.0


def test_nonfinite_series_is_censored_not_crashed():
    w = windows.detect([1.0, np.nan] + [1.0] * 40)
    assert w.censored


def test_ramp_then_plateau_cuts_the_ramp():
    rng = np.random.RandomState(0)
    t = np.arange(400, dtype=np.float64)
    x = np.minimum(t / 100.0, 1.0) * 10.0 + rng.randn(400) * 0.05
    w = windows.detect(x)
    assert w.method == "ewma_plateau"
    assert 90 <= w.begin <= 160  # the ramp ends at t=100
    raw_vs_stable = windows.windowed_stats(x, w)
    assert raw_vs_stable["stable"] > raw_vs_stable["raw"]


def test_window_invariant_enforced_at_construction():
    with pytest.raises(ValueError, match="invariant"):
        windows.Window(begin=5, end=3, T=10, method="ewma_plateau")
    with pytest.raises(ValueError, match="invariant"):
        windows.Window(begin=0, end=11, T=10, method="ewma_plateau")


def test_cell_block_shape_and_shift_field():
    spec = SweepSpec(config=SimConfig(m=M, N=256), workloads=_wl(),
                     seeds=(0, 1), metrics="summary", do_warmup=False)
    rows = run_sweep(spec).rows()
    block = windows.cell_block(rows, dt_ms=50.0)
    assert set(block) == {"window", "stable", "window_shift"}
    win = block["window"]
    assert 0 <= win["begin"] <= win["end"] <= win["T"] == T
    assert win["end_ms"] == win["end"] * 50.0
    assert isinstance(block["window_shift"]["mean_queue"], float)


# ---------------------------------------------------------------------------
# Engine parity: obs on/off, and q_mean across metrics modes
# ---------------------------------------------------------------------------


def _sweep_rows(metrics):
    spec = SweepSpec(config=SimConfig(m=M, N=256), workloads=_wl(),
                     seeds=(0,), metrics=metrics, do_warmup=False)
    return run_sweep(spec).rows()


def test_engine_bitwise_identical_with_recorder_on_and_off():
    was = trace_lib.RECORDER.enabled
    try:
        trace_lib.RECORDER.enabled = True
        (on,) = _sweep_rows("full")
        trace_lib.RECORDER.enabled = False
        (off,) = _sweep_rows("full")
    finally:
        trace_lib.RECORDER.enabled = was
    assert np.array_equal(on.queue_timeline, off.queue_timeline)
    assert np.array_equal(on.lat_pred, off.lat_pred)
    assert np.array_equal(on.d_timeline, off.d_timeline)
    assert np.array_equal(on.steered, off.steered)


def test_summary_q_mean_matches_full_timeline_bitwise():
    (full,) = _sweep_rows("full")
    (summ,) = _sweep_rows("summary")
    assert summ.q_mean_timeline is not None
    # both sides reduce the same float32 timeline with jnp.mean
    want = np.asarray(
        windows.q_mean_series(full), np.float32)
    got = np.asarray(summ.q_mean_timeline, np.float32)
    assert np.array_equal(got, want)
    # and the shared window detector sees the identical series
    assert windows.detect(got) == windows.detect(want)


# ---------------------------------------------------------------------------
# repro-report round-trip and --check
# ---------------------------------------------------------------------------


def _emit_pair(tmp_path):
    rec = trace_lib.RECORDER
    rec.configure(path=tmp_path / "art.trace.jsonl", fresh=True,
                  enabled=True)
    with rec.span("bench/first_call", cat="bench"):
        pass
    with rec.span("sim/run", cat="execute") as sp:
        sp["compiled"] = True
    rec.write_chrome(tmp_path / "art.trace.json")
    doc = {
        "meta": {"jax_version": "0", "device_kind": "cpu"},
        "cells": {"a": {
            "window": {"begin": 2, "end": 38, "T": 40,
                       "method": "ewma_plateau", "censored": False},
            "stable": {"mean_queue": 1.0},
            "window_shift": {"mean_queue": -0.1},
        }},
    }
    (tmp_path / "art.json").write_text(json.dumps(doc))
    return tmp_path


def test_report_round_trips_artifact_and_trace(tmp_path, capsys):
    _emit_pair(tmp_path)
    assert report_main([str(tmp_path / "art.json")]) == 0
    out = capsys.readouterr().out
    assert "cells.a" in out and "ewma_plateau" in out
    assert "first-call" in out


def test_report_check_clean_and_detects_bad_window(tmp_path, capsys):
    _emit_pair(tmp_path)
    assert report_main(["--check", str(tmp_path)]) == 0
    bad = {"window": {"begin": 9, "end": 3, "T": 40,
                      "method": "ewma_plateau"}}
    (tmp_path / "bad.json").write_text(json.dumps(bad))
    assert report_main(["--check", str(tmp_path)]) == 1
    assert "invariant" in capsys.readouterr().err


def test_check_paths_flags_malformed_middle_line(tmp_path):
    good = json.dumps({"name": "a", "cat": "c", "ph": "i", "ts": 0.0,
                       "pid": 1, "tid": 1})
    p = tmp_path / "x.trace.jsonl"
    p.write_text("{not json\n" + good + "\n")
    assert any("malformed" in m for m in check_paths([p]))


# The hypothesis properties over arbitrary timelines (window invariant,
# constant-load warmup bound) live in tests/test_properties.py — that
# module already gates on the optional hypothesis dep, and importorskip
# would skip THIS whole module, deterministic tests included.
