"""Proxy fleet: Δ=0 equivalence contract, gossip-delayed visibility,
the write-pressure install guard, and eager SimConfig validation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SimConfig, make_workload, simulate
from repro.core import cache as cache_lib
from repro.core import fleet as fleet_lib

DT = 50.0


def _one(key, write=False):
    return (jnp.asarray([key], jnp.int32), jnp.asarray([True]),
            jnp.asarray([bool(write)]))


def _step(fl, key, proxy, t, *, write=False, gossip_ms=100.0,
          mode="lease", lease_ms=100_000.0):
    """Drive one single-request tick at time t·DT served by ``proxy``."""
    keys, mask, w = _one(key, write)
    assert int(fl.tick) == t, "ticks must be driven in order"
    return fleet_lib.lookup_fleet(
        fl, keys, mask, w, jnp.asarray([proxy], jnp.int32),
        jnp.asarray(t * DT), mode=mode, lease_ms=lease_ms,
        gossip_ms=gossip_ms)


# ---------------------------------------------------------------------------
# Δ=0 equivalence (the fleet's core contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", cache_lib.MODES)
@pytest.mark.parametrize("P", [1, 2, 8])
def test_gossip_zero_fleet_matches_shared_table_in_sim(mode, P):
    """End-to-end: a gossip_ms=0 fleet run is bit-for-bit the shared-table
    cache run — counters AND queue dynamics."""
    wl = make_workload("skewed", T=150, m=4, seed=3, write_frac=0.2)
    a = simulate(SimConfig(m=4, policy="hash", middleware=("cache",),
                           cache_mode=mode), wl, do_warmup=False)
    b = simulate(SimConfig(m=4, P=P, policy="hash",
                           middleware=("fleet_cache",), cache_mode=mode,
                           gossip_ms=0.0), wl, do_warmup=False)
    sc, fc = a.final_cache, b.final_cache
    assert int(sc.hits) == int(fc.hits)
    assert int(sc.misses) == int(fc.misses)
    assert int(sc.stale_serves) == int(fc.stale_serves)
    assert int(sc.bypasses) == int(fc.bypasses)
    np.testing.assert_array_equal(np.asarray(sc.expiry_ms),
                                  np.asarray(fc.shared.expiry_ms))
    np.testing.assert_array_equal(np.asarray(sc.global_version),
                                  np.asarray(fc.shared.global_version))
    np.testing.assert_array_equal(a.queue_timeline, b.queue_timeline)
    np.testing.assert_array_equal(a.cache_hits, b.cache_hits)


def test_per_proxy_counters_sum_to_aggregate():
    wl = make_workload("skewed", T=200, m=4, seed=5, write_frac=0.1)
    r = simulate(SimConfig(m=4, P=8, policy="hash",
                           middleware=("fleet_cache",), gossip_ms=100.0),
                 wl, do_warmup=False)
    fc = r.final_cache
    assert int(fc.hits_p.sum()) == int(fc.hits)
    assert int(fc.misses_p.sum()) == int(fc.misses)
    assert int(fc.stale_p.sum()) == int(fc.stale_serves)
    assert int(fc.bypasses_p.sum()) == int(fc.bypasses)
    # with the tick-rotated shard, no proxy monopolizes the traffic
    assert int((fc.hits_p + fc.misses_p > 0).sum()) == 8


# ---------------------------------------------------------------------------
# Gossip-delayed visibility (Δ > 0)
# ---------------------------------------------------------------------------


def test_remote_install_invisible_until_gossip_propagates():
    """gossip_ms=100 at dt=50: an entry installed by proxy 0 is invisible
    to proxy 1 for two ticks, then visible."""
    fl = fleet_lib.init_fleet(16, P=2, D=fleet_lib.delay_ticks(100.0, DT))
    fl, hit = _step(fl, 3, proxy=0, t=0)          # p0 installs (miss)
    assert not bool(hit[0])
    fl, hit = _step(fl, 3, proxy=1, t=1)          # too fresh for p1
    assert not bool(hit[0])
    fl, _ = _step(fl, 9, proxy=0, t=2)            # unrelated tick
    # p1's reinstall at t=1 is the latest event on key 3; by t=3 it is
    # 100 ms old, so every proxy sees the entry
    fl, hit = _step(fl, 3, proxy=0, t=3)
    assert bool(hit[0])
    assert int(fl.shared.hits) == 1 and int(fl.shared.misses) == 3


def test_own_events_always_visible_immediately():
    fl = fleet_lib.init_fleet(16, P=2, D=fleet_lib.delay_ticks(500.0, DT))
    fl, hit = _step(fl, 7, proxy=0, t=0, gossip_ms=500.0)
    assert not bool(hit[0])
    fl, hit = _step(fl, 7, proxy=0, t=1, gossip_ms=500.0)  # own install
    assert bool(hit[0])


def test_lease_mode_pays_stale_serves_under_gossip_delay():
    """The Δ=0 'staleness is zero by construction' claim breaks once
    invalidations take time to travel: a remote proxy serves the
    pre-write entry from its lagged view, and the omniscient counter
    records it."""
    fl = fleet_lib.init_fleet(16, P=2, D=fleet_lib.delay_ticks(100.0, DT))
    fl, _ = _step(fl, 3, proxy=0, t=0)                 # p0 installs
    fl, _ = _step(fl, 3, proxy=0, t=1, write=True)     # p0 invalidates
    fl, hit = _step(fl, 3, proxy=1, t=2)               # p1: lagged view
    assert bool(hit[0])                                # served locally...
    assert int(fl.shared.stale_serves) == 1            # ...and it was stale
    assert int(fl.stale_p[1]) == 1
    # once the invalidation propagates, the entry is gone fleet-wide
    fl, _ = _step(fl, 9, proxy=0, t=3)                 # unrelated tick
    fl, hit = _step(fl, 3, proxy=1, t=4)
    assert not bool(hit[0])


def test_gossip_delay_monotonically_hurts_lease_coherence():
    wl = make_workload("skewed", T=400, m=8, seed=2, write_frac=0.15)
    stale = []
    for g in (0.0, 100.0, 400.0):
        r = simulate(SimConfig(m=8, P=8, policy="hash",
                               middleware=("fleet_cache",), gossip_ms=g),
                     wl, do_warmup=False)
        stale.append(int(r.final_cache.stale_serves))
    assert stale[0] == 0                  # Δ=0 recovers the lease guarantee
    assert stale[2] > stale[1] >= stale[0]


# ---------------------------------------------------------------------------
# Write-pressure install guard (satellite: the E8 rename_storm fix)
# ---------------------------------------------------------------------------


def test_write_pressure_guard_flips_installs_off_and_back_on():
    c = cache_lib.init_cache(16)
    keys, mask, w = _one(5)
    # storm window: write mix far above W_HIGH with enough events
    c = c._replace(win_writes=jnp.asarray(100.0),
                   win_reads=jnp.asarray(10.0))
    assert float(cache_lib.write_pressure(c)) > cache_lib.W_HIGH
    c, hit = cache_lib.lookup_batch(c, keys, mask, w, jnp.asarray(0.0))
    assert not bool(hit[0])
    assert int(c.bypasses) == 1                      # install bypassed...
    c, hit = cache_lib.lookup_batch(c, keys, mask, w, jnp.asarray(1.0))
    assert not bool(hit[0]) and int(c.bypasses) == 2  # ...so still a miss
    # calm window: guard releases, installs resume
    c = c._replace(win_writes=jnp.asarray(0.0),
                   win_reads=jnp.asarray(100.0))
    assert float(cache_lib.write_pressure(c)) <= cache_lib.W_HIGH
    c, hit = cache_lib.lookup_batch(c, keys, mask, w, jnp.asarray(2.0))
    assert not bool(hit[0]) and int(c.bypasses) == 2
    c, hit = cache_lib.lookup_batch(c, keys, mask, w, jnp.asarray(3.0))
    assert bool(hit[0])                               # entry installed again


def test_write_pressure_guard_ignores_tiny_windows():
    """A couple of writes right after the window reset must not trip the
    guard — the live signal needs GUARD_MIN_EVENTS samples."""
    c = cache_lib.init_cache(16)
    c = c._replace(win_writes=jnp.asarray(3.0), win_reads=jnp.asarray(0.0))
    assert float(cache_lib.write_pressure(c)) <= cache_lib.W_HIGH


def test_guard_uses_slow_ewma_too():
    c = cache_lib.init_cache(16)
    c = c._replace(write_frac=jnp.asarray(0.5, jnp.float32))
    assert float(cache_lib.write_pressure(c)) > cache_lib.W_HIGH


# ---------------------------------------------------------------------------
# Eager SimConfig validation (satellite)
# ---------------------------------------------------------------------------


def test_unknown_policy_raises_at_construction():
    with pytest.raises(ValueError, match="available.*round_robin"):
        SimConfig(policy="no_such_policy")


def test_unknown_middleware_stage_raises_at_construction():
    with pytest.raises(ValueError, match="available.*fleet_cache"):
        SimConfig(middleware=("no_such_stage",))


def test_unknown_cache_mode_raises_at_construction():
    with pytest.raises(ValueError, match="available.*ttl_per_key"):
        SimConfig(cache_mode="write_through")


@pytest.mark.parametrize("field", ["m", "P", "N", "V", "n_groups"])
def test_nonpositive_sizes_raise_at_construction(field):
    with pytest.raises(ValueError, match=f"{field} must be a positive"):
        SimConfig(**{field: 0})


def test_negative_gossip_raises_at_construction():
    with pytest.raises(ValueError, match="gossip_ms"):
        SimConfig(gossip_ms=-1.0)


def test_valid_config_still_constructs():
    cfg = SimConfig(policy="midas", middleware=("fleet_cache",),
                    cache_mode="ttl_per_key", gossip_ms=250.0)
    assert cfg.middleware_chain == ("fleet_cache",)
