"""Middleware pipeline: cache-as-stage preserves the coherence invariants
of test_core_cache.py, absorbed requests leave the batch, stages compose."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SimConfig, controllers, make_workload,
                        middleware as mw_lib, simulate)


def _cache_mw(mode="lease", **cfg_kw):
    cfg = SimConfig(N=16, cache_mode=mode, **cfg_kw)
    mw = mw_lib.get("cache")
    return mw, mw.init(cfg), cfg


def _batch(keys, writes=None, now=0.0):
    keys = jnp.asarray(keys, jnp.int32)
    mask = jnp.ones_like(keys, dtype=bool)
    w = jnp.zeros_like(mask) if writes is None else jnp.asarray(writes, bool)
    return mw_lib.BatchView(keys=keys, mask=mask, is_write=w,
                            now_ms=jnp.asarray(now),
                            rng=jnp.zeros((2,), jnp.uint32))


def test_unknown_middleware_error_lists_names():
    with pytest.raises(ValueError, match="available"):
        mw_lib.get("no_such_stage")


def test_cache_stage_miss_then_hit_within_ttl():
    mw, st, cfg = _cache_mw()
    st, mask, absorbed = mw.on_batch(st, _batch([3], now=0.0), cfg)
    assert bool(mask[0]) and float(absorbed) == 0      # miss reaches server
    st, mask, absorbed = mw.on_batch(st, _batch([3], now=10.0), cfg)
    assert not bool(mask[0]) and float(absorbed) == 1  # hit absorbed
    assert int(st.hits) == 1 and int(st.misses) == 1


def test_cache_stage_write_invalidates_immediately():
    mw, st, cfg = _cache_mw("lease")
    st, _, _ = mw.on_batch(st, _batch([3], now=0.0), cfg)
    st, mask, _ = mw.on_batch(st, _batch([3], writes=[True], now=1.0), cfg)
    assert bool(mask[0])                               # writes pass through
    st, mask, _ = mw.on_batch(st, _batch([3], now=2.0), cfg)
    assert bool(mask[0])                               # entry was invalidated
    assert int(st.stale_serves) == 0


def test_cache_stage_never_serves_past_expiry():
    mw, st, cfg = _cache_mw("lease", lease_ms=100.0)
    st, _, _ = mw.on_batch(st, _batch([5], now=0.0), cfg)
    st, mask, absorbed = mw.on_batch(st, _batch([5], now=101.0), cfg)
    assert bool(mask[0]) and float(absorbed) == 0


def test_cache_stage_slow_hook_retunes_ttl():
    mw, st, cfg = _cache_mw("ttl_aggregate", rtt_ms=5.0)
    st = st._replace(win_writes=jnp.asarray(100.0),
                     win_reads=jnp.asarray(100.0))
    knobs = controllers.init_knobs(cfg.rtt_ms)
    st2 = mw.on_slow(st, cfg, knobs)
    assert float(st2.ttl_ms) >= 5.0                    # >= one RTT
    assert float(st2.win_writes) == 0.0                # window reset
    # the controller-emitted ttl_scale knob scales the retuned horizon
    half = mw.on_slow(
        st, cfg, knobs._replace(ttl_scale=jnp.asarray(0.5, jnp.float32)))
    assert float(half.ttl_ms) == pytest.approx(
        max(float(st2.ttl_ms) * 0.5, cfg.rtt_ms))


def test_legacy_cache_flag_equals_middleware_chain():
    """cache_enabled=True is exactly middleware=("cache",)."""
    wl = make_workload("skewed", T=120, m=4, seed=2)
    a = simulate(SimConfig(m=4, policy="hash", cache_enabled=True), wl,
                 do_warmup=False)
    b = simulate(SimConfig(m=4, policy="hash", middleware=("cache",)), wl,
                 do_warmup=False)
    np.testing.assert_array_equal(a.queue_timeline, b.queue_timeline)
    np.testing.assert_array_equal(a.cache_hits, b.cache_hits)
    assert int(a.final_cache.hits) == int(b.final_cache.hits)


def test_custom_stage_composes_before_cache():
    """A third-party stage slots into the pipeline ahead of the cache."""
    @mw_lib.register("_test_drop_writes")
    class DropWrites(mw_lib.Middleware):
        def on_batch(self, state, batch, cfg):
            keep = batch.mask & ~batch.is_write
            absorbed = jnp.sum(batch.mask & batch.is_write)
            return state, keep, absorbed.astype(jnp.float32)

    try:
        wl = make_workload("skewed", T=60, m=4, seed=4, write_frac=0.5)
        cfg = SimConfig(m=4, policy="hash",
                        middleware=("_test_drop_writes", "cache"))
        res = simulate(cfg, wl, do_warmup=False)
        # with every write absorbed upstream, the cache never sees an
        # invalidation => zero stale serves, and arrivals < offered load
        assert int(res.final_cache.stale_serves) == 0
        assert res.arrivals.sum() < np.asarray(wl.mask).sum()
    finally:
        mw_lib.unregister("_test_drop_writes")
