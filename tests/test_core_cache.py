"""Cooperative cache: coherence invariants per mode."""
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cache_lib


def _req(keys, writes=None):
    keys = jnp.asarray(keys, jnp.int32)
    mask = jnp.ones_like(keys, dtype=bool)
    w = jnp.zeros_like(mask) if writes is None else jnp.asarray(writes, bool)
    return keys, mask, w


def test_miss_then_hit_within_ttl():
    c = cache_lib.init_cache(16)
    keys, mask, w = _req([3])
    c, hit = cache_lib.lookup_batch(c, keys, mask, w, jnp.asarray(0.0),
                                    mode="lease", lease_ms=1000.0)
    assert not bool(hit[0])
    c, hit = cache_lib.lookup_batch(c, keys, mask, w, jnp.asarray(10.0),
                                    mode="lease", lease_ms=1000.0)
    assert bool(hit[0])
    assert int(c.hits) == 1 and int(c.misses) == 1


def test_lease_mode_write_invalidates_immediately():
    c = cache_lib.init_cache(16)
    keys, mask, _ = _req([3])
    c, _ = cache_lib.lookup_batch(c, keys, mask, jnp.zeros(1, bool),
                                  jnp.asarray(0.0), mode="lease")
    # write to key 3 kills the entry
    c, _ = cache_lib.lookup_batch(c, keys, mask, jnp.ones(1, bool),
                                  jnp.asarray(1.0), mode="lease")
    c, hit = cache_lib.lookup_batch(c, keys, mask, jnp.zeros(1, bool),
                                    jnp.asarray(2.0), mode="lease")
    assert not bool(hit[0])            # never served past invalidation
    assert int(c.stale_serves) == 0


def test_entry_never_served_past_expiry():
    c = cache_lib.init_cache(16)
    keys, mask, w = _req([5])
    c, _ = cache_lib.lookup_batch(c, keys, mask, w, jnp.asarray(0.0),
                                  mode="lease", lease_ms=100.0)
    c, hit = cache_lib.lookup_batch(c, keys, mask, w, jnp.asarray(101.0),
                                    mode="lease", lease_ms=100.0)
    assert not bool(hit[0])


def test_ttl_per_key_hot_keys_get_short_ttls():
    c = cache_lib.init_cache(16)
    now = 0.0
    # hammer key 1 with writes every 10 ms -> high hazard
    for i in range(20):
        keys, mask, _ = _req([1])
        c, _ = cache_lib.lookup_batch(c, keys, mask, jnp.ones(1, bool),
                                      jnp.asarray(now), mode="ttl_per_key")
        now += 10.0
    h_hot = float(c.key_hazard[1])
    assert h_hot > 0.01               # ~1/10ms
    # installing hot key now gets TTL near the floor
    keys, mask, w = _req([1])
    c, _ = cache_lib.lookup_batch(c, keys, mask, w, jnp.asarray(now),
                                  mode="ttl_per_key", rtt_ms=2.0)
    ttl_installed = float(c.expiry_ms[1]) - now
    assert ttl_installed <= 2.0 + 1e-3   # clipped to RTT floor


def test_sentinel_does_not_corrupt_last_key():
    """Regression: masked-out scatters must not write to key N-1."""
    N = 8
    c = cache_lib.init_cache(N)
    keys = jnp.asarray([0], jnp.int32)
    mask = jnp.asarray([False])        # nothing valid
    c2, hit = cache_lib.lookup_batch(c, keys, mask, jnp.zeros(1, bool),
                                     jnp.asarray(0.0), mode="lease")
    np.testing.assert_array_equal(np.asarray(c2.expiry_ms),
                                  np.asarray(c.expiry_ms))
    np.testing.assert_array_equal(np.asarray(c2.global_version),
                                  np.asarray(c.global_version))
    assert not bool(hit[0])


def test_slow_update_ttl_respects_lease_and_floor():
    c = cache_lib.init_cache(16)
    c = c._replace(win_writes=jnp.asarray(100.0),
                   win_reads=jnp.asarray(100.0))
    c2 = cache_lib.slow_update(c, window_ms=30_000.0, rtt_ms=5.0,
                               lease_remaining_ms=50.0)
    assert float(c2.ttl_ms) <= 50.0    # capped by lease expiry
    assert float(c2.ttl_ms) >= 5.0     # >= one RTT
    assert float(c2.win_writes) == 0.0  # window reset


def test_slow_update_gamma_shrink_under_heavy_writes():
    c = cache_lib.init_cache(16)
    base = c._replace(win_writes=jnp.asarray(10.0),
                      win_reads=jnp.asarray(1000.0))
    lo = cache_lib.slow_update(base, 30_000.0, 0.001)
    heavy = c._replace(win_writes=jnp.asarray(900.0),
                       win_reads=jnp.asarray(100.0),
                       write_frac=jnp.asarray(0.9))
    hi = cache_lib.slow_update(heavy, 30_000.0, 0.001)
    # same hazard-free comparison isn't exact; check the γ path triggered
    assert float(hi.write_frac) > cache_lib.W_HIGH
    assert float(lo.write_frac) < cache_lib.W_HIGH
