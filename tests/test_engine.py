"""Engine performance contracts (DESIGN.md §9).

Three contracts, all load-bearing for the scan-over-waves rewrite:

* **Wave parity** — the scan engine reproduces the unrolled reference
  bit-for-bit on CPU: queue timelines, counters, and the full final
  state (policy pytree included), for legacy waves (n_groups ∈ {1,4,8})
  and fleet routing (P ∈ {2,8}), across policies × middleware chains.
* **Summary parity** — ``metrics="summary"`` sweep rows equal the
  post-hoc :func:`repro.core.sim.summarize` reduction of the matching
  full-timeline rows, and their sketch quantiles track the exact ones.
* **Compile behaviour** — the wave-scan body is traced O(1) times per
  compile (not once per wave), and lowered HLO size is flat in
  ``n_groups`` where the unrolled reference grows linearly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SimConfig, make_workload, simulate, simulate_sweep,
                        summarize, telemetry)
from repro.core import control as ctl
from repro.core import sim as sim_lib

T = 160
WL = make_workload("bursty", T=T, m=8, seed=3, N=512)


def _pair(cfg):
    """(scan result, unrolled-reference result) for one config."""
    ref = dataclasses.replace(cfg, unroll_waves=True)
    return (simulate(cfg, WL, do_warmup=False),
            simulate(ref, WL, do_warmup=False))


def _assert_results_equal(a, b):
    for f in ("queue_timeline", "arrivals", "lat_pred", "d_timeline",
              "delta_l_timeline", "pressure", "steered", "eligible",
              "cache_hits", "f_max_timeline"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f)


@pytest.mark.parametrize("n_groups", [1, 4, 8])
@pytest.mark.parametrize("policy,mw", [
    ("power_of_d", ()),
    ("midas", ("cache",)),
    ("chbl", ()),
])
def test_scan_matches_unrolled_bitwise(n_groups, policy, mw):
    cfg = SimConfig(m=8, N=512, policy=policy, middleware=mw,
                    n_groups=n_groups)
    _assert_results_equal(*_pair(cfg))


@pytest.mark.parametrize("policy,mw", [
    ("power_of_d", ()),
    ("midas", ("cache",)),
    ("chbl", ()),
])
def test_route_impl_pallas_matches_ref_bitwise(policy, mw):
    """The route_select kernel path (interpret mode on CPU) is bit-for-
    bit the jnp policy path — the DESIGN.md §15 parity contract on the
    E8 smoke policies."""
    cfg = SimConfig(m=8, N=512, policy=policy, middleware=mw,
                    route_impl="ref")
    pal = dataclasses.replace(cfg, route_impl="pallas")
    _assert_results_equal(simulate(cfg, WL, do_warmup=False),
                          simulate(pal, WL, do_warmup=False))


def test_route_impl_validated_eagerly():
    with pytest.raises(ValueError, match="unknown route_impl"):
        SimConfig(route_impl="cuda")


def test_route_impl_auto_resolves_ref_on_cpu(monkeypatch):
    """auto == default_impl(): ref on CPU (golden files stay pinned),
    overridable via REPRO_KERNEL_IMPL."""
    from repro.kernels import common as kernels_common

    monkeypatch.delenv("REPRO_KERNEL_IMPL", raising=False)
    assert kernels_common.resolve_route_impl("auto") == \
        kernels_common.default_impl()
    if jax.default_backend() != "tpu":
        assert kernels_common.resolve_route_impl("auto") == "ref"
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
    assert kernels_common.resolve_route_impl("auto") == "pallas"
    assert kernels_common.resolve_route_impl("ref") == "ref"


@pytest.mark.parametrize("P", [2, 8])
def test_fleet_routing_scan_matches_unrolled_bitwise(P):
    cfg = SimConfig(m=8, N=512, P=P, policy="midas",
                    middleware=("fleet_cache",), fleet_routing=True,
                    gossip_ms=100.0)
    _assert_results_equal(*_pair(cfg))


def test_scan_final_state_matches_unrolled_bitwise():
    """Full carried state — policy pins, caches, control, RNG — is
    identical, not just the emitted timelines."""
    cfg = SimConfig(m=8, N=512, policy="midas", middleware=("cache",))
    ref = dataclasses.replace(cfg, unroll_waves=True)
    fin_a, _ = sim_lib._run_scan(
        cfg, sim_lib.init_state(cfg), WL.keys, WL.mask, WL.is_write)
    fin_b, _ = sim_lib._run_scan(
        ref, sim_lib.init_state(ref), WL.keys, WL.mask, WL.is_write)
    la, lb = (jax.tree_util.tree_leaves(f) for f in (fin_a, fin_b))
    assert len(la) == len(lb)
    for xa, xb in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def test_scan_matches_unrolled_with_warmup():
    """Targets derived through warmup feed both engines identically."""
    cfg = SimConfig(m=8, N=512, policy="midas", cache_enabled=True)
    ref = dataclasses.replace(cfg, unroll_waves=True)
    a = simulate(cfg, WL)
    b = simulate(ref, WL)
    _assert_results_equal(a, b)


# ---------------------------------------------------------------------------
# Summary metrics parity
# ---------------------------------------------------------------------------


def test_summary_matches_full_reduction():
    wls = [make_workload(n, T=120, m=4, seed=0, N=256)
           for n in ("bursty", "skewed")]
    kw = dict(policies=("midas", "round_robin"), seeds=(0, 1),
              do_warmup=False)
    cfg = SimConfig(m=4, N=256, middleware=("cache",))
    full = simulate_sweep(cfg, wls, **kw)
    summ = simulate_sweep(cfg, wls, metrics="summary", **kw)
    for policy in kw["policies"]:
        for wl_name in ("bursty", "skewed"):
            for fr, sr in zip(full[policy][wl_name], summ[policy][wl_name]):
                ref = summarize(fr)
                assert sr.n_ticks == ref.n_ticks == 120
                np.testing.assert_allclose(sr.queue_sum, ref.queue_sum,
                                           rtol=1e-6)
                np.testing.assert_allclose(sr.queue_hist, ref.queue_hist,
                                           rtol=1e-6, atol=1e-3)
                np.testing.assert_allclose(sr.lat_hist, ref.lat_hist,
                                           rtol=1e-6, atol=1e-3)
                assert sr.max_queue() == ref.max_queue() == fr.max_queue()
                assert sr.cache_hits_total == pytest.approx(
                    fr.cache_hits.sum())
                assert sr.steered_total == pytest.approx(fr.steered.sum())
                # derived metrics agree with the exact full-timeline ones
                assert sr.mean_queue() == pytest.approx(
                    fr.mean_queue(), abs=1e-4)
                assert sr.dispersion() == pytest.approx(
                    fr.dispersion(), abs=1e-4)
                assert sr.dispersion_t() == pytest.approx(
                    fr.dispersion_t(), abs=1e-4)


def test_summary_quantiles_track_exact_within_sketch_resolution():
    wl = make_workload("skewed", T=200, m=8, seed=5)
    cfg = SimConfig(m=8, policy="power_of_d")
    (fr,) = simulate_sweep(cfg, wl, do_warmup=False)["power_of_d"]
    (sr,) = simulate_sweep(cfg, wl, do_warmup=False,
                           metrics="summary")["power_of_d"]
    assert sr.worst_case_queue() == pytest.approx(
        fr.worst_case_queue(), rel=0.1)
    p50f, p99f = fr.latency_quantiles()
    p50s, p99s = sr.latency_quantiles()
    assert p50s == pytest.approx(p50f, rel=0.1, abs=1.0)
    assert p99s == pytest.approx(p99f, rel=0.1, abs=1.0)


def test_summary_single_workload_keeps_legacy_shape():
    sweep = simulate_sweep(SimConfig(m=4, N=256), WL_SMALL,
                           seeds=(0, 1), do_warmup=False,
                           metrics="summary")
    rows = sweep["midas"]
    assert len(rows) == 2
    for r in rows:
        assert isinstance(r, sim_lib.SummaryResult)
        assert r.config.m == 4


WL_SMALL = make_workload("light", T=60, m=4, seed=0, N=256)


def test_sweep_rejects_unknown_metrics_mode():
    with pytest.raises(ValueError, match="metrics"):
        simulate_sweep(SimConfig(m=4), WL_SMALL, metrics="everything")


# ---------------------------------------------------------------------------
# Compile behaviour: trace counts and HLO size
# ---------------------------------------------------------------------------


def test_wave_scan_body_trace_count_is_flat_in_n_groups():
    """The wave body traces a constant number of times per compile —
    NOT once per wave.  (The unrolled reference runs its Python loop
    body G times per trace; the scan engine must not.)"""
    wl = make_workload("light", T=24, m=6, seed=0, N=128)
    deltas = {}
    for G in (4, 12):
        cfg = SimConfig(m=6, N=128, policy="power_of_d", n_groups=G)
        before = sim_lib._WAVE_TRACES[0]
        simulate(cfg, wl, do_warmup=False)
        deltas[G] = sim_lib._WAVE_TRACES[0] - before
    assert deltas[4] == deltas[12], deltas
    # a compile re-enters the body a small constant number of times
    # (carry-structure discovery + lowering), never per-wave
    assert 1 <= deltas[12] < 4, deltas


def test_sweep_compiles_once_per_policy_across_group_sizes():
    """Changing n_groups at fixed grid shapes costs one cheap retrace of
    the O(1) wave-scan trace; seeds never retrace."""
    wl = make_workload("light", T=24, m=6, seed=1, N=128)
    for G in (2, 6):
        cfg = SimConfig(m=6, N=128, policy="power_of_d", n_groups=G)
        before = sim_lib._SWEEP_TRACES[0]
        simulate_sweep(cfg, wl, seeds=(0, 1, 2), do_warmup=False)
        assert sim_lib._SWEEP_TRACES[0] == before + 1
        # warm cache: same cfg + shapes re-runs without any retrace
        before = sim_lib._SWEEP_TRACES[0]
        simulate_sweep(cfg, wl, seeds=(3, 4, 5), do_warmup=False)
        assert sim_lib._SWEEP_TRACES[0] == before


def test_hlo_size_flat_in_n_groups_for_scan_engine():
    """Lowered-HLO size is O(1) in n_groups for the wave scan and O(G)
    for the unrolled reference — the §9 compile-cost contract."""
    wl = make_workload("light", T=16, m=6, seed=2, N=128)
    st_args = lambda cfg: (cfg, sim_lib.init_state(cfg), wl.keys, wl.mask,
                           wl.is_write)

    def hlo_chars(G, unroll):
        cfg = SimConfig(m=6, N=128, policy="power_of_d", n_groups=G,
                        unroll_waves=unroll)
        return len(sim_lib._run_scan.lower(*st_args(cfg)).as_text())

    scan_small, scan_big = hlo_chars(2, False), hlo_chars(16, False)
    ref_small, ref_big = hlo_chars(2, True), hlo_chars(16, True)
    assert scan_big < 1.15 * scan_small, (scan_small, scan_big)
    assert ref_big > 2.0 * ref_small, (ref_small, ref_big)


# ---------------------------------------------------------------------------
# Telemetry helpers backing the engine
# ---------------------------------------------------------------------------


def test_weighted_quantiles_matches_reference_and_clips():
    v = np.array([3.0, 1.0, 2.0, 4.0])
    w = np.array([1.0, 1.0, 1.0, 1.0])
    assert telemetry.weighted_quantiles(v, w, (50, 100)) == (2.0, 4.0)
    # zero weight -> zeros
    assert telemetry.weighted_quantiles(v, np.zeros(4), (50,)) == (0.0,)
    # fp-clip regression: cumulative weight ending below 1.0 must not
    # index past the end for q=100
    w = np.full(10, 0.1)
    v = np.arange(10.0)
    (q100,) = telemetry.weighted_quantiles(v, w, (100,))
    assert q100 == 9.0


@pytest.mark.parametrize("alpha", [ctl.ALPHA_FAST, 0.9])
def test_ewma_series_matches_sequential_loop(alpha):
    """Parity with the recurrence — including fast-decay alphas, where
    the blocked rescale must cap the block to dodge float64 underflow
    (regression: alpha=0.9 used to NaN the tail)."""
    rng = np.random.default_rng(0)
    x = rng.uniform(0, 10, size=(700, 5))
    got = telemetry.ewma_series(x, alpha, block=64)
    acc = np.zeros(5)
    want = np.zeros_like(x)
    for t in range(x.shape[0]):
        acc = (1 - alpha) * acc + alpha * x[t]
        want[t] = acc
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-12)


def test_hist_sketch_quantiles_within_bin_resolution():
    rng = np.random.default_rng(1)
    vals = rng.lognormal(mean=3.0, sigma=1.0, size=4096).astype(np.float32)
    sk = telemetry.hist_add(telemetry.make_hist(), jnp.asarray(vals),
                            jnp.ones(vals.shape, jnp.float32))
    counts = np.asarray(sk.counts)
    assert counts.sum() == pytest.approx(vals.size)
    for q in (50.0, 99.0, 99.9):
        exact = float(np.percentile(vals, q))
        approx = telemetry.hist_quantile(counts, q)
        assert approx == pytest.approx(exact, rel=0.08), q
    # zeros land in the underflow bin and read back as 0.0
    sk0 = telemetry.hist_add(telemetry.make_hist(), jnp.zeros((8,)),
                             jnp.ones((8,)))
    assert telemetry.hist_quantile(np.asarray(sk0.counts), 50.0) == 0.0
    assert telemetry.hist_quantile(np.zeros(4), 50.0) == 0.0
