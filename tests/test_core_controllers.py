"""Controller registry contracts (DESIGN.md §10).

Four load-bearing contracts:

* **Golden parity** — ``SimConfig(controller="hysteresis")`` (the
  default) reproduces the PRE-REFACTOR engine bit-for-bit on CPU:
  timelines, counters, and knob trajectories recorded in
  ``tests/data/control_golden.npz`` by the monolithic ``control.py``
  engine, across policies × middleware × ablations, through the
  slow-loop cadence and the warmup target derivation.
* **Registry behaviour** — registration, list-alternatives errors,
  third-party plug-in via ``@controllers.register``.
* **Knob schema** — every registered controller's emitted knobs stay
  inside their :class:`KnobSpec` bounds, and no controller sustains a
  limit cycle under constant load (hypothesis, registry-wide).
* **Ablation decorators** — masks apply to the emitted view only; the
  wrapped controller's dynamics are untouched.
"""
import dataclasses
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SimConfig, controllers, make_workload, simulate
from repro.core import control as ctl
from repro.core import telemetry

GOLDEN = np.load(Path(__file__).parent / "data" / "control_golden.npz")

FIELDS = (
    "queue_timeline",
    "arrivals",
    "lat_pred",
    "d_timeline",
    "delta_l_timeline",
    "f_max_timeline",
    "pressure",
    "steered",
    "eligible",
    "cache_hits",
)
WL = make_workload("bursty", T=160, m=8, seed=3, N=512)


def _assert_matches_golden(res, name):
    for f in FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(res, f)), GOLDEN[f"{name}/{f}"],
            err_msg=f"{name}/{f}")


# ---------------------------------------------------------------------------
# Golden parity: the default controller IS the pre-refactor engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,kw", [
    ("pod_bare", dict(policy="power_of_d", middleware=())),
    ("chbl_bare", dict(policy="chbl", middleware=())),
    ("midas_cache", dict(policy="midas", middleware=("cache",))),
    ("midas_fleet", dict(policy="midas", middleware=("fleet_cache",),
                         fleet_routing=True, gossip_ms=100.0)),
    ("midas_no_margin", dict(policy="midas", middleware=("cache",),
                             ablate="no_margin")),
    ("midas_no_pin", dict(policy="midas", middleware=("cache",),
                          ablate="no_pin")),
    ("midas_no_bucket", dict(policy="midas", middleware=("cache",),
                             ablate="no_bucket")),
])
def test_default_controller_matches_prerefactor_engine(name, kw):
    cfg = SimConfig(m=8, N=512, **kw)
    assert cfg.controller == "hysteresis"
    _assert_matches_golden(simulate(cfg, WL, do_warmup=False), name)


@pytest.mark.parametrize("name,mode", [
    ("midas_slow_ttl", "ttl_aggregate"),
    ("midas_slow_lease", "lease"),
])
def test_default_controller_matches_golden_through_slow_loop(name, mode):
    """700 ticks crosses the T_slow cadence: the controller-threaded
    ``ttl_scale`` knob (identity at init) must not perturb the retune."""
    wl = make_workload("bursty", T=700, m=8, seed=3, N=512)
    cfg = SimConfig(m=8, N=512, policy="midas", middleware=("cache",),
                    cache_mode=mode)
    _assert_matches_golden(simulate(cfg, wl, do_warmup=False), name)


def test_default_controller_matches_golden_with_warmup():
    cfg = SimConfig(m=8, N=512, policy="midas", middleware=("cache",))
    _assert_matches_golden(simulate(cfg, WL), "midas_warmup")


def test_legacy_fast_update_matches_golden_trajectory():
    """The ``control.fast_update`` shim (now delegating to the registered
    hysteresis controller) replays the recorded pre-refactor knob
    trajectory bit-for-bit."""
    B = GOLDEN["fast_update/B"]
    p99 = GOLDEN["fast_update/p99"]
    jit = GOLDEN["fast_update/jitter"]
    c = ctl.init_control(rtt_ms=2.0, b_tgt=0.15, p99_tgt=500.0)
    for i in range(B.shape[0]):
        c = ctl.fast_update(c, jnp.asarray(B[i]), jnp.asarray(p99[i]),
                            2.0, jnp.asarray(jit[i]))
        for k in ("d", "delta_l", "delta_t", "f_max", "pressure"):
            assert np.asarray(getattr(c, k)) == GOLDEN[
                f"fast_update/{k}"][i], (i, k)


# ---------------------------------------------------------------------------
# Registry behaviour
# ---------------------------------------------------------------------------


def test_builtins_registered():
    names = controllers.available()
    for expect in ("hysteresis", "aimd", "deadband_pid", "static"):
        assert expect in names


def test_unknown_controller_lists_alternatives():
    with pytest.raises(ValueError, match="hysteresis"):
        SimConfig(controller="pid2000")
    with pytest.raises(ValueError, match="aimd"):
        controllers.get("nope")


def test_unknown_consensus_and_ablation_list_alternatives():
    with pytest.raises(ValueError, match="median"):
        SimConfig(consensus="mode")
    with pytest.raises(ValueError, match="no_bucket"):
        SimConfig(ablate="no_cache")


def test_third_party_controller_registers_and_runs():
    @controllers.register("always_max")
    class AlwaysMax(controllers.Controller):
        def fast(self, state, sig):
            state = state._replace(
                knobs=state.knobs._replace(
                    d=jnp.asarray(controllers.D_MAX, jnp.int32)))
            return state, self.view(state)

    try:
        wl = make_workload("light", T=40, m=4, seed=0, N=128)
        res = simulate(SimConfig(m=4, N=128, policy="midas",
                                 controller="always_max"), wl,
                       do_warmup=False)
        assert res.d_timeline.max() == controllers.D_MAX
        # duplicate registration under the same name is rejected
        with pytest.raises(ValueError, match="already registered"):
            @controllers.register("always_max")
            class Other(controllers.Controller):
                pass
    finally:
        controllers.unregister("always_max")
    assert "always_max" not in controllers.available()


# ---------------------------------------------------------------------------
# Knob schema
# ---------------------------------------------------------------------------


def test_knob_specs_cover_knobs_fields():
    assert tuple(s.name for s in controllers.KNOB_SPECS) == \
        controllers.Knobs._fields
    k = controllers.init_knobs(rtt_ms=2.0)
    for s, v in zip(controllers.KNOB_SPECS, k):
        init = 2.0 if s.init is None else s.init
        assert float(v) == pytest.approx(init), s.name
        assert s.lo - 1e-6 <= float(v) <= s.hi + 1e-6, s.name


def test_clip_knobs_enforces_bounds_and_dtypes():
    k = controllers.init_knobs(2.0)._replace(
        d=jnp.asarray(99, jnp.int32),
        delta_l=jnp.asarray(-3.0, jnp.float32),
        f_max=jnp.asarray(7.0, jnp.float32))
    c = controllers.clip_knobs(k)
    assert int(c.d) == controllers.D_MAX
    assert float(c.delta_l) == controllers.DELTA_L_MIN
    assert float(c.f_max) == controllers.F_MAX_HIGH
    assert c.d.dtype == jnp.int32


# ---------------------------------------------------------------------------
# Ablation decorators
# ---------------------------------------------------------------------------


def test_ablation_masks_view_not_dynamics():
    base_c = controllers.get("hysteresis")
    abl = controllers.wrap_ablations(
        controllers.get("hysteresis"), "no_margin,no_bucket")
    cfg = SimConfig(m=4)
    s0 = base_c.init(cfg, (0.0, 1.0))
    s1 = abl.init(cfg, (0.0, 1.0))
    sig = controllers.make_signals(B=5.0, p99=1e6, rtt_ms=2.0)
    for _ in range(10):
        s0, k0 = base_c.fast(s0, sig)
        s1, k1 = abl.fast(s1, sig)
    # identical dynamics: the carried state matches leaf-for-leaf
    for a, b in zip(jnp.asarray(s0.knobs.d)[None],
                    jnp.asarray(s1.knobs.d)[None]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(s1.knobs.delta_l) == float(s0.knobs.delta_l)
    assert float(s1.knobs.f_max) == float(s0.knobs.f_max)
    # ...but the emitted view is masked
    assert float(k1.delta_l) == 0.0
    assert float(k1.delta_t) < -1e8
    assert float(k1.f_max) == 1.0
    assert float(k0.f_max) < 1.0
    # un-ablated knobs pass through the view unchanged
    assert int(k1.d) == int(k0.d)
    assert float(k1.pin_ms) == float(k0.pin_ms)


def test_no_pin_ablation_zeroes_pin_view():
    abl = controllers.wrap_ablations(controllers.get("static"), "no_pin")
    st = abl.init(SimConfig(m=4), (0.1, 100.0))
    assert float(abl.view(st).pin_ms) == 0.0
    assert float(st.knobs.pin_ms) == controllers.PIN_C_MS


def test_wrap_ablations_empty_is_identity():
    c = controllers.get("aimd")
    assert controllers.wrap_ablations(c, "") is c
    with pytest.raises(ValueError, match="no_margin"):
        controllers.wrap_ablations(c, "bogus")


# ---------------------------------------------------------------------------
# Consensus reducers (SimConfig.consensus)
# ---------------------------------------------------------------------------


def test_consensus_reducers():
    views = jnp.asarray([[1.0, 10.0], [2.0, 20.0], [9.0, 90.0]])
    np.testing.assert_allclose(
        np.asarray(telemetry.reduce_views(views, "mean")), [4.0, 40.0])
    np.testing.assert_allclose(
        np.asarray(telemetry.reduce_views(views, "median")), [2.0, 20.0])
    np.testing.assert_allclose(
        np.asarray(telemetry.reduce_views(views, "max")), [9.0, 90.0])
    with pytest.raises(ValueError, match="median"):
        telemetry.reduce_views(views, "p95")
    # legacy single-arg shim still means "mean"
    np.testing.assert_allclose(
        np.asarray(ctl.consensus_view(views)), [4.0, 40.0])


def test_fleet_consensus_reducer_changes_control_not_routing_views():
    """median vs mean consensus feeds the one control loop different
    aggregates — knob trajectories may diverge, queue dynamics stay
    finite and the default (mean) is the golden-tested path."""
    wl = make_workload("bursty", T=200, m=8, seed=5, N=512)
    base = SimConfig(m=8, N=512, policy="midas", P=4,
                     middleware=("fleet_cache",), fleet_routing=True,
                     gossip_ms=100.0)
    res_mean = simulate(base, wl, do_warmup=False)
    res_med = simulate(dataclasses.replace(base, consensus="median"), wl,
                       do_warmup=False)
    for r in (res_mean, res_med):
        assert np.isfinite(r.queue_timeline).all()
        assert r.d_timeline.min() >= controllers.D_MIN
        assert r.d_timeline.max() <= controllers.D_MAX
    # same workload, same routing waves: arrivals totals agree
    assert res_mean.arrivals.sum() == pytest.approx(res_med.arrivals.sum())


# ---------------------------------------------------------------------------
# Summary-mode knob surfacing (satellite: E8/E9 control reporting)
# ---------------------------------------------------------------------------


def test_summary_mode_surfaces_knob_trajectories():
    from repro.core import simulate_sweep, summarize

    wl = make_workload("bursty", T=120, m=4, seed=0, N=256)
    cfg = SimConfig(m=4, N=256, policy="midas", middleware=("cache",))
    (fr,) = simulate_sweep(cfg, wl, do_warmup=False)["midas"]
    (sr,) = simulate_sweep(cfg, wl, do_warmup=False,
                           metrics="summary")["midas"]
    for f in ("d_timeline", "delta_l_timeline", "f_max_timeline",
              "pressure"):
        got = getattr(sr, f)
        assert got is not None and got.shape == (120,)
        np.testing.assert_array_equal(got, np.asarray(getattr(fr, f)),
                                      err_msg=f)
    # summarize() of the full row carries the same trajectories
    ref = summarize(fr)
    np.testing.assert_array_equal(ref.d_timeline, sr.d_timeline)
    np.testing.assert_array_equal(ref.pressure, sr.pressure)


def test_trajectory_stats_shapes_and_static_case():
    stats = controllers.trajectory_stats(
        np.full(100, 2.0), np.full(100, 4.0), np.full(100, 0.1),
        np.zeros(100), dt_ms=50.0)
    assert stats["oscillation_per_min"] == 0.0
    assert stats["settle_ms"] == 0.0
    assert stats["knob_churn"] == 0.0
    assert stats["settled"] == 1.0
    d = np.array([2, 3, 3, 3], np.float64)
    pr = np.array([0.0, 1.0, 1.0, 0.0])
    stats = controllers.trajectory_stats(
        d, np.full(4, 4.0), np.full(4, 0.1), pr, dt_ms=50.0)
    assert stats["oscillation_per_min"] > 0
