"""Routing-layer unit tests: margins, pinning, leaky bucket, baselines.

Policies now live in self-contained registered modules under
``repro.core.policies``; these tests exercise their functional kernels.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashring
from repro.core.policies import bounded_load as chbl
from repro.core.policies import midas as midas_mod
from repro.core.policies import power_of_d as pod_mod
from repro.core.policies import round_robin as rr_mod

M, N, W = 8, 64, 20


def _rs():
    return midas_mod.init_midas(N=N, w_ticks=W)


def _midas(rs, keys, L, *, d=2, delta_l=2.0, delta_t=0.0, f_max=1.0,
           now=0.0, p50=None, rng=0):
    keys = jnp.asarray(keys, jnp.int32)
    ring = hashring.make_ring(M, V=32)
    feas = hashring.feasible_set(ring, keys, 4)
    mask = jnp.ones(keys.shape, bool)
    p50 = L * 100.0 if p50 is None else p50
    return midas_mod.route_midas(
        rs, jax.random.PRNGKey(rng), keys, feas, jnp.asarray(L, jnp.float32),
        jnp.asarray(p50, jnp.float32), mask, jnp.asarray(d),
        jnp.asarray(delta_l), jnp.asarray(delta_t), jnp.asarray(f_max),
        jnp.asarray(now), 300.0, W) + (feas,)


def test_no_steering_when_balanced():
    """Equal loads never satisfy the Δ_L margin: everyone stays on primary."""
    L = jnp.ones((M,)) * 5.0
    rs, assign, stats, feas = _midas(_rs(), np.arange(32), L)
    np.testing.assert_array_equal(np.asarray(assign), np.asarray(feas[:, 0]))
    assert float(stats.steered) == 0


def test_steering_respects_both_margins():
    """Queue margin alone is not enough — the p50 margin must hold too."""
    L = jnp.asarray([50.0, 0, 0, 0, 0, 0, 0, 0])
    keys = np.arange(64)
    # p50 margin blocked: candidate p50 == primary p50
    rs, assign, stats, feas = _midas(_rs(), keys, L, d=4,
                                     p50=jnp.ones((M,)) * 10.0,
                                     delta_t=5.0)
    prim = np.asarray(feas[:, 0])
    assert float(stats.steered) == 0
    np.testing.assert_array_equal(np.asarray(assign), prim)
    # both margins open: requests with primary 0 steer away
    rs, assign, stats, feas = _midas(_rs(), keys, L, d=4, delta_t=0.0)
    prim = np.asarray(feas[:, 0])
    a = np.asarray(assign)
    hot = prim == 0
    if hot.any():
        assert (a[hot] != 0).any()
    # steered targets had >= Δ_L shorter queues => ΔV < 0 per paper
    moved = a != prim
    Lnp = np.asarray(L)
    assert all(Lnp[prim[i]] - Lnp[a[i]] >= 2.0 for i in np.where(moved)[0])


def test_leaky_bucket_exact_cap():
    L = jnp.asarray([50.0, 0, 0, 0, 0, 0, 0, 0])
    rs = _rs()
    total_steered, total_elig = 0.0, 0.0
    for t in range(30):
        rs, assign, stats, _ = _midas(rs, np.arange(64), L, d=4,
                                      f_max=0.1, now=t * 50.0, rng=t)
        total_steered += float(stats.steered)
        total_elig += float(stats.eligible)
    assert total_elig > 0
    assert total_steered <= 0.1 * total_elig + 1.0


def test_pin_honored_until_expiry():
    L = jnp.asarray([50.0, 0, 0, 0, 0, 0, 0, 0])
    rs = _rs()
    keys = np.arange(64)
    rs, assign1, stats, feas = _midas(rs, keys, L, d=4, now=0.0)
    a1 = np.asarray(assign1)
    prim = np.asarray(feas[:, 0])
    steered_keys = keys[a1 != prim]
    assert len(steered_keys) > 0
    # within pin window (C=300ms): same assignment even though loads flipped
    L_flipped = jnp.asarray([0.0, 50, 50, 50, 50, 50, 50, 50])
    rs2, assign2, _, _ = _midas(rs, steered_keys, L_flipped, d=4, now=100.0)
    np.testing.assert_array_equal(np.asarray(assign2), a1[a1 != prim])
    # after expiry the pin no longer applies (routes to primary: balanced L)
    rs3, assign3, _, feas3 = _midas(rs, steered_keys, jnp.ones((M,)),
                                    d=4, now=500.0)
    np.testing.assert_array_equal(np.asarray(assign3),
                                  np.asarray(feas3[:, 0]))


def test_round_robin_is_static_key_placement():
    keys = jnp.asarray([0, 1, 2, 9, 17], jnp.int32)
    mask = jnp.ones((5,), bool)
    a = np.asarray(rr_mod.route_round_robin(keys, mask, M))
    np.testing.assert_array_equal(a, [0, 1, 2, 1, 1])


def test_power_of_d_prefers_less_loaded():
    ring = hashring.make_ring(M, V=32)
    keys = jnp.arange(256, dtype=jnp.int32)
    feas = hashring.feasible_set(ring, keys, 4)
    L = jnp.asarray([100.0, 0, 100, 0, 100, 0, 100, 0])
    a = pod_mod.route_power_of_d(jax.random.PRNGKey(0), feas, L,
                                 jnp.ones((256,), bool), 4)
    loads_chosen = np.asarray(L)[np.asarray(a)]
    # with d=4 over distinct feasible sets, the heavy servers are avoidable
    # for almost all keys
    assert (loads_chosen == 0).mean() > 0.9


def test_bounded_load_stays_on_primary_under_cap():
    """CHBL is placement-stable: balanced loads never move a request."""
    ring = hashring.make_ring(M, V=32)
    keys = jnp.arange(128, dtype=jnp.int32)
    feas = hashring.feasible_set(ring, keys, 4)
    mask = jnp.ones((128,), bool)
    L = jnp.ones((M,)) * 3.0
    a = chbl.route_bounded_load(feas, L, mask)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(feas[:, 0]))


def test_bounded_load_spills_only_over_cap():
    """Requests whose primary exceeds c*(mean+1) walk to a successor that
    fits; everyone else stays put."""
    ring = hashring.make_ring(M, V=32)
    keys = jnp.arange(256, dtype=jnp.int32)
    feas = hashring.feasible_set(ring, keys, 4)
    mask = jnp.ones((256,), bool)
    L = jnp.asarray([100.0, 0, 0, 0, 0, 0, 0, 0])
    cap = chbl.C_LOAD * (float(jnp.mean(L)) + 1.0)
    a = np.asarray(chbl.route_bounded_load(feas, L, mask))
    prim = np.asarray(feas[:, 0])
    Lnp = np.asarray(L)
    over = Lnp[prim] > cap
    assert over.any()
    # spilled requests landed under the cap; others kept their primary
    assert (Lnp[a[over]] <= cap).all()
    np.testing.assert_array_equal(a[~over], prim[~over])
