"""Distribution-layer tests.

Device count is locked at first jax init, so multi-device tests run in
subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count set
before importing jax (the same pattern launch/dryrun.py uses).
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 16, timeout: int = 480) -> str:
    env = dict(os.environ,
               PYTHONPATH=SRC,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_mesh_shapes_and_axis_names():
    out = _run("""
        import jax
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert m1.axis_names == ("data", "model"), m1.axis_names
        assert m1.devices.shape == (16, 16)
        print("OK")
    """, devices=512)
    assert "OK" in out


def test_mini_dryrun_train_and_decode_compile():
    """Lower+compile a reduced arch on a 4x4 mesh: the full dry-run path
    (shardings, train step, serve step) in miniature."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.config import RunConfig, get_smoke_arch
        from repro import models
        from repro.sharding import rules as R
        from repro.train.step import make_train_step, train_state_shapes
        from repro.serve.step import make_serve_step

        import repro.config as C
        cfg = get_smoke_arch("qwen3-moe-235b-a22b")   # MoE: hardest path
        run = RunConfig(arch=cfg.name)
        mesh = jax.make_mesh((4, 4), ("data", "model"))
        rules = R.make_rules("train", mesh)

        def named(t):
            return jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), t,
                is_leaf=lambda x: isinstance(x, P))

        with mesh, R.use_rules(rules):
            step = make_train_step(cfg, run)
            ss = train_state_shapes(cfg, run)
            from repro.launch import specs as S
            state_spec = S.train_state_pspec(cfg, run, rules, ss)
            batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
            bspec = {"tokens": rules.spec("batch", "seq", shape=(8, 32))}
            lowered = jax.jit(step, in_shardings=(named(state_spec),
                                                  named(bspec)),
                              out_shardings=(named(state_spec), None)
                              ).lower(ss, batch)
            compiled = lowered.compile()
            ca = compiled.cost_analysis()
            # jax < 0.5 returns a per-computation list; newer, one dict
            ca = ca[0] if isinstance(ca, list) else ca
            assert ca.get("flops", 0) > 0
            print("TRAIN_OK")

        srules = R.make_rules("serve", mesh)
        with mesh, R.use_rules(srules):
            sstep = make_serve_step(cfg, run)
            params = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
                models.param_shapes(cfg))
            pspec = S.params_pspec(cfg, srules)
            cache = models.init_decode_cache(cfg, 8, 64, jnp.bfloat16,
                                             mode="shape")
            cspec = jax.tree_util.tree_map(
                lambda a, s: srules.spec(*a, shape=s.shape),
                models.cache_logical_axes(cfg), cache,
                is_leaf=lambda x: isinstance(x, tuple))
            toks = jax.ShapeDtypeStruct((8, 1), jnp.int32)
            pos = jax.ShapeDtypeStruct((8,), jnp.int32)
            lo = jax.jit(sstep,
                         in_shardings=(named(pspec), named(cspec),
                                       NamedSharding(mesh, P()),
                                       NamedSharding(mesh, P())),
                         out_shardings=(NamedSharding(mesh, P()),
                                        named(cspec))
                         ).lower(params, cache, toks, pos)
            lo.compile()
            print("DECODE_OK")
    """)
    assert "TRAIN_OK" in out and "DECODE_OK" in out


def test_shardmap_moe_matches_einsum_oracle():
    """The shard_map dispatch (hc1a/hc3b §Perf paths) must equal the
    einsum oracle: forward, telemetry, and gradients."""
    out = _run("""
        import os, dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import get_smoke_arch
        from repro.models import moe as moe_lib
        from repro.models.layers import Maker
        from repro.sharding import rules as R

        for arch, ruleset, shape in (
                ("qwen3-moe-235b-a22b", "train", (8, 16)),
                ("dbrx-132b", "serve_decode_moe", (4, 1))):
            cfg = get_smoke_arch(arch)
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=32.0))
            mesh = jax.make_mesh((4, 4), ("data", "model"))
            key = jax.random.PRNGKey(0)
            p = moe_lib.moe_init(Maker(key, jnp.float32), cfg)
            x = jax.random.normal(jax.random.fold_in(key, 1),
                                  shape + (cfg.d_model,)) * 0.1
            load = jnp.ones((cfg.moe.num_experts,))
            rules = R.make_rules(ruleset, mesh)
            with mesh, R.use_rules(rules):
                y_s, aux_s = jax.jit(lambda p, x: moe_lib.moe_apply_sharded(
                    p, cfg, x, load))(p, x)
                def loss_s(p):
                    y, _ = moe_lib.moe_apply_sharded(p, cfg, x, load)
                    return (y.astype(jnp.float32) ** 2).mean()
                gs = jax.jit(jax.grad(loss_s))(p)
            os.environ["REPRO_MOE_EINSUM"] = "1"
            y_e, aux_e = jax.jit(lambda p, x: moe_lib.moe_apply(
                p, cfg, x, load))(p, x)
            def loss_e(p):
                y, _ = moe_lib.moe_apply(p, cfg, x, load)
                return (y.astype(jnp.float32) ** 2).mean()
            ge = jax.jit(jax.grad(loss_e))(p)
            del os.environ["REPRO_MOE_EINSUM"]
            assert np.allclose(np.asarray(y_s), np.asarray(y_e),
                               atol=3e-5), arch
            assert np.allclose(np.asarray(aux_s.load),
                               np.asarray(aux_e.load), atol=1e-5), arch
            for a, b in zip(jax.tree.leaves(gs), jax.tree.leaves(ge)):
                assert np.allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5), arch
            print(f"{arch}_OK")
    """)
    assert "qwen3-moe-235b-a22b_OK" in out and "dbrx-132b_OK" in out


def test_divisibility_fallback_rules():
    out = _run("""
        import jax
        from repro.sharding import rules as R
        mesh = jax.make_mesh((4, 4), ("data", "model"))
        r = R.make_rules("train", mesh)
        # 15 heads don't divide model=4 -> dropped; 16 do -> kept
        assert r.spec("embed", "heads", shape=(64, 15))[1] is None
        assert r.spec("embed", "heads", shape=(64, 16))[1] == "model"
        # fsdp tuple prefix fallback
        s = r.spec("embed_fsdp", shape=(8,))
        print("OK", s)
    """)
    assert "OK" in out


def test_dryrun_artifacts_complete_and_coherent():
    """The committed dry-run artifacts must cover every applicable cell on
    both meshes with sane roofline terms."""
    art = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    if not art.exists():
        pytest.skip("dry-run artifacts not generated")
    from repro.config import applicable_cells  # noqa: E402  (1-dev import ok)
    for arch, shape in applicable_cells():
        for pods in (1, 2):
            f = art / f"{arch}__{shape}__pod{pods}.json"
            assert f.exists(), f"missing dry-run artifact {f.name}"
            d = json.loads(f.read_text())
            assert d["flops_per_device"] > 0, f.name
            rf = d["roofline"]
            assert rf["dominant"] in ("compute_s", "memory_s",
                                      "collective_s")
            assert 0 < rf["useful_flops_ratio"] < 2.0, (f.name, rf)
