"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one decode step on CPU, asserting shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.config import get_arch, get_smoke_arch, list_archs

B, S = 2, 32


def _batch(cfg, key):
    k1, k2 = jax.random.split(key)
    if cfg.frontend == "audio_frames":
        return {
            "frames": jax.random.normal(k1, (B, S, cfg.d_model),
                                        jnp.float32) * 0.02,
            "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
        }
    if cfg.frontend == "vlm_patches":
        P = cfg.frontend_tokens
        return {
            "tokens": jax.random.randint(k1, (B, S - P), 0, cfg.vocab_size),
            "patches": jax.random.normal(k2, (B, P, cfg.d_model),
                                         jnp.float32) * 0.02,
        }
    return {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_loss(arch):
    cfg = get_smoke_arch(arch)
    key = jax.random.PRNGKey(0)
    params = models.init_params(cfg, key)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, moe_state, aux = models.forward(params, cfg, batch,
                                            models.init_moe_state(cfg))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, (new_state, metrics) = models.loss_fn(
        params, cfg, batch, models.init_moe_state(cfg))
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    # MoE archs must emit telemetry + drop metrics
    if cfg.moe is not None:
        assert new_state, "moe state missing"
        for v in new_state.values():
            assert np.isfinite(np.asarray(v)).all()
        assert "moe_drop_rate" in metrics


@pytest.mark.parametrize("arch", list_archs())
def test_grad_step(arch):
    cfg = get_smoke_arch(arch)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    def loss(p):
        l, _ = models.loss_fn(p, cfg, batch, models.init_moe_state(cfg))
        return l

    grads = jax.grad(loss)(params)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # at least some gradient signal everywhere except unused frontends
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in flat)
    assert gnorm > 0


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_prefill(arch):
    """Prefill logits at position t must match step-by-step decode."""
    import dataclasses
    cfg = get_smoke_arch(arch)
    if cfg.frontend == "audio_frames":
        pytest.skip("audio stub trains on frames; decode covered by "
                    "token-embedding path in other archs")
    if cfg.moe is not None:
        # capacity drops depend on batch size; use dropless capacity so the
        # prefill and decode paths are numerically comparable
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    S_test = 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S_test), 0,
                              cfg.vocab_size)
    batch = ({"tokens": toks} if cfg.frontend != "vlm_patches" else
             {"tokens": toks,
              "patches": jnp.zeros((B, cfg.frontend_tokens, cfg.d_model))})
    full_logits, _, _ = models.forward(params, cfg, batch,
                                       models.init_moe_state(cfg))
    if cfg.frontend == "vlm_patches":
        pytest.skip("vlm decode tested via text-only path in dense archs")

    cache = models.init_decode_cache(cfg, B, 16, dtype=jnp.float32)
    outs = []
    for t in range(S_test):
        pos = jnp.full((B,), t, jnp.int32)
        lg, cache = models.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                       pos)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_full_configs_param_counts():
    """Full (non-smoke) configs instantiate abstractly with expected sizes."""
    expected = {
        "dbrx-132b": 131.6e9, "qwen3-moe-235b-a22b": 235.1e9,
        "falcon-mamba-7b": 7.27e9, "smollm-360m": 0.36e9,
    }
    for arch, n in expected.items():
        cfg = get_arch(arch)
        shapes = models.param_shapes(cfg, jnp.bfloat16)
        total = sum(int(np.prod(s.shape))
                    for s in jax.tree_util.tree_leaves(shapes))
        assert abs(total - n) / n < 0.02, (arch, total, n)


def test_logical_axes_align_with_shapes():
    """Axes trees and shape trees must be structurally identical with
    matching ranks — guards spec/param drift."""
    for arch in list_archs():
        cfg = get_arch(arch)
        shapes = models.param_shapes(cfg)
        axes = models.param_logical_axes(cfg)
        jax.tree_util.tree_map(
            lambda s, a: None if len(s.shape) == len(a)
            else pytest.fail(f"{arch}: {s.shape} vs {a}"),
            shapes, axes, is_leaf=lambda x: isinstance(x, tuple))
