"""Fault registry, schedule compiler, and the faulted engine.

The central contracts:

* ZERO-COST-WHEN-OFF — ``faults=None``, ``faults=()``, and a benign
  never-firing event all reproduce the PR 5 golden engine bit-for-bit.
* Ground truth vs detection — a crashed server stops serving instantly
  but stays in the routed ring until the heartbeat timeout expires.
* Remap invalidation — after an epoch flip, no proxy (shared cache or
  fleet, any P) serves an owner-changed entry without revalidation.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FaultEvent, SimConfig, make_workload, simulate
from repro.core import cache as cache_lib
from repro.core import controllers as ctrl_lib
from repro.core import faults
from repro.core import fleet as fleet_lib

WL = make_workload("bursty", T=160, m=8, seed=3, N=512)
GOLDEN = "tests/data/control_golden.npz"


def _cfg(**kw):
    kw.setdefault("m", 8)
    kw.setdefault("N", 512)
    kw.setdefault("policy", "midas")
    return SimConfig(**kw)


# ---------------------------------------------------------------------------
# Registry + validation
# ---------------------------------------------------------------------------


def test_registry_lists_builtin_kinds():
    for kind in ("proxy_crash", "proxy_join", "server_brownout",
                 "gossip_partition", "ckpt_storm_fleet"):
        assert kind in faults.available()


def test_unknown_kind_lists_alternatives():
    with pytest.raises(ValueError, match="proxy_crash"):
        faults.get_class("power_cut")
    with pytest.raises(ValueError, match="available"):
        _cfg(faults=("power_cut",))


def test_config_validation_errors():
    with pytest.raises(ValueError, match="tuple"):
        _cfg(faults="proxy_crash")  # a bare string is a bug, not a list
    with pytest.raises(ValueError, match="target"):
        _cfg(faults=(FaultEvent("proxy_crash", target=8),))
    with pytest.raises(ValueError, match="magnitude"):
        _cfg(faults=(FaultEvent("server_brownout", magnitude=0.0),))
    with pytest.raises(ValueError, match="proxy"):
        _cfg(faults=(FaultEvent("gossip_partition", target=99),))
    with pytest.raises(ValueError, match="t0"):
        _cfg(faults=(FaultEvent("proxy_crash", t0=-5),))


def test_names_normalize_to_default_events():
    cfg = _cfg(faults=("server_brownout",))
    assert cfg.faults == (FaultEvent("server_brownout"),)
    assert cfg.fault_events == cfg.faults
    assert _cfg().fault_events == ()


def test_parse_fault_cli_specs():
    ev = faults.parse_fault("proxy_crash:t0=200,duration=300,target=2")
    assert ev == FaultEvent("proxy_crash", t0=200, duration=300, target=2)
    ev = faults.parse_fault("ckpt_storm_fleet:magnitude=0.25")
    assert ev.magnitude == 0.25
    with pytest.raises(ValueError, match="available"):
        faults.parse_fault("nope:t0=1")
    with pytest.raises(ValueError, match="parameter"):
        faults.parse_fault("proxy_crash:frequency=3")


def test_all_dead_schedule_rejected():
    cfg = _cfg(m=2, faults=(
        FaultEvent("proxy_crash", t0=10, duration=50, target=0),
        FaultEvent("proxy_crash", t0=10, duration=50, target=1),
    ))
    with pytest.raises(ValueError, match="live"):
        simulate(cfg, WL, do_warmup=False)


# ---------------------------------------------------------------------------
# Compiler: detection, epochs, flags
# ---------------------------------------------------------------------------


def test_compile_none_for_empty():
    assert faults.compile_faults(_cfg(), 160) is None
    assert faults.compile_faults(_cfg(faults=()), 160) is None


def test_detection_lags_ground_truth():
    cfg = _cfg(faults=(FaultEvent("proxy_crash", t0=40, duration=60,
                                  target=0),))
    fc = faults.compile_faults(cfg, 160)
    K = fc.timeout_ticks
    assert K == faults.detect_ticks(cfg.dt_ms) == 10  # 500ms / 50ms
    assert not fc.member[40:100, 0].any()
    # presumed alive through the timeout, detected dead after it
    assert fc.detected[40:40 + K, 0].all()
    assert not fc.detected[40 + K:100, 0].any()
    # rejoin heartbeat makes re-detection immediate
    assert fc.detected[100:, 0].all()
    assert fc.has_downtime and fc.has_remap
    assert not (fc.has_brownout or fc.has_partition or fc.has_storm)
    # three epochs: all-live, server0-out, all-live again
    assert fc.epoch_masks.shape[0] == 3
    assert fc.owner_by_epoch is not None
    # epoch flips only where detection changed
    flip = fc.epoch != fc.epoch_prev
    assert flip.sum() == 2 and flip[50] and flip[100]


def test_benign_flags_all_off():
    cfg = _cfg(faults=(FaultEvent("server_brownout", t0=40, duration=60,
                                  target=1, magnitude=1.0),))
    fc = faults.compile_faults(cfg, 160)
    assert fc is not None
    assert not (fc.has_downtime or fc.has_remap or fc.has_brownout
                or fc.has_partition or fc.has_storm)


# ---------------------------------------------------------------------------
# Golden parity: the zero-fault engine is untouched
# ---------------------------------------------------------------------------


def test_zero_fault_paths_reproduce_golden():
    g = np.load(GOLDEN)
    want = g["midas_cache/queue_timeline"]
    for fa in (None, ()):
        cfg = _cfg(middleware=("cache",), faults=fa)
        r = simulate(cfg, WL, do_warmup=False)
        np.testing.assert_array_equal(r.queue_timeline, want)
        np.testing.assert_array_equal(r.d_timeline,
                                      g["midas_cache/d_timeline"])


def test_benign_event_value_equal_to_golden():
    """A never-firing event (brownout at magnitude 1.0) keeps every
    has_* flag off: the engine takes value-identical paths."""
    g = np.load(GOLDEN)
    cfg = _cfg(middleware=("cache",),
               faults=(FaultEvent("server_brownout", t0=40, duration=60,
                                  target=1, magnitude=1.0),))
    r = simulate(cfg, WL, do_warmup=False)
    np.testing.assert_array_equal(r.queue_timeline,
                                  g["midas_cache/queue_timeline"])
    np.testing.assert_array_equal(r.cache_hits,
                                  g["midas_cache/cache_hits"])


# ---------------------------------------------------------------------------
# Faulted engine behaviour
# ---------------------------------------------------------------------------

CRASH = (FaultEvent("proxy_crash", t0=40, duration=60, target=0),)


def test_crash_freezes_dead_server_and_recovers():
    cfg = _cfg(middleware=("cache",), faults=CRASH)
    r = simulate(cfg, WL, do_warmup=False)
    fc = faults.compile_faults(cfg, 160)
    q0 = r.queue_timeline[:, 0]
    K = fc.timeout_ticks
    # once detection lands, no new arrivals reach the dead server and
    # nothing drains: its queue is exactly frozen until rejoin
    frozen = q0[40 + K:100]
    assert (frozen == frozen[0]).all()
    assert (r.arrivals[40 + K:100, 0] == 0).all()
    # it serves again after rejoin and eventually drains
    assert r.arrivals[100:, 0].sum() > 0


def test_crash_scan_unroll_parity():
    cfg = _cfg(middleware=("cache",), faults=CRASH)
    r = simulate(cfg, WL, do_warmup=False)
    ru = simulate(dataclasses.replace(cfg, unroll_waves=True), WL,
                  do_warmup=False)
    np.testing.assert_array_equal(r.queue_timeline, ru.queue_timeline)
    np.testing.assert_array_equal(r.arrivals, ru.arrivals)
    np.testing.assert_array_equal(r.cache_hits, ru.cache_hits)


def test_brownout_slows_target_drain():
    cfg = _cfg(faults=(FaultEvent("server_brownout", t0=40, duration=80,
                                  target=1, magnitude=0.25),))
    r = simulate(cfg, WL, do_warmup=False)
    base = simulate(_cfg(), WL, do_warmup=False)
    win = slice(45, 120)
    assert (r.queue_timeline[win, 1].mean()
            > base.queue_timeline[win, 1].mean())


def test_storm_adds_write_arrivals():
    cfg = _cfg(middleware=("cache",),
               faults=(FaultEvent("ckpt_storm_fleet", t0=40, duration=40,
                                  magnitude=0.5),))
    r = simulate(cfg, WL, do_warmup=False)
    base = simulate(_cfg(middleware=("cache",)), WL, do_warmup=False)
    storm_win = r.arrivals[40:80].sum()
    assert storm_win > base.arrivals[40:80].sum()
    # outside the window the workload is untouched
    np.testing.assert_array_equal(r.arrivals[:40], base.arrivals[:40])


def test_partition_spikes_fleet_staleness():
    base = _cfg(middleware=("fleet_cache",), P=4, gossip_ms=100.0)
    cfg = dataclasses.replace(
        base,
        faults=(FaultEvent("gossip_partition", t0=20, duration=120,
                           target=1),),
    )
    r = simulate(cfg, WL, do_warmup=False)
    rb = simulate(base, WL, do_warmup=False)
    stale = np.asarray(r.final_cache.stale_p)
    stale_b = np.asarray(rb.final_cache.stale_p)
    # the partitioned proxy serves from an ever-staler snapshot
    assert stale[1] >= stale_b[1]
    assert stale.sum() >= stale_b.sum()


# ---------------------------------------------------------------------------
# Remap invalidation: the no-stale-owner property
# ---------------------------------------------------------------------------


def test_remap_invalidate_shared_cache():
    N = 64
    c = cache_lib.init_cache(N)
    c = c._replace(
        expiry_ms=jnp.full((N,), 1e9, jnp.float32),
        cached_version=jnp.zeros((N,), jnp.int32),
    )
    moved = jnp.arange(N) % 3 == 0
    c = cache_lib.remap_invalidate(c, moved)
    keys = jnp.arange(N, dtype=jnp.int32)
    ones = jnp.ones((N,), bool)
    _, hit = cache_lib.lookup_batch(
        c, keys, ones, ~ones, jnp.asarray(50.0)
    )
    hit = np.asarray(hit)
    assert not hit[np.asarray(moved)].any()
    assert hit[~np.asarray(moved)].all()


@pytest.mark.parametrize("P", [1, 2, 8])
def test_remap_invalidate_fleet_property(P):
    """No proxy — whatever lagged snapshot its gossip view selects —
    serves an owner-changed entry without revalidation."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    N, D = 32, 4

    @given(
        moved_bits=st.lists(st.booleans(), min_size=N, max_size=N),
        tick=st.integers(0, 10),
    )
    @settings(max_examples=20, deadline=None)
    def prop(moved_bits, tick):
        fs = fleet_lib.init_fleet(N, P, D)
        # every view (converged + all snapshots) holds live entries
        fs = fs._replace(
            shared=fs.shared._replace(
                expiry_ms=jnp.full((N,), 1e9, jnp.float32),
                cached_version=jnp.zeros((N,), jnp.int32),
            ),
            lag_expiry=jnp.full((D, N), 1e9, jnp.float32),
            tick=jnp.asarray(tick, jnp.int32),
        )
        moved = jnp.asarray(moved_bits)
        fs = fleet_lib.remap_invalidate(fs, moved)
        keys = jnp.arange(N, dtype=jnp.int32)
        ones = jnp.ones((N,), bool)
        proxy = fleet_lib.proxy_assign(N, P, fs.tick)
        _, hit = fleet_lib.lookup_fleet(
            fs, keys, ones, ~ones, proxy, jnp.asarray(50.0),
            gossip_ms=100.0,
        )
        hit = np.asarray(hit)
        assert not hit[np.asarray(moved)].any()
        assert hit[~np.asarray(moved)].all()

    prop()


def test_faulted_run_serves_no_moved_entry():
    """End-to-end: with a crash mid-run, replaying each epoch's owner
    table shows cache hits never happen on a tick where the serving
    ring's owner differs from the installing ring's owner without a
    fresh install (spot check via total-hit accounting: hits under
    fault <= hits without fault, since invalidation only removes)."""
    cfg = _cfg(middleware=("cache",), faults=CRASH)
    base = _cfg(middleware=("cache",))
    r = simulate(cfg, WL, do_warmup=False)
    rb = simulate(base, WL, do_warmup=False)
    assert r.cache_hits.sum() <= rb.cache_hits.sum()


# ---------------------------------------------------------------------------
# Availability plumbing: install guard, Signals, controller reaction
# ---------------------------------------------------------------------------


def test_install_guard_under_degraded_avail():
    N = 16
    c = cache_lib.init_cache(N)
    keys = jnp.arange(N, dtype=jnp.int32)
    ones = jnp.ones((N,), bool)
    degraded = jnp.asarray(0.875, jnp.float32)
    c2, _ = cache_lib.lookup_batch(
        c, keys, ones, ~ones, jnp.asarray(10.0), avail=degraded
    )
    assert int(c2.bypasses) == N  # nothing installed while degraded
    assert (np.asarray(c2.expiry_ms) == 0.0).all()
    c3, _ = cache_lib.lookup_batch(
        c, keys, ones, ~ones, jnp.asarray(10.0),
        avail=jnp.asarray(1.0, jnp.float32),
    )
    assert int(c3.bypasses) == 0  # full availability: installs proceed


def test_hysteresis_reacts_to_degraded_avail():
    cfg = _cfg()
    ctrl = ctrl_lib.get("hysteresis")
    st0 = ctrl.init(cfg, (0.15, 500.0))
    calm = ctrl_lib.make_signals(B=0.0, p99=0.0, rtt_ms=cfg.rtt_ms)
    # calm signals, full availability: no escalation
    st1, _ = ctrl.fast(st0, calm)
    assert int(st1.knobs.d) == int(st0.knobs.d)
    # calm signals, degraded availability: escalate immediately
    st2, _ = ctrl.fast(st0, calm._replace(avail=jnp.asarray(0.875)))
    assert int(st2.knobs.d) == int(st0.knobs.d) + 1


def test_no_fault_signal_ablation_blinds_controller():
    cfg = _cfg()
    ctrl = ctrl_lib.wrap_ablations(
        ctrl_lib.get("hysteresis"), "no_fault_signal"
    )
    st0 = ctrl.init(cfg, (0.15, 500.0))
    degraded = ctrl_lib.make_signals(
        B=0.0, p99=0.0, rtt_ms=cfg.rtt_ms, avail=0.875
    )
    st1, _ = ctrl.fast(st0, degraded)
    assert int(st1.knobs.d) == int(st0.knobs.d)  # flies blind


def test_unknown_ablation_still_rejected():
    with pytest.raises(ValueError, match="no_fault_signal"):
        ctrl_lib.parse_ablations("no_cache")


# ---------------------------------------------------------------------------
# Compound fault programs: overlap, sequence, cascade (PR 9)
# ---------------------------------------------------------------------------


def _compiled(events, T=160, **kw):
    return faults.compile_faults(_cfg(faults=tuple(events), **kw), T)


def test_overlap_requires_intersecting_windows():
    a = FaultEvent("proxy_crash", t0=20, duration=30, target=0)
    b = FaultEvent("ckpt_storm_fleet", t0=40, duration=40, magnitude=0.5)
    assert faults.overlap(a, b) == (a, b)
    c = FaultEvent("server_brownout", t0=100, duration=20, target=1,
                   magnitude=0.5)
    with pytest.raises(ValueError, match="sequence"):
        faults.overlap(a, c)


def test_program_schedule_is_elementwise_composition():
    """A compound program's compiled schedule equals the element-wise
    composition of its single-event schedules: membership ANDs, service
    scales multiply, partitions OR, storm intensities max, active ORs —
    the monotonic-apply property programs.py documents."""
    events = faults.overlap(
        FaultEvent("ckpt_storm_fleet", t0=30, duration=60, magnitude=0.5),
        FaultEvent("proxy_crash", t0=40, duration=40, target=0),
        FaultEvent("server_brownout", t0=35, duration=50, target=2,
                   magnitude=0.3),
        FaultEvent("gossip_partition", t0=30, duration=30, target=0),
    )
    prog = _compiled(events, P=4)
    singles = [_compiled((e,), P=4) for e in events]
    np.testing.assert_array_equal(
        prog.member, np.logical_and.reduce([s.member for s in singles]))
    np.testing.assert_allclose(
        prog.service_scale,
        np.prod([s.service_scale for s in singles], axis=0), rtol=1e-6)
    np.testing.assert_array_equal(
        prog.partition,
        np.logical_or.reduce([s.partition for s in singles]))
    np.testing.assert_allclose(
        prog.storm, np.max([s.storm for s in singles], axis=0))
    np.testing.assert_array_equal(
        prog.active, np.logical_or.reduce([s.active for s in singles]))


def test_sequence_retimes_and_composes():
    events = faults.rolling(
        "server_brownout", targets=(1, 2, 3), t0=20, duration=30,
        stagger=25, magnitude=0.3)
    assert [e.t0 for e in events] == [20, 45, 70]
    assert [e.target for e in events] == [1, 2, 3]
    prog = _compiled(events)
    singles = [_compiled((e,)) for e in events]
    np.testing.assert_allclose(
        prog.service_scale,
        np.prod([s.service_scale for s in singles], axis=0), rtol=1e-6)
    assert prog.has_brownout
    with pytest.raises(ValueError, match="stagger"):
        faults.sequence(events[0], t0=0, stagger=-1)


def test_cascade_fires_at_detection_time():
    """The cascade effect's resolved t0 is the trigger's *detection*
    tick (crash + heartbeat timeout) plus the offset — never earlier."""
    trig = FaultEvent("proxy_crash", t0=40, duration=60, target=0)
    casc = faults.CascadeEvent(
        trigger=trig,
        effect=FaultEvent("gossip_partition", t0=0, duration=30,
                          target=0),
        offset=5)
    cfg = _cfg(P=4, faults=(casc,))
    assert cfg.faults == (casc,)  # rides SimConfig next to plain events
    det = faults.detection_tick(trig, dt_ms=cfg.dt_ms, T=160, m=8, P=4)
    assert det == 40 + faults.detect_ticks(cfg.dt_ms)
    resolved = faults.resolve(
        (casc,), dt_ms=cfg.dt_ms, T=160, m=8, P=4)
    assert resolved[0] == trig
    assert resolved[1].t0 == det + 5
    assert resolved[1].t0 >= det
    fc = faults.compile_faults(cfg, 160)
    assert fc.partition[det + 5:det + 35, 0].all()
    assert not fc.partition[:det + 5].any()


def test_cascade_benign_trigger_detected_at_first_active_tick():
    trig = FaultEvent("server_brownout", t0=25, duration=40, target=1,
                      magnitude=0.3)
    assert faults.detection_tick(
        trig, dt_ms=50.0, T=160, m=8, P=1) == 25


def test_zero_length_program_reproduces_golden():
    """``sequence()`` is ``()`` — and both reproduce the golden engine
    bit-for-bit (zero-cost-when-off extends to empty programs)."""
    g = np.load(GOLDEN)
    assert faults.sequence() == ()
    cfg = _cfg(middleware=("cache",), faults=faults.sequence())
    r = simulate(cfg, WL, do_warmup=False)
    np.testing.assert_array_equal(r.queue_timeline,
                                  g["midas_cache/queue_timeline"])
    np.testing.assert_array_equal(r.d_timeline,
                                  g["midas_cache/d_timeline"])


def test_storm_from_pool_calibration():
    class _Pool:
        def backlogs(self):
            return [0, 30, 10, 0]

    ev = faults.storm_from_pool(_Pool(), t0=5, duration=9)
    assert ev.kind == "ckpt_storm_fleet"
    assert ev.t0 == 5 and ev.duration == 9
    assert ev.magnitude == pytest.approx(0.75)
