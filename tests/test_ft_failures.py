"""FailureDetector unit tests (injected clocks) and the detection-parity
contract: the host-side detector and the fault compiler's in-sim
``detect_available`` implement the SAME windowed-heartbeat rule."""
import numpy as np
import pytest

from repro.core.faults import detect_available
from repro.ft.failures import FailureDetector, elastic_plan


def test_all_alive_at_init():
    det = FailureDetector(4, timeout_s=10.0, now=0.0)
    assert det.failed(now=10.0) == set()
    assert det.failed(now=10.001) == {0, 1, 2, 3}


def test_heartbeat_resets_timeout():
    det = FailureDetector(3, timeout_s=5.0, now=0.0)
    det.heartbeat(1, now=7.0)
    assert det.failed(now=9.0) == {0, 2}
    # host 1's clock restarted at 7.0
    assert det.failed(now=12.0) == {0, 2}
    assert det.failed(now=12.5) == {0, 1, 2}


def test_failed_is_strict_inequality():
    det = FailureDetector(1, timeout_s=5.0, now=0.0)
    assert det.failed(now=5.0) == set()  # exactly at timeout: alive
    assert det.failed(now=5.0 + 1e-9) == {0}


def test_straggler_scoring():
    det = FailureDetector(4, timeout_s=10.0, now=0.0)
    for h in range(3):
        det.heartbeat(h, step_time_s=1.0, now=1.0)
    det.heartbeat(3, step_time_s=10.0, now=1.0)
    assert det.stragglers() == {3}
    # fewer than two reporters: no verdict
    det2 = FailureDetector(4, timeout_s=10.0, now=0.0)
    det2.heartbeat(0, step_time_s=9.0, now=1.0)
    assert det2.stragglers() == set()


def test_straggler_ewma_recovers():
    det = FailureDetector(2, straggler_factor=1.5, alpha=0.5, now=0.0)
    det.heartbeat(0, step_time_s=1.0, now=1.0)
    det.heartbeat(1, step_time_s=8.0, now=1.0)
    assert det.stragglers() == {1}
    for t in range(2, 12):
        det.heartbeat(0, step_time_s=1.0, now=float(t))
        det.heartbeat(1, step_time_s=1.0, now=float(t))
    assert det.stragglers() == set()


def test_elastic_plan_shapes():
    assert elastic_plan(8, set(), min_hosts=1)["action"] == "abort"
    p = elastic_plan(8, set(range(8)))
    assert p["action"] == "resume" and p["new_dp"] == 8
    p = elastic_plan(8, {0, 1, 2, 3, 4, 6})
    assert p["action"] == "reshard"
    assert p["new_dp"] == 4 and p["dropped"] == [5, 7]


# ---------------------------------------------------------------------------
# Parity: FailureDetector == faults.detect_available on a tick grid
# ---------------------------------------------------------------------------


def _detector_grid(member: np.ndarray, K: int) -> np.ndarray:
    """Replay a (T, m) ground-truth membership grid through the host
    detector: tick j maps to time j (dt = 1 s), ``timeout_s = K``, and
    the initial presumed-alive heartbeat lands at time -1 — the same
    virtual-alive padding ``detect_available`` applies before t = 0."""
    T, m = member.shape
    det = FailureDetector(m, timeout_s=float(K), now=-1.0)
    out = np.zeros((T, m), bool)
    for t in range(T):
        for h in range(m):
            if member[t, h]:
                det.heartbeat(h, now=float(t))
        dead = det.failed(now=float(t))
        out[t] = [h not in dead for h in range(m)]
    return out


@pytest.mark.parametrize("K", [1, 3, 10])
def test_detector_matches_in_sim_reference(K):
    rng = np.random.default_rng(7)
    member = rng.random((60, 5)) > 0.3
    got = _detector_grid(member, K)
    want = detect_available(member, K)
    np.testing.assert_array_equal(got, want)


def test_detector_parity_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(
        data=st.data(),
        T=st.integers(1, 40),
        m=st.integers(1, 6),
        K=st.integers(1, 8),
    )
    @settings(max_examples=30, deadline=None)
    def prop(data, T, m, K):
        bits = data.draw(
            st.lists(
                st.booleans(), min_size=T * m, max_size=T * m
            )
        )
        member = np.asarray(bits, bool).reshape(T, m)
        np.testing.assert_array_equal(
            _detector_grid(member, K), detect_available(member, K)
        )

    prop()
