"""Serving-path integration: prefill cache handoff -> decode continuation
must match the full-sequence forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.config import get_smoke_arch

ARCHS = ["smollm-360m", "jamba-v0.1-52b", "falcon-mamba-7b", "gemma2-2b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_smoke_arch(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    B, P, D = 2, 6, 4                    # prompt 6 tokens, decode 4 more
    S = P + D
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)

    ref_logits, _, _ = models.forward(params, cfg, {"tokens": toks[:, :S]},
                                      models.init_moe_state(cfg))

    # prefill the prompt, collect the decode-ready cache
    logits_p, cache = models.prefill(params, cfg,
                                     {"tokens": toks[:, :P]},
                                     cache_len=S, cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0], np.float32),
                               np.asarray(ref_logits[:, P - 1], np.float32),
                               rtol=2e-2, atol=2e-2)

    # continue token-by-token from the prefilled cache
    for t in range(P, S):
        pos = jnp.full((B,), t, jnp.int32)
        lg, cache = models.decode_step(params, cfg, cache,
                                       toks[:, t:t + 1], pos)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(ref_logits[:, t], np.float32),
            rtol=2e-2, atol=2e-2, err_msg=f"{arch} pos {t}")
