"""Policy registry + pipeline API: registration, dispatch, sweeps."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SimConfig, make_workload, simulate, simulate_sweep
from repro.core import policies
from repro.core import sim as sim_lib

BUILTINS = ("chbl", "hash", "jsq", "midas", "power_of_d", "round_robin",
            "rr_request", "uniform")


def test_builtins_registered():
    names = policies.available()
    for n in BUILTINS:
        assert n in names


def test_unknown_policy_error_lists_available_names():
    wl = make_workload("bursty", T=8, m=4, seed=0)
    with pytest.raises(ValueError) as ei:
        simulate(SimConfig(m=4, policy="no_such_policy"), wl,
                 do_warmup=False)
    msg = str(ei.value)
    assert "no_such_policy" in msg
    for n in policies.available():
        assert n in msg


@pytest.mark.parametrize("name", BUILTINS)
def test_every_policy_simulates_bursty_without_nans(name):
    wl = make_workload("bursty", T=40, m=4, seed=3)
    res = simulate(SimConfig(m=4, N=256, policy=name), wl, do_warmup=False)
    assert np.isfinite(res.queue_timeline).all()
    assert (res.queue_timeline >= 0).all()
    assert np.isfinite(res.lat_pred).all()
    # everything that arrived was routed somewhere valid
    assert res.arrivals.sum() == np.asarray(wl.mask).sum()


def test_third_party_policy_registers_and_runs():
    @policies.register("_test_all_to_zero")
    class AllToZero(policies.Policy):
        def route(self, state, ctx):
            assign = jnp.where(ctx.mask, 0, -1).astype(jnp.int32)
            return state, assign, policies.RouteStats.zeros()

    try:
        wl = make_workload("bursty", T=20, m=4, seed=0)
        res = simulate(SimConfig(m=4, policy="_test_all_to_zero"), wl,
                       do_warmup=False)
        assert res.arrivals[:, 1:].sum() == 0
        assert res.arrivals[:, 0].sum() == np.asarray(wl.mask).sum()
    finally:
        policies.unregister("_test_all_to_zero")
    assert "_test_all_to_zero" not in policies.available()


def test_duplicate_registration_rejected():
    @policies.register("_test_dup")
    class First(policies.Policy):
        pass

    try:
        with pytest.raises(ValueError, match="already registered"):
            @policies.register("_test_dup")
            class Second(policies.Policy):
                pass
    finally:
        policies.unregister("_test_dup")


def test_adaptive_flag_drives_warmup():
    """Warmup targeting is a capability flag, not a policy-name check."""
    assert policies.get_class("midas").adaptive
    for name in ("hash", "power_of_d", "jsq", "chbl"):
        assert not policies.get_class(name).adaptive


def test_sweep_matches_per_seed_runs_and_compiles_once():
    wl = make_workload("bursty", T=200, m=4, seed=11)
    cfg = SimConfig(m=4, N=512, policy="power_of_d")
    seeds = (0, 1, 2, 3)
    before = sim_lib._SWEEP_TRACES[0]
    sweep = simulate_sweep(cfg, wl, seeds=seeds, do_warmup=False)
    assert sim_lib._SWEEP_TRACES[0] == before + 1   # one compile, 4 seeds
    assert set(sweep) == {"power_of_d"}
    assert len(sweep["power_of_d"]) == len(seeds)
    for i, s in enumerate(seeds):
        single = simulate(dataclasses.replace(cfg, seed=s), wl,
                          do_warmup=False)
        np.testing.assert_allclose(sweep["power_of_d"][i].queue_timeline,
                                   single.queue_timeline,
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(sweep["power_of_d"][i].arrivals,
                                   single.arrivals, rtol=1e-5, atol=1e-5)


def test_sweep_fans_out_over_policies_with_cache():
    wl = make_workload("skewed", T=120, m=4, seed=2)
    sweep = simulate_sweep(SimConfig(m=4, middleware=("cache",)), wl,
                           policies=("hash", "midas"), seeds=(0, 1),
                           do_warmup=False)
    assert set(sweep) == {"hash", "midas"}
    for rows in sweep.values():
        assert len(rows) == 2
        for r in rows:
            assert r.final_cache is not None
            assert np.isfinite(r.queue_timeline).all()
