"""Workload registry, combinators, scenarios, and trace replay."""
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SimConfig, make_workload, simulate_sweep, workloads
from repro.core import sim as sim_lib

DATA = Path(__file__).resolve().parent / "data"

LEGACY = ("light", "uniform_heavy", "bursty", "periodic", "diurnal",
          "skewed", "storm")
SCENARIOS = ("job_startup", "rename_storm", "flash_crowd", "multi_tenant")


def _count(w):
    return int(np.asarray(w.mask).sum())


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_legacy_seven_and_scenarios_registered():
    names = workloads.available()
    for n in LEGACY + SCENARIOS + ("trace_replay",):
        assert n in names
    assert workloads.WORKLOADS == LEGACY        # legacy tuple preserved
    assert len(names) >= 12


def test_unknown_workload_error_lists_every_alternative():
    with pytest.raises(ValueError) as ei:
        make_workload("no_such_workload", T=10, m=4)
    msg = str(ei.value)
    assert "no_such_workload" in msg
    for n in workloads.available():
        assert n in msg


def test_third_party_workload_registers_and_runs():
    @workloads.register("_test_constant")
    class Constant(workloads.WorkloadSpec):
        def build(self, p):
            rate = jnp.full((p.T,), 0.2 * p.cap)
            return workloads.assemble(p.rng, rate, p.R, p.N, 0.0,
                                      p.write_frac, "_test_constant")

    try:
        wl = make_workload("_test_constant", T=20, m=4, seed=0)
        assert wl.name == "_test_constant"
        assert wl.keys.shape == wl.mask.shape
    finally:
        workloads.unregister("_test_constant")
    assert "_test_constant" not in workloads.available()


def test_duplicate_workload_registration_rejected():
    @workloads.register("_test_dup_wl")
    class First(workloads.WorkloadSpec):
        pass

    try:
        with pytest.raises(ValueError, match="already registered"):
            @workloads.register("_test_dup_wl")
            class Second(workloads.WorkloadSpec):
                pass
    finally:
        workloads.unregister("_test_dup_wl")


def test_every_workload_well_formed():
    for name in workloads.available():
        wl = make_workload(name, T=60, m=4, seed=0, N=256)
        assert wl.keys.shape == wl.mask.shape == wl.is_write.shape
        k = np.asarray(wl.keys)
        assert (k >= 0).all() and (k < wl.N).all()
        assert not np.any(np.asarray(wl.is_write) & ~np.asarray(wl.mask))


def test_every_workload_honors_requested_horizon():
    """Regression: multi-phase scenarios must yield exactly T ticks even
    for degenerate horizons, so same-params grids always batch together."""
    for name in workloads.available():
        for T in (1, 2, 3, 5, 8):
            wl = make_workload(name, T=T, m=4, seed=0, N=256)
            assert wl.keys.shape[0] == T, (name, T, wl.keys.shape)


def test_registry_smoke_every_workload_simulates_nan_free():
    """Every registered workload runs NaN-free under midas + round_robin —
    one batched sweep per policy, however many workloads are registered."""
    wls = [make_workload(n, T=40, m=4, seed=0, N=256)
           for n in workloads.available()]
    before = sim_lib._SWEEP_TRACES[0]
    sweep = simulate_sweep(SimConfig(m=4, N=256), wls,
                           policies=("midas", "round_robin"),
                           do_warmup=False)
    assert sim_lib._SWEEP_TRACES[0] == before + 2   # one compile per policy
    for policy, per_wl in sweep.items():
        assert set(per_wl) == set(workloads.available())
        for wl_name, rows in per_wl.items():
            for r in rows:
                assert np.isfinite(r.queue_timeline).all(), (policy, wl_name)
                assert (r.queue_timeline >= 0).all(), (policy, wl_name)
                assert np.isfinite(r.lat_pred).all(), (policy, wl_name)


def test_multi_workload_sweep_matches_single_runs():
    wls = [make_workload(n, T=80, m=4, seed=0, N=256)
           for n in ("bursty", "skewed")]
    sweep = simulate_sweep(SimConfig(m=4, N=256), wls,
                           policies=("power_of_d",), seeds=(0,),
                           do_warmup=False)
    lone = simulate_sweep(SimConfig(m=4, N=256), wls[1],
                          policies=("power_of_d",), seeds=(0,),
                          do_warmup=False)
    np.testing.assert_allclose(
        sweep["power_of_d"]["skewed"][0].queue_timeline,
        lone["power_of_d"][0].queue_timeline, rtol=1e-5, atol=1e-5)


def test_sweep_rejects_mismatched_grids_and_duplicate_names():
    a = make_workload("light", T=20, m=4, seed=0)
    b = make_workload("light", T=30, m=4, seed=1)
    with pytest.raises(ValueError, match="grid shape"):
        simulate_sweep(SimConfig(m=4), [a, b], do_warmup=False)
    with pytest.raises(ValueError, match="unique"):
        simulate_sweep(SimConfig(m=4), [a, a], do_warmup=False)


# ---------------------------------------------------------------------------
# Combinators — conservation contracts
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pair():
    return (make_workload("light", T=50, m=8, seed=0),
            make_workload("skewed", T=50, m=8, seed=1))


def test_mix_partitions_requests(pair):
    """The Bernoulli selection partitions slots: the two complementary
    mixes together carry exactly the requests of both components."""
    a, b = pair
    m1 = workloads.mix(a, b, 0.3, seed=7)
    m2 = workloads.mix(b, a, 0.3, seed=7)
    assert _count(m1) + _count(m2) == _count(a) + _count(b)
    # writes stay within masks
    for m in (m1, m2):
        assert not np.any(np.asarray(m.is_write) & ~np.asarray(m.mask))


def test_mix_extremes_recover_components(pair):
    a, b = pair
    np.testing.assert_array_equal(
        np.asarray(workloads.mix(a, b, 0.0).mask), np.asarray(a.mask))
    np.testing.assert_array_equal(
        np.asarray(workloads.mix(a, b, 1.0).keys), np.asarray(b.keys))


def test_concat_counts_add_and_time_stacks(pair):
    a, b = pair
    c = workloads.concat(a, b)
    assert c.keys.shape[0] == a.keys.shape[0] + b.keys.shape[0]
    assert _count(c) == _count(a) + _count(b)
    np.testing.assert_array_equal(np.asarray(c.mask)[:a.mask.shape[0]],
                                  np.asarray(a.mask))


def test_scale_rate_identity_thin_boost(pair):
    a, _ = pair
    assert _count(workloads.scale_rate(a, 1.0)) == _count(a)
    thinned = workloads.scale_rate(a, 0.5, seed=3)
    assert _count(thinned) <= _count(a)
    assert not np.any(np.asarray(thinned.mask) & ~np.asarray(a.mask))
    boosted = workloads.scale_rate(a, 2.0, seed=3)
    counts = np.asarray(a.mask).sum(axis=1)
    R = a.mask.shape[1]
    expect = np.minimum(np.round(counts * 2.0), R).astype(int)
    np.testing.assert_array_equal(np.asarray(boosted.mask).sum(axis=1),
                                  expect)
    # boosted keys only replicate the tick's own keys
    k_orig = np.asarray(a.keys)
    k_boost = np.asarray(boosted.keys)
    m_orig, m_boost = np.asarray(a.mask), np.asarray(boosted.mask)
    for t in (0, 17, 42):
        if m_orig[t].any():
            assert set(k_boost[t][m_boost[t]]) <= set(k_orig[t][m_orig[t]])


def test_shift_hotset_moves_keys_only(pair):
    a, _ = pair
    sh = workloads.shift_hotset(a, 1234)
    np.testing.assert_array_equal(np.asarray(sh.mask), np.asarray(a.mask))
    np.testing.assert_array_equal(np.asarray(sh.is_write),
                                  np.asarray(a.is_write))
    np.testing.assert_array_equal(
        np.asarray(sh.keys), (np.asarray(a.keys) + 1234) % a.N)


# ---------------------------------------------------------------------------
# Trace replay
# ---------------------------------------------------------------------------


def test_trace_replay_roundtrips_checked_in_npz():
    """Rebucketing the shipped trace reproduces its events exactly when the
    grid is wide/long enough (loop off)."""
    t_ms, key, is_write = workloads.load_trace(DATA / "synthetic_trace.npz")
    dt = 50.0
    T = int(np.floor(t_ms.max() / dt)) + 1
    wl = make_workload("trace_replay", T=T, m=8, seed=0, dt_ms=dt,
                       R=64, N=4096, trace=DATA / "synthetic_trace.npz",
                       loop=False)
    mask = np.asarray(wl.mask)
    assert mask.sum() == t_ms.size            # nothing dropped
    # row-major extraction reproduces the trace in (tick, arrival) order
    got_keys = np.asarray(wl.keys)[mask]
    got_writes = np.asarray(wl.is_write)[mask]
    order = np.argsort(np.floor(t_ms / dt), kind="stable")
    np.testing.assert_array_equal(got_keys, key[order] % 4096)
    np.testing.assert_array_equal(got_writes, is_write[order])


def test_trace_replay_loops_to_fill_horizon():
    wl = make_workload("trace_replay", T=2000, m=8, seed=0)  # 100 s grid
    per_tick = np.asarray(wl.mask).sum(axis=1)
    # the ~20 s trace repeats: the tail half of the horizon still has load
    assert per_tick[1000:].sum() > 0.25 * per_tick.sum()


def test_trace_replay_missing_file_raises_helpfully():
    with pytest.raises(FileNotFoundError, match="t_ms"):
        make_workload("trace_replay", T=10, m=4,
                      trace=DATA / "no_such_trace.npz")


def test_rebucket_drops_overflow_beyond_slot_budget():
    t_ms = np.zeros(10)                        # 10 events in tick 0
    key = np.arange(10)
    w = np.zeros(10, bool)
    keys, mask, writes = workloads.rebucket(t_ms, key, w, T=4, R=4, N=64,
                                            dt_ms=50.0, loop=False)
    assert mask[0].sum() == 4                  # first R kept, rest dropped
    np.testing.assert_array_equal(keys[0][mask[0]], np.arange(4))
