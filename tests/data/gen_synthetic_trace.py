"""Regenerate synthetic_trace.npz — the checked-in trace-replay fixture.

The events come from ``repro.core.workloads.trace.synthetic_events`` (the
library's in-code fallback when this file is absent), so the fixture and
the fallback can never drift.

  PYTHONPATH=src python tests/data/gen_synthetic_trace.py
"""
from pathlib import Path

import numpy as np

from repro.core.workloads.trace import synthetic_events

OUT = Path(__file__).resolve().parent / "synthetic_trace.npz"


def main() -> None:
    t_ms, key, is_write = synthetic_events()
    np.savez_compressed(OUT, t_ms=t_ms, key=key, is_write=is_write)
    print(f"wrote {OUT} ({t_ms.size} events, "
          f"{t_ms.max() / 1000.0:.1f} s span)")


if __name__ == "__main__":
    main()
