"""Generate tests/data/control_golden.npz — pre-refactor engine goldens.

The checked-in ``control_golden.npz`` was produced by the engine AS OF THE
COMMIT THAT INTRODUCED THE CONTROLLER REGISTRY (PR 5), i.e. by the
pre-refactor control plane (the monolithic ``control.py`` hysteresis
update wired directly into ``sim._tick``).  The parity contract in
``tests/test_core_controllers.py`` asserts that
``SimConfig(controller="hysteresis")`` — the default — reproduces these
arrays bit-for-bit on CPU, across policies × middleware × ablations,
including a horizon long enough to cross the slow-loop cadence.

Regenerating the file on a machine where the contract already holds is a
no-op by construction; regenerate ONLY to extend the config set::

    PYTHONPATH=src python tests/data/gen_control_golden.py
"""

from __future__ import annotations

from pathlib import Path

import numpy as np

from repro.core import SimConfig, make_workload, simulate
from repro.core import control as ctl

OUT = Path(__file__).resolve().parent / "control_golden.npz"

# Engine configs: policies × middleware × ablations, plus one horizon
# that crosses the slow-loop boundary (T_slow = 600 ticks at dt=50 ms)
# and one run through the §III-B warmup target derivation.
CONFIGS = {
    "pod_bare": dict(policy="power_of_d", middleware=()),
    "chbl_bare": dict(policy="chbl", middleware=()),
    "midas_cache": dict(policy="midas", middleware=("cache",)),
    "midas_fleet": dict(
        policy="midas",
        middleware=("fleet_cache",),
        fleet_routing=True,
        gossip_ms=100.0,
    ),
    "midas_no_margin": dict(
        policy="midas", middleware=("cache",), ablate="no_margin"
    ),
    "midas_no_pin": dict(
        policy="midas", middleware=("cache",), ablate="no_pin"
    ),
    "midas_no_bucket": dict(
        policy="midas", middleware=("cache",), ablate="no_bucket"
    ),
}
FIELDS = (
    "queue_timeline",
    "arrivals",
    "lat_pred",
    "d_timeline",
    "delta_l_timeline",
    "f_max_timeline",
    "pressure",
    "steered",
    "eligible",
    "cache_hits",
)
T = 160
T_SLOW = 700  # crosses the 600-tick slow-loop cadence


def main() -> None:
    arrays = {}
    wl = make_workload("bursty", T=T, m=8, seed=3, N=512)
    for name, kw in CONFIGS.items():
        res = simulate(SimConfig(m=8, N=512, **kw), wl, do_warmup=False)
        for f in FIELDS:
            arrays[f"{name}/{f}"] = np.asarray(getattr(res, f))

    wl_slow = make_workload("bursty", T=T_SLOW, m=8, seed=3, N=512)
    for name, kw in (
        ("midas_slow_ttl", dict(middleware=("cache",),
                                cache_mode="ttl_aggregate")),
        ("midas_slow_lease", dict(middleware=("cache",),
                                  cache_mode="lease")),
    ):
        res = simulate(SimConfig(m=8, N=512, policy="midas", **kw),
                       wl_slow, do_warmup=False)
        for f in FIELDS:
            arrays[f"{name}/{f}"] = np.asarray(getattr(res, f))

    # full default pipeline incl. warmup-derived targets
    res = simulate(
        SimConfig(m=8, N=512, policy="midas", middleware=("cache",)), wl
    )
    for f in FIELDS:
        arrays[f"midas_warmup/{f}"] = np.asarray(getattr(res, f))

    # unit-level knob trajectory of the pre-refactor fast_update under a
    # deterministic synthetic signal sequence
    n = 400
    B = np.abs(np.sin(np.arange(n) / 7.0)) * 3.0
    p99 = 400.0 + 300.0 * np.sin(np.arange(n) / 11.0)
    jit = np.random.default_rng(0).uniform(-1.0, 1.0, n)
    c = ctl.init_control(rtt_ms=2.0, b_tgt=0.15, p99_tgt=500.0)
    traj = {k: [] for k in ("d", "delta_l", "delta_t", "f_max", "pressure")}
    import jax.numpy as jnp

    for i in range(n):
        c = ctl.fast_update(
            c, jnp.asarray(B[i], jnp.float32),
            jnp.asarray(p99[i], jnp.float32), 2.0,
            jnp.asarray(jit[i], jnp.float32),
        )
        for k in traj:
            traj[k].append(np.asarray(getattr(c, k)))
    for k, v in traj.items():
        arrays[f"fast_update/{k}"] = np.stack(v)
    arrays["fast_update/B"] = B.astype(np.float32)
    arrays["fast_update/p99"] = p99.astype(np.float32)
    arrays["fast_update/jitter"] = jit.astype(np.float32)

    np.savez_compressed(OUT, **arrays)
    print(f"wrote {OUT} ({len(arrays)} arrays)")


if __name__ == "__main__":
    main()
