"""Regenerate redteam_worst.npz — the committed adversarial fixture.

The parameters below are the worst hysteresis input found by the
adversarial-traffic search (``python experiments/run_hillclimb.py
advtraffic``): the AdversaryParams vector that maximized the hysteresis
controller's oscillation rate over the search box.  Re-running the
search may find a different (worse) vector; this script pins the one
the committed fixture, the E13 ``adv_trace`` cell, and the
``tests/test_redteam.py`` regression budget were all measured against.

  PYTHONPATH=src python tests/data/gen_redteam_trace.py
"""
from pathlib import Path

from repro.core.workloads import make_workload
from repro.core.workloads.adversary import AdversaryParams, save_trace

OUT = Path(__file__).resolve().parent / "redteam_worst.npz"

# worst-vs-hysteresis vector from the advtraffic search (seed 0):
# 21 d-flips/min on the unguarded hysteresis controller — short ~14-tick
# bursts at ~0.83x capacity, each on a rotated hotset, with ~116 calm
# ticks between them: every cycle clears both the escalate (K_UP) and
# release (K_DOWN) dwells, so d climbs and releases indefinitely
WORST = AdversaryParams(
    period=130.8316972393037,
    duty=0.1090463474204382,
    shift_frac=0.44217964607932064,
    write_hi=0.5774894842206617,
    amp=0.8314696184062458,
)

# the grid the search evaluated on (and E13's adv_trace cell replays)
T, M, N, SEED = 1200, 8, 1024, 0


def main() -> None:
    wl = make_workload(
        "adversarial", T=T, m=M, seed=SEED, N=N, params=WORST)
    save_trace(OUT, wl)
    import numpy as np

    with np.load(OUT) as z:
        n, span = z["t_ms"].size, z["t_ms"].max() / 1000.0
    print(f"wrote {OUT} ({n} events, {span:.1f} s span)")


if __name__ == "__main__":
    main()
