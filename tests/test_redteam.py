"""Red-team regressions: the oscillation guard and the committed
worst-case adversarial trace (PR 9).

The fixture ``tests/data/redteam_worst.npz`` is the adversarial-traffic
search's worst discovered input vs the hysteresis controller
(``tests/data/gen_redteam_trace.py`` regenerates it from the pinned
parameter vector).  The budget test is the red-team contract: replayed
through ``trace_replay``, the guarded hysteresis controller must
oscillate strictly less than the unguarded one AND stay under an
absolute flips-per-minute budget — if either regresses, the guard's
circuit breaker stopped doing its job.
"""
import numpy as np
import pytest

from repro.core import SimConfig, make_workload, simulate
from repro.core import controllers as ctrl_lib
from repro.core.workloads import adversary

FIXTURE = "tests/data/redteam_worst.npz"
T, M, N = 1200, 8, 1024
# flips/min the guarded hysteresis must stay under on the fixture.  The
# unguarded controller limit-cycles at 21 flips/min; the guard trips at
# the first slow tick and freezes the knobs, cutting it to 12 — the
# budget sits between the two so either direction of regression
# (fixture losing its bite, guard losing its brake) fails loudly.
OSC_BUDGET = 15.0


def _replay():
    return make_workload(
        "trace_replay", T=T, m=M, seed=0, N=N,
        trace=FIXTURE, loop=False)


def _osc(guard: bool) -> float:
    cfg = SimConfig(m=M, N=N, policy="midas", controller="hysteresis",
                    guard=guard)
    r = simulate(cfg, _replay(), do_warmup=True)
    st = ctrl_lib.trajectory_stats(
        r.d_timeline, r.delta_l_timeline, r.f_max_timeline, r.pressure,
        cfg.dt_ms)
    return float(st["oscillation_per_min"])


# ---------------------------------------------------------------------------
# Guard wiring
# ---------------------------------------------------------------------------


def test_wrap_guard_disabled_is_identity():
    ctrl = ctrl_lib.get("hysteresis")
    assert ctrl_lib.wrap_guard(ctrl, False) is ctrl


def test_guard_name_and_view_delegation():
    ctrl = ctrl_lib.wrap_guard(ctrl_lib.get("hysteresis"), True)
    assert ctrl.name == "hysteresis+guard"
    cfg = SimConfig(m=M, N=N)
    st = ctrl.init(cfg, (0.15, 500.0))
    v = ctrl.view(st)
    assert int(v.d) == ctrl_lib.D_INIT  # inner view, untouched at init


def test_guard_config_validation():
    with pytest.raises(ValueError, match="guard"):
        SimConfig(m=M, N=N, guard="yes")


def test_guard_off_is_golden():
    """guard=False routes through the identical unwrapped controller —
    the zero-cost-when-off contract extends to the guard plane."""
    g = np.load("tests/data/control_golden.npz")
    wl = make_workload("bursty", T=160, m=8, seed=3, N=512)
    cfg = SimConfig(m=8, N=512, policy="midas", middleware=("cache",),
                    guard=False)
    r = simulate(cfg, wl, do_warmup=False)
    np.testing.assert_array_equal(r.queue_timeline,
                                  g["midas_cache/queue_timeline"])
    np.testing.assert_array_equal(r.d_timeline,
                                  g["midas_cache/d_timeline"])


# ---------------------------------------------------------------------------
# Adversary family + trace export
# ---------------------------------------------------------------------------


def test_adversarial_registered_and_parametric():
    wl = make_workload("adversarial", T=200, m=8, seed=0, N=256,
                       period=40, duty=0.25)
    assert wl.name == "adversarial"
    assert wl.keys.shape == wl.mask.shape == wl.is_write.shape
    with pytest.raises(ValueError, match="available"):
        make_workload("adversarial", T=200, m=8, seed=0, N=256,
                      frequency=3)


def test_params_roundtrip_and_clipping():
    p = adversary.AdversaryParams(period=10_000.0, duty=0.5)
    assert p.clipped().period == adversary.BOUNDS["period"][1]
    v = adversary.AdversaryParams().to_vector()
    assert adversary.AdversaryParams.from_vector(v) == \
        adversary.AdversaryParams()


def test_trace_roundtrip_multiset_exact(tmp_path):
    """save_trace -> trace_replay(loop=False) reproduces every tick's
    event multiset (slot positions may compact; counts and contents
    must not change)."""
    wl = make_workload("adversarial", T=120, m=8, seed=1, N=256)
    path = tmp_path / "adv.npz"
    adversary.save_trace(path, wl)
    back = make_workload("trace_replay", T=120, m=8, seed=1, N=256,
                         trace=path, loop=False)
    mask = np.asarray(wl.mask)
    bmask = np.asarray(back.mask)
    np.testing.assert_array_equal(mask.sum(axis=1), bmask.sum(axis=1))
    keys, bkeys = np.asarray(wl.keys), np.asarray(back.keys)
    wr, bwr = np.asarray(wl.is_write), np.asarray(back.is_write)
    for t in range(120):
        a = sorted(zip(keys[t][mask[t]], wr[t][mask[t]]))
        b = sorted(zip(bkeys[t][bmask[t]], bwr[t][bmask[t]]))
        assert a == b, f"tick {t}: event multiset changed"


# ---------------------------------------------------------------------------
# The committed worst case: guard budget regression
# ---------------------------------------------------------------------------


def test_fixture_guard_holds_oscillation_budget():
    unguarded = _osc(False)
    guarded = _osc(True)
    # the fixture must actually be adversarial (a limit cycle exists)...
    assert unguarded > OSC_BUDGET
    # ...and the guard must break it: strictly lower, under budget
    assert guarded < unguarded
    assert guarded <= OSC_BUDGET
