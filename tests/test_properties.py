"""Hypothesis property tests on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional dep: skip cleanly when absent
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import cache as cache_lib
from repro.core import control as ctl
from repro.core import controllers as ctrl_lib
from repro.core import fleet as fleet_lib
from repro.core import hashring, telemetry

SETTINGS = dict(max_examples=30, deadline=None)


class _Cfg:
    """Minimal config stub for direct controller stepping."""

    rtt_ms = 2.0


@given(m=st.integers(2, 24), key_lo=st.integers(0, 10_000))
@settings(**SETTINGS)
def test_feasible_sets_always_valid(m, key_lo):
    ring = hashring.make_ring(m, V=32)
    keys = jnp.arange(key_lo, key_lo + 64, dtype=jnp.int32)
    feas = np.asarray(hashring.feasible_set(ring, keys, 4))
    prim = np.asarray(hashring.primary(ring, keys))
    assert ((feas >= 0) & (feas < m)).all()
    assert (feas[:, 0] == prim).all()
    # entries distinct whenever m >= 4
    if m >= 4:
        assert all(len(set(r.tolist())) == 4 for r in feas)


@given(pressures=st.lists(st.floats(0.0, 10.0), min_size=1, max_size=200))
@settings(**SETTINGS)
def test_control_knobs_always_bounded(pressures):
    """No pressure sequence can push knobs out of their paper bounds."""
    c = ctl.init_control(rtt_ms=2.0, b_tgt=0.0, p99_tgt=1.0)
    for p in pressures:
        # drive via imbalance directly (b_tgt=0 so B == pressure term)
        c = ctl.fast_update(c, jnp.asarray(p), jnp.asarray(0.0), 2.0,
                            jnp.asarray(0.0))
        assert ctl.D_MIN <= int(c.d) <= ctl.D_MAX
        assert ctl.DELTA_L_MIN <= float(c.delta_l) <= ctl.DELTA_L_MAX


import functools  # noqa: E402


@functools.lru_cache(maxsize=None)
def _traj_runner(ctrl_name, n_steps):
    """Jitted fast-loop trajectory: one compile per controller, every
    hypothesis example then runs as a single device call."""
    import jax

    c = ctrl_lib.get(ctrl_name)

    @jax.jit
    def run(state, B_seq):
        def body(s, B):
            s, k = c.fast(s, ctrl_lib.make_signals(
                B=B, p99=0.0, rtt_ms=2.0))
            return s, (k.d, k.delta_l, k.f_max)

        return jax.lax.scan(body, state, B_seq)

    return run


@given(ctrl_name=st.sampled_from(ctrl_lib.available()),
       pressures=st.lists(st.floats(0.0, 10.0), min_size=1, max_size=80),
       b_tgt=st.floats(0.0, 1.0))
@settings(**SETTINGS)
def test_every_registered_controller_keeps_knobs_in_spec_bounds(
        ctrl_name, pressures, b_tgt):
    """Registry-wide KnobSpec contract: NO registered controller, under
    ANY pressure sequence, may emit a knob outside its declared bounds
    (the engine's routing policies assume d ∈ {1..4}, f_max ≤ 1, ...)."""
    run = _traj_runner(ctrl_name, 80)
    # drive via imbalance directly (B − b_tgt is the pressure term);
    # pad to the runner's static length by holding the last value
    B = np.asarray(pressures + [pressures[-1]] * (80 - len(pressures)),
                   np.float32) + np.float32(b_tgt)
    s = ctrl_lib.get(ctrl_name).init(_Cfg, (b_tgt, 1.0))
    _, (d, dl, fm) = run(s, jnp.asarray(B))
    for name, vals in (("d", d), ("delta_l", dl), ("f_max", fm)):
        spec = ctrl_lib.spec(name)
        v = np.asarray(vals, np.float64)
        assert (spec.lo - 1e-6 <= v).all() and (v <= spec.hi + 1e-6).all(), \
            (ctrl_name, name, float(v.min()), float(v.max()))


@given(ctrl_name=st.sampled_from(ctrl_lib.available()),
       pressure=st.floats(0.0, 10.0))
@settings(**SETTINGS)
def test_every_registered_controller_is_oscillation_free_under_constant_load(
        ctrl_name, pressure):
    """No sustained limit cycle: under a CONSTANT signal a knob may ramp
    monotonically toward its fixed point (hysteresis steps, integrator
    ramps) but must never reverse direction — direction reversals under
    constant load ARE the oscillation the paper's hysteresis band
    exists to prevent.  (Whether a run also *settles* is a measured
    metric — E4's ``settled_frac`` — not a universal invariant: a slow
    integrator legitimately keeps ramping toward its fixed point.)"""
    n = 300
    run = _traj_runner(ctrl_name, n)
    s = ctrl_lib.get(ctrl_name).init(_Cfg, (0.0, 1.0))
    B = jnp.full((n,), pressure, jnp.float32)
    _, (d, dl, fm) = run(s, B)
    for name, vals in (("d", d), ("delta_l", dl), ("f_max", fm)):
        series = np.asarray(vals, np.float64)
        spec = ctrl_lib.spec(name)
        eps = 1e-9 * max(spec.hi - spec.lo, 1.0)
        diffs = np.diff(series)
        nz = diffs[np.abs(diffs) > eps]
        assert not ((nz > 0).any() and (nz < 0).any()), \
            (ctrl_name, name, "direction reversal under constant load")


@given(loads=st.lists(st.integers(0, 100), min_size=2, max_size=16),
       data=st.data())
@settings(**SETTINGS)
def test_lyapunov_steering_with_margin_2_strictly_decreases_v(loads, data):
    L = jnp.asarray(loads, jnp.float32)
    m = len(loads)
    p = data.draw(st.integers(0, m - 1))
    j = data.draw(st.integers(0, m - 1))
    if p == j:
        return
    if loads[p] - loads[j] >= 2:          # the admitted-steer condition
        dv = float(ctl.lyapunov_delta_v(L, jnp.asarray(p), jnp.asarray(j)))
        assert dv <= -2.0


@given(alpha=st.floats(0.01, 0.99),
       xs=st.lists(st.floats(-100, 100), min_size=1, max_size=50))
@settings(**SETTINGS)
def test_ewma_stays_within_input_hull(alpha, xs):
    lo, hi = min(xs + [0.0]), max(xs + [0.0])
    acc = jnp.asarray(0.0)
    for x in xs:
        acc = telemetry.ewma(acc, jnp.asarray(x), alpha)
        assert lo - 1e-4 <= float(acc) <= hi + 1e-4


@given(ops=st.lists(st.tuples(st.integers(0, 15), st.booleans()),
                    min_size=1, max_size=60))
@settings(**SETTINGS)
def test_lease_mode_never_serves_stale(ops):
    """In lease mode a cached read can never observe an outdated version."""
    c = cache_lib.init_cache(16)
    now = 0.0
    for key, is_write in ops:
        keys = jnp.asarray([key], jnp.int32)
        mask = jnp.asarray([True])
        w = jnp.asarray([is_write])
        c, _ = cache_lib.lookup_batch(c, keys, mask, w, jnp.asarray(now),
                                      mode="lease", lease_ms=500.0)
        now += 7.0
    assert int(c.stale_serves) == 0


@given(ops=st.lists(st.tuples(st.integers(0, 15), st.booleans()),
                    min_size=1, max_size=40),
       P=st.sampled_from([1, 2, 8]),
       mode=st.sampled_from(cache_lib.MODES))
@settings(**SETTINGS)
def test_fleet_gossip_zero_matches_shared_table(ops, P, mode):
    """Δ=0 equivalence contract: with instant gossip the fleet reproduces
    the converged shared-table cache bit-for-bit — same hit decisions,
    same counters, same table trajectory — for any P and coherence mode."""
    N = 16
    shared = cache_lib.init_cache(N)
    fl = fleet_lib.init_fleet(N, P, D=1)
    now = 0.0
    for t, (key, is_write) in enumerate(ops):
        keys = jnp.asarray([key], jnp.int32)
        mask = jnp.asarray([True])
        w = jnp.asarray([is_write])
        shared, hit_s = cache_lib.lookup_batch(
            shared, keys, mask, w, jnp.asarray(now), mode=mode,
            lease_ms=300.0)
        proxy = fleet_lib.proxy_assign(1, P, t)
        fl, hit_f = fleet_lib.lookup_fleet(
            fl, keys, mask, w, proxy, jnp.asarray(now), mode=mode,
            lease_ms=300.0, gossip_ms=0.0)
        assert bool(hit_s[0]) == bool(hit_f[0])
        now += 13.0
    for field in ("hits", "misses", "stale_serves", "bypasses"):
        assert int(getattr(shared, field)) == int(getattr(fl.shared, field))
    assert int(fl.hits_p.sum()) == int(shared.hits)
    for field in ("expiry_ms", "cached_version", "global_version",
                  "key_hazard"):
        np.testing.assert_array_equal(
            np.asarray(getattr(shared, field)),
            np.asarray(getattr(fl.shared, field)))


@given(writes=st.lists(st.floats(1.0, 1000.0), min_size=2, max_size=30))
@settings(**SETTINGS)
def test_ttl_never_exceeds_lease_or_cap(writes):
    c = cache_lib.init_cache(8)
    c = c._replace(win_writes=jnp.asarray(sum(writes)),
                   win_reads=jnp.asarray(100.0))
    lease = float(np.random.default_rng(0).uniform(1, 1e5))
    c2 = cache_lib.slow_update(c, 30_000.0, rtt_ms=1.0,
                               lease_remaining_ms=lease)
    assert float(c2.ttl_ms) <= min(lease, cache_lib.TTL_CAP_MS) + 1e-3
    assert float(c2.ttl_ms) >= 1.0


# ---------------------------------------------------------------------------
# Engine parity (DESIGN.md §9): scan-over-waves == unrolled reference
# ---------------------------------------------------------------------------

_PARITY_WL = None


def _parity_wl():
    global _PARITY_WL
    if _PARITY_WL is None:
        from repro.core import make_workload
        _PARITY_WL = make_workload("bursty", T=40, m=4, seed=9, N=128)
    return _PARITY_WL


@given(policy=st.sampled_from(("round_robin", "uniform", "power_of_d",
                               "midas", "jsq", "chbl")),
       mw=st.sampled_from(((), ("cache",), ("fleet_cache",))),
       n_groups=st.sampled_from((1, 3, 8)),
       fleet=st.booleans())
@settings(max_examples=12, deadline=None)
def test_wave_scan_parity_any_policy_middleware(policy, mw, n_groups,
                                                fleet):
    """Bit-for-bit: the wave scan equals the unrolled Python loop for any
    (policy, middleware chain, wave count, routing mode) draw."""
    import dataclasses

    from repro.core import SimConfig, simulate
    cfg = SimConfig(m=4, N=128, P=4, policy=policy, middleware=mw,
                    n_groups=n_groups, fleet_routing=fleet, gossip_ms=50.0)
    ref = dataclasses.replace(cfg, unroll_waves=True)
    wl = _parity_wl()
    a = simulate(cfg, wl, do_warmup=False)
    b = simulate(ref, wl, do_warmup=False)
    np.testing.assert_array_equal(a.queue_timeline, b.queue_timeline)
    np.testing.assert_array_equal(a.arrivals, b.arrivals)
    np.testing.assert_array_equal(a.steered, b.steered)
    np.testing.assert_array_equal(a.cache_hits, b.cache_hits)


# ---------------------------------------------------------------------------
# Observability: the windowing contract (DESIGN.md §13)
# ---------------------------------------------------------------------------


@given(
    xs=st.lists(
        st.floats(min_value=-1e6, max_value=1e6,
                  allow_nan=False, allow_infinity=False),
        min_size=0, max_size=120,
    ),
    hold=st.integers(2, 16),
)
@settings(max_examples=60, deadline=None)
def test_window_invariant_for_arbitrary_series(xs, hold):
    """0 <= begin <= end <= T for ANY finite timeline, and windowed
    statistics never produce non-finite parity shifts."""
    from repro.obs import windows

    w = windows.detect(np.asarray(xs), hold=hold)
    assert 0 <= w.begin <= w.end <= w.T == len(xs)
    stats = windows.windowed_stats(np.asarray(xs), w)
    assert np.isfinite(stats["shift"])


@given(level=st.floats(-100.0, 100.0), n=st.integers(20, 200))
@settings(max_examples=30, deadline=None)
def test_constant_load_always_opens_within_hold(level, n):
    """A constant-load trace has no transient: the stable window opens
    within the hold bound and runs to the horizon."""
    from repro.obs import windows

    w = windows.detect(np.full(n, level))
    assert w.method == "ewma_plateau" and w.begin <= windows.HOLD
    assert w.end == w.T == n
