"""§V theory: balls-into-bins max-load and M/M/1 latency."""
import math

import pytest

from repro.core import theory


def test_mm1_latency():
    assert theory.mm1_latency(0.0, 10.0) == pytest.approx(0.1)
    assert theory.mm1_latency(5.0, 10.0) == pytest.approx(0.2)
    assert theory.mm1_latency(10.0, 10.0) == math.inf


def test_power_of_two_beats_uniform():
    m = 64
    gap1, _ = theory.maxload_gap_empirical(n_balls=m, m=m, d=1, trials=30)
    gap2, _ = theory.maxload_gap_empirical(n_balls=m, m=m, d=2, trials=30)
    assert gap2 < gap1
    # theory scale: ln m/ln ln m vs ln ln m / ln 2
    assert gap1 > theory.power_of_d_maxload_gap_theory(m, 2)


def test_maxload_gap_shrinks_with_d():
    m = 64
    gaps = [theory.maxload_gap_empirical(n_balls=m, m=m, d=d, trials=20)[0]
            for d in (1, 2, 4)]
    assert gaps[0] > gaps[1] >= gaps[2]


def test_uniform_gap_matches_theory_scale():
    """E[max above mean] ≈ ln m / ln ln m for n = m balls (within 2x)."""
    m = 256
    gap, _ = theory.maxload_gap_empirical(n_balls=m, m=m, d=1, trials=30)
    pred = theory.uniform_maxload_gap_theory(m)
    assert 0.5 * pred < gap < 2.5 * pred
