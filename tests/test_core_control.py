"""Self-stabilizing control loop: hysteresis, bounds, Lyapunov argument."""
import jax.numpy as jnp
import numpy as np

from repro.core import control as ctl


def _ctrl():
    return ctl.init_control(rtt_ms=2.0, b_tgt=0.1, p99_tgt=100.0)


def step_n(c, B, p99, n):
    for _ in range(n):
        c = ctl.fast_update(c, jnp.asarray(B), jnp.asarray(p99), 2.0,
                            jnp.asarray(0.0))
    return c


def test_pressure_zero_when_within_targets():
    c = _ctrl()
    P = ctl.pressure_score(jnp.asarray(0.05), jnp.asarray(50.0), c)
    assert float(P) == 0.0


def test_knobs_escalate_after_k_up():
    c = _ctrl()
    # high pressure for K_UP iterations bumps d once and relaxes delta_l
    c = step_n(c, 2.0, 1000.0, ctl.K_UP)
    assert int(c.d) == ctl.D_INIT + 1
    assert float(c.delta_l) == ctl.DELTA_L_INIT - 1


def test_knobs_deescalate_after_k_down():
    c = _ctrl()
    c = step_n(c, 0.0, 0.0, ctl.K_DOWN)
    assert int(c.d) == ctl.D_INIT - 1
    assert float(c.delta_l) == ctl.DELTA_L_INIT + 1


def test_counter_resets_after_firing():
    c = _ctrl()
    c = step_n(c, 2.0, 1000.0, ctl.K_UP)          # fires
    assert int(c.above_cnt) == 0                  # reset
    c2 = step_n(c, 2.0, 1000.0, ctl.K_UP - 1)     # not yet again
    assert int(c2.d) == int(c.d)


def test_knob_bounds_under_sustained_pressure():
    c = _ctrl()
    c = step_n(c, 5.0, 1e6, 100)
    assert int(c.d) == ctl.D_MAX
    assert float(c.delta_l) == ctl.DELTA_L_MIN
    c = step_n(c, 0.0, 0.0, 400)
    assert int(c.d) == ctl.D_MIN
    assert float(c.delta_l) == ctl.DELTA_L_MAX


def test_deadband_freezes_knobs():
    """H_down < P < H_up: neither counter advances, knobs frozen."""
    c = _ctrl()
    mid_B = float(c.b_tgt) + (ctl.H_DOWN + ctl.H_UP) / 2
    c2 = step_n(c, mid_B, 0.0, 50)
    assert int(c2.d) == int(c.d)
    assert float(c2.delta_l) == float(c.delta_l)


def test_warmup_targets_formulas():
    B = jnp.asarray([0.1, 0.2, 0.3, 0.4, 0.5])
    b_tgt, p99_tgt = ctl.warmup_targets(B, jnp.asarray(100.0), rtt_ms=2.0)
    assert np.isclose(float(b_tgt), 0.3 + 0.05)
    assert np.isclose(float(p99_tgt), 125.0)       # 1.25 * p99_warm
    # RTT floor binds on very fast paths
    _, p99_tgt2 = ctl.warmup_targets(B, jnp.asarray(1.0), rtt_ms=20.0)
    assert np.isclose(float(p99_tgt2), 22.0)


def test_lyapunov_delta_matches_potential_difference():
    rng = np.random.default_rng(0)
    for _ in range(50):
        L = jnp.asarray(rng.integers(0, 50, size=8).astype(np.float32))
        p, j = rng.choice(8, size=2, replace=False)
        moved = L.at[p].add(-1.0).at[j].add(1.0)
        dv_direct = (ctl.lyapunov_potential(moved)
                     - ctl.lyapunov_potential(L))
        dv_formula = ctl.lyapunov_delta_v(L, jnp.asarray(p), jnp.asarray(j))
        assert np.isclose(float(dv_direct), float(dv_formula), atol=1e-3)


def test_lyapunov_negative_iff_margin_at_least_two():
    """Δ_L >= 2  =>  ΔV <= -2 < 0 (paper's stability condition)."""
    L = jnp.asarray([10.0, 8.0, 7.5, 3.0])
    # margin exactly 2: p=0 (10), j with L=8
    dv = float(ctl.lyapunov_delta_v(L, jnp.asarray(0), jnp.asarray(1)))
    assert dv == -2.0
    # margin 1 is NOT enough (ΔV = 0)
    L2 = jnp.asarray([10.0, 9.0])
    dv2 = float(ctl.lyapunov_delta_v(L2, jnp.asarray(0), jnp.asarray(1)))
    assert dv2 == 0.0


def test_f_max_adapts_with_hysteresis_and_stays_bounded():
    """The steering cap doubles under sustained pressure (the rename_storm
    relief valve), saturates at F_MAX_HIGH, and decays back to the
    paper's 10% floor under calm load."""
    c = _ctrl()
    c = step_n(c, B=5.0, p99=0.0, n=ctl.K_UP)      # one escalation
    assert np.isclose(float(c.f_max), 2 * ctl.F_CAP)
    c = step_n(c, B=5.0, p99=0.0, n=40)            # saturate
    assert np.isclose(float(c.f_max), ctl.F_MAX_HIGH)
    c = step_n(c, B=0.0, p99=0.0, n=200)           # calm: decay to floor
    assert np.isclose(float(c.f_max), ctl.F_CAP)
