"""Consistent-hash ring: determinism, feasibility, stability."""
import jax.numpy as jnp
import numpy as np

from repro.core import hashring


def test_primary_range_and_determinism():
    ring = hashring.make_ring(8, V=64)
    keys = jnp.arange(1000, dtype=jnp.int32)
    p1 = hashring.primary(ring, keys)
    p2 = hashring.primary(ring, keys)
    assert ((p1 >= 0) & (p1 < 8)).all()
    assert (p1 == p2).all()


def test_primary_roughly_balanced():
    ring = hashring.make_ring(8, V=128)
    keys = jnp.arange(20000, dtype=jnp.int32)
    p = np.asarray(hashring.primary(ring, keys))
    counts = np.bincount(p, minlength=8)
    # virtual nodes keep shares within ~2x of fair
    assert counts.min() > 20000 / 8 / 2
    assert counts.max() < 20000 / 8 * 2


def test_feasible_set_contains_primary_and_distinct():
    ring = hashring.make_ring(8, V=64)
    keys = jnp.arange(500, dtype=jnp.int32)
    feas = np.asarray(hashring.feasible_set(ring, keys, 4))
    prim = np.asarray(hashring.primary(ring, keys))
    assert feas.shape == (500, 4)
    assert (feas[:, 0] == prim).all()
    assert ((feas >= 0) & (feas < 8)).all()
    for row in feas:
        assert len(set(row.tolist())) == 4, row


def test_feasible_set_small_m():
    ring = hashring.make_ring(2, V=16)
    feas = np.asarray(hashring.feasible_set(ring, jnp.arange(100), 4))
    # fewer servers than d_max: padding keeps entries in range
    assert ((feas >= 0) & (feas < 2)).all()


def test_consistency_under_server_addition():
    """Adding one server moves at most ~K/m keys (consistent hashing)."""
    keys = jnp.arange(20000, dtype=jnp.int32)
    for m in (4, 8, 16):
        p_before = np.asarray(hashring.primary(hashring.make_ring(m), keys))
        p_after = np.asarray(hashring.primary(hashring.make_ring(m + 1), keys))
        moved = (p_before != p_after).mean()
        # ideal: 1/(m+1); allow 2.5x slack for virtual-node variance
        assert moved < 2.5 / (m + 1), (m, moved)
        # keys that moved must have moved TO the new server
        assert (p_after[p_before != p_after] == m).all()


def test_mix32_is_a_permutation_sample():
    xs = jnp.arange(100000, dtype=jnp.uint32)
    hs = np.asarray(hashring.mix32(xs))
    assert len(np.unique(hs)) == 100000  # injective on this range


def test_make_ring_is_memoized():
    """Host-side lru_cache: re-traces reuse the same concrete ring."""
    assert hashring.make_ring(8, V=64) is hashring.make_ring(8, 64)
    assert hashring.make_ring(8, V=64) is not hashring.make_ring(8, 32)


def test_member_primary_matches_feasible_under_membership():
    """np/JAX parity: the numpy subring primary equals column 0 of the
    member-aware feasible gather, for several live sets."""
    m, V = 8, 64
    ring = hashring.make_ring(m, V)
    keys = jnp.arange(4000, dtype=jnp.int32)
    rng = np.random.default_rng(0)
    for _ in range(4):
        member = rng.random(m) > 0.4
        if not member.any():
            member[0] = True
        np_prim = hashring.np_member_primary(m, V, member, np.asarray(keys))
        feas = np.asarray(
            hashring.feasible_set(
                ring, keys, 4, scan_width=m * V,
                member=jnp.asarray(member),
            )
        )
        np.testing.assert_array_equal(feas[:, 0], np_prim)
        # every feasible entry is a live server
        assert member[feas].all()


def test_member_all_live_is_bitwise_identity():
    """member=all-ones takes the exact member-free path byte for byte."""
    ring = hashring.make_ring(8, V=64)
    keys = jnp.arange(2000, dtype=jnp.int32)
    base = np.asarray(hashring.feasible_set(ring, keys, 4))
    live = np.asarray(
        hashring.feasible_set(
            ring, keys, 4, member=jnp.ones(8, bool)
        )
    )
    np.testing.assert_array_equal(base, live)


def test_member_removal_minimal_disruption():
    """Dropping one server only remaps the keys it owned; survivors'
    keys keep their owner (consistent hashing on the subring)."""
    m, V = 8, 64
    keys = np.arange(20000)
    full = np.ones(m, bool)
    before = hashring.np_member_primary(m, V, full, keys)
    for dead in (0, 3, 7):
        member = full.copy()
        member[dead] = False
        after = hashring.np_member_primary(m, V, member, keys)
        moved = before != after
        # only the dead server's keys move, and never onto the dead one
        assert (before[moved] == dead).all()
        assert (after != dead).all()
        assert ((~moved) | (before == dead)).all()


def test_member_primary_rejects_bad_input():
    import pytest

    with pytest.raises(ValueError):
        hashring.np_member_primary(8, 64, np.ones(7, bool), np.arange(4))
    with pytest.raises(ValueError):
        hashring.np_member_primary(8, 64, np.zeros(8, bool), np.arange(4))


def test_member_removal_property():
    import pytest

    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(
        m=st.integers(2, 12),
        dead=st.data(),
    )
    @settings(max_examples=25, deadline=None)
    def prop(m, dead):
        d = dead.draw(st.integers(0, m - 1))
        keys = np.arange(3000)
        full = np.ones(m, bool)
        member = full.copy()
        member[d] = False
        before = hashring.np_member_primary(m, 32, full, keys)
        after = hashring.np_member_primary(m, 32, member, keys)
        moved = before != after
        assert (before[moved] == d).all()
        assert (after != d).all()

    prop()


def test_numpy_builder_matches_traced_hash():
    """The memoized numpy ring builder reproduces the jnp hash exactly."""
    m, V = 8, 64
    ring = hashring.make_ring(m, V)
    servers = jnp.repeat(jnp.arange(m, dtype=jnp.uint32), V)
    replicas = jnp.tile(jnp.arange(V, dtype=jnp.uint32), m)
    pos = hashring.hash2(servers * jnp.uint32(0x10001) + replicas,
                         jnp.uint32(1))
    order = jnp.argsort(pos)
    np.testing.assert_array_equal(np.asarray(pos[order]),
                                  np.asarray(ring.positions))
    np.testing.assert_array_equal(
        np.asarray(servers[order].astype(jnp.int32)),
        np.asarray(ring.owners))


# ---------------------------------------------------------------------------
# Per-shard subrings (DESIGN.md §12 — the sharded sweep's ring slices)
# ---------------------------------------------------------------------------


def test_subring_primary_matches_global_and_partitions():
    rng = np.random.default_rng(7)
    keys = rng.integers(0, 1 << 31, size=20000, dtype=np.int64)
    for m, V, n_shards in ((8, 64, 4), (64, 64, 8), (3, 16, 5), (16, 32, 1)):
        ring = hashring.make_ring(m, V)
        ref = np.asarray(hashring.primary(ring, jnp.asarray(keys)))
        shard_of = hashring.np_key_shard(keys, n_shards)
        covered = 0
        slots = 0
        for s in range(n_shards):
            sub = hashring.np_subring(m, V, s, n_shards)
            slots += sub.positions.size
            ks = keys[shard_of == s]
            covered += ks.size
            np.testing.assert_array_equal(
                hashring.np_subring_primary(sub, ks), ref[shard_of == s])
        # shards partition the keys, and subring slots sum to the global
        # ring plus one tail per shard
        assert covered == keys.size
        assert slots == m * V + n_shards * 16


def test_subring_feasible_matches_global():
    rng = np.random.default_rng(11)
    keys = rng.integers(0, 1 << 31, size=5000, dtype=np.int64)
    for m, V, n_shards in ((8, 64, 4), (64, 64, 8)):
        ring = hashring.make_ring(m, V)
        ref = np.asarray(hashring.feasible_set(ring, jnp.asarray(keys), 4))
        shard_of = hashring.np_key_shard(keys, n_shards)
        for s in range(n_shards):
            sub = hashring.np_subring(m, V, s, n_shards)
            ks = keys[shard_of == s]
            np.testing.assert_array_equal(
                hashring.np_subring_feasible(sub, ks, 4),
                ref[shard_of == s])


def test_subring_rejects_bad_input():
    import pytest

    with pytest.raises(ValueError, match="shard must be"):
        hashring.np_subring(8, 64, 4, 4)
    sub = hashring.np_subring(8, 64, 0, 4)
    # a key from another shard's arc is refused
    keys = np.arange(4000)
    other = keys[hashring.np_key_shard(keys, 4) == 2][:8]
    with pytest.raises(ValueError, match="route with np_key_shard"):
        hashring.np_subring_primary(sub, other)
    with pytest.raises(ValueError, match="tail"):
        mine = keys[hashring.np_key_shard(keys, 4) == 0][:8]
        hashring.np_subring_feasible(sub, mine, 4, scan_width=32)


def test_subring_union_property():
    """Hypothesis: per-shard subring ownership unions to the global ring
    for arbitrary (m, V, n_shards) splits."""
    import pytest

    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(
        m=st.integers(2, 24),
        n_shards=st.integers(1, 12),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=25, deadline=None)
    def prop(m, n_shards, seed):
        V = 16
        keys = np.random.default_rng(seed).integers(
            0, 1 << 31, size=2000, dtype=np.int64)
        ring = hashring.make_ring(m, V)
        ref = np.asarray(hashring.primary(ring, jnp.asarray(keys)))
        shard_of = hashring.np_key_shard(keys, n_shards)
        out = np.full(keys.size, -1, np.int32)
        for s in range(n_shards):
            sub = hashring.np_subring(m, V, s, n_shards)
            sel = shard_of == s
            out[sel] = hashring.np_subring_primary(sub, keys[sel])
        assert (out >= 0).all()          # the shards cover every key
        np.testing.assert_array_equal(out, ref)

    prop()
