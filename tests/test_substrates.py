"""Substrate tests: checkpointing (atomic/restart/elastic), data pipeline
determinism, failure detection, MIDAS writers/router/shard balancing."""
import json

import numpy as np
import pytest

from repro.ckpt import CheckpointManager, WriterPool
from repro.data import Prefetcher, SyntheticLM, assign_shards, host_load_cv
from repro.ft import FailureDetector, elastic_plan
from repro.serve import MidasRouter
from repro.config import get_smoke_arch


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": {"w": rng.normal(size=(16, 8)).astype(np.float32)},
        "b": [rng.normal(size=(4,)).astype(np.float32),
              np.int32(7)],
    }


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path, lanes=3)
    tree = _tree()
    cm.save(10, tree)
    step, restored = cm.restore_latest(tree)
    assert step == 10
    np.testing.assert_array_equal(restored["a"]["w"], tree["a"]["w"])
    np.testing.assert_array_equal(restored["b"][0], tree["b"][0])


def test_checkpoint_gc_and_latest(tmp_path):
    cm = CheckpointManager(tmp_path, lanes=2, keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _tree(s))
    assert cm.all_steps() == [3, 4]
    assert cm.latest_step() == 4


def test_checkpoint_detects_corruption(tmp_path):
    cm = CheckpointManager(tmp_path, lanes=1)
    tree = _tree()
    cm.save(5, tree)
    # flip bytes in one payload
    d = cm.root / "step_00000005"
    manifest = json.loads((d / "manifest.json").read_text())
    f = d / next(iter(manifest["leaves"].values()))["file"]
    arr = np.load(f)
    arr = arr + 1.0
    np.save(f, arr)
    with pytest.raises(IOError):
        cm.restore(5, tree)


def test_checkpoint_ignores_partial_tmp(tmp_path):
    cm = CheckpointManager(tmp_path, lanes=1)
    cm.save(1, _tree())
    # simulate a crash mid-save: orphan tmp dir with no manifest
    (cm.root / "step_00000002.tmp").mkdir()
    assert cm.latest_step() == 1


def test_checkpoint_async(tmp_path):
    cm = CheckpointManager(tmp_path, lanes=4)
    fut = cm.save(7, _tree(), blocking=False)
    fut.result(timeout=30)
    assert cm.latest_step() == 7


def test_writer_pool_midas_defuses_lane_hotspot():
    """Checkpoint storm with a HOT lane: two giant leaves whose names
    hash to the same primary lane (the paper's hot-directory scenario).
    Static hash stacks ~400 MB on one lane; MIDAS steers the second giant
    to a lighter lane via power-of-d on live backlog."""
    probe = WriterPool(4, policy="hash")
    # find two names colliding on the same primary lane
    first = probe.assign("giant0", 0)
    twin = next(f"giant{i}" for i in range(1, 64)
                if probe.assign(f"giant{i}", 0) == first)

    GIANT = 200 * 1 << 20
    maxes = {}
    for policy in ("hash", "midas"):
        pool = WriterPool(4, policy=policy)
        pool.assign("giant0", GIANT)
        pool.assign(twin, GIANT)
        for i in range(64):              # trailing medium leaves
            pool.assign(f"leaf{i}", 4 << 20)
        maxes[policy] = max(pool._backlog)
    assert maxes["hash"] >= 2 * GIANT            # hotspot stacked
    assert maxes["midas"] <= 1.4 * GIANT         # steered apart
    # worst-case lane backlog cut by >= 50% (paper band: 50-80%)
    assert maxes["midas"] <= 0.7 * maxes["hash"]


def test_pipeline_deterministic_and_seekable():
    cfg = get_smoke_arch("smollm-360m")
    src = SyntheticLM(cfg, batch=2, seq=16, seed=3)
    b1 = src.batch_at(42)
    b2 = src.batch_at(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(src.batch_at(43)["tokens"], b1["tokens"])
    # restart-exactness through the prefetcher
    pf = Prefetcher(src, start_step=42)
    step, batch = next(pf)
    pf.close()
    assert step == 42
    np.testing.assert_array_equal(batch["tokens"], b1["tokens"])


def test_pipeline_hosts_get_distinct_data():
    cfg = get_smoke_arch("smollm-360m")
    a = SyntheticLM(cfg, 2, 16, seed=0, host=0, num_hosts=2).batch_at(0)
    b = SyntheticLM(cfg, 2, 16, seed=0, host=1, num_hosts=2).batch_at(0)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_failure_detector_and_elastic_plan():
    fd = FailureDetector(hosts=4, timeout_s=5.0)
    now = 100.0
    for h in range(4):
        fd.heartbeat(h, step_time_s=1.0, now=now)
    fd.heartbeat(3, step_time_s=5.0, now=now)       # slow host
    assert fd.failed(now=now + 1) == set()
    assert fd.failed(now=now + 10) == {0, 1, 2, 3}
    fd.heartbeat(0, now=now + 8)
    assert 1 in fd.failed(now=now + 10)
    assert 0 not in fd.failed(now=now + 10)
    assert fd.stragglers() == {3}

    plan = elastic_plan(4, alive={0, 1, 2})
    assert plan["action"] == "reshard"
    assert plan["new_dp"] == 2
    plan = elastic_plan(4, alive={0, 1, 2, 3})
    assert plan["action"] == "resume"


def test_shard_balancing_midas_beats_rr():
    rng = np.random.default_rng(1)
    sizes = (rng.zipf(1.3, 400) * 1000).tolist()
    cv = {}
    for policy in ("round_robin", "hash", "midas"):
        a = assign_shards(sizes, 8, policy=policy)
        cv[policy] = host_load_cv(sizes, a, 8)
    assert cv["midas"] < cv["hash"]


def test_router_affinity_and_steering():
    r = MidasRouter(replicas=4, d=2, delta_l=2.0, f_max=1.0)
    # same session routes to the same replica (affinity)
    t1, _, _ = r.route(123, now_ms=0.0)
    t2, _, _ = r.route(123, now_ms=1.0)
    assert t1 == t2
    # overload the primary of session 7 -> steering kicks in
    r2 = MidasRouter(replicas=4, d=2, delta_l=2.0, f_max=1.0, pin_ms=0.0)
    feas = r2._feasible(7)
    r2.replicas[feas[0]].queue_len = 50.0
    for _ in range(10):
        r2.ingest_telemetry()
    target, steered, _ = r2.route(7, now_ms=10.0)
    assert steered and target != feas[0]


def test_router_prefix_cache_and_invalidation():
    r = MidasRouter(replicas=2, prefix_cache=True)
    _, _, h1 = r.route(1, 0.0, prefix_hash=99)
    _, _, h2 = r.route(2, 1.0, prefix_hash=99)
    assert not h1 and h2
    r.invalidate_prefix(99)
    _, _, h3 = r.route(3, 2.0, prefix_hash=99)
    assert not h3


def test_trainer_end_to_end_with_restart(tmp_path):
    """Train, checkpoint, 'crash', resume — loss stream continues."""
    from repro.config import RunConfig
    from repro.train.trainer import Trainer, TrainerConfig
    cfg = get_smoke_arch("smollm-360m")
    run = RunConfig(arch="smollm-360m")
    tc = TrainerConfig(steps=6, batch=2, seq=32, ckpt_dir=str(tmp_path),
                       ckpt_every=3, log_every=100)
    tr = Trainer(cfg, run, tc, log_fn=lambda s: None)
    state = tr.train()
    assert int(state.step) == 6
    # resume: a fresh trainer picks up from the step-6 checkpoint
    tc2 = TrainerConfig(steps=8, batch=2, seq=32, ckpt_dir=str(tmp_path),
                        ckpt_every=100, log_every=100)
    tr2 = Trainer(cfg, run, tc2, log_fn=lambda s: None)
    st2 = tr2.init_or_resume()
    assert int(st2.step) == 6
    final = tr2.train(st2)
    assert int(final.step) == 8
