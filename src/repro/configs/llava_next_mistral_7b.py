"""LLaVA-NeXT (Mistral-7B backbone) — VLM with anyres tiling.
Backbone only per spec: the vision tower is a stub; ``input_specs()``
supplies precomputed patch embeddings prepended to the token stream.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.config import ArchConfig, register_arch

FULL = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    rope_theta=1000000.0,
    norm="rmsnorm",
    act="silu",
    frontend="vlm_patches",
    frontend_tokens=576,          # 24x24 CLIP-ViT-L/14 base-tile patches
    notes="long_500k skipped: pure full attention (quadratic).",
)

SMOKE = ArchConfig(
    name="llava-next-mistral-7b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    norm="rmsnorm",
    act="silu",
    frontend="vlm_patches",
    frontend_tokens=16,
)

register_arch(FULL, SMOKE)
