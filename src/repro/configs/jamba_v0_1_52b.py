"""Jamba-v0.1-52B — hybrid Mamba+attention (1:7 interleave), MoE 16e top-2
every other layer. [arXiv:2403.19887; hf]

Sub-quadratic (attention only every 8th layer) -> long_500k applies.
"""
from repro.config import ArchConfig, MambaConfig, MoEConfig, register_arch

FULL = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    norm="rmsnorm",
    act="silu",
    moe=MoEConfig(num_experts=16, experts_per_token=2, d_ff_expert=14336,
                  router="midas", midas_d=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    attn_every=8,                 # 1 attention layer per 8 (1:7 attn:mamba)
    moe_every=2,                  # MoE FFN every other layer
    applicable_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

SMOKE = ArchConfig(
    name="jamba-v0.1-52b-smoke",
    family="hybrid",
    num_layers=8,                 # one full attn_every period
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    norm="rmsnorm",
    act="silu",
    moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff_expert=128,
                  router="midas", midas_d=2),
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
    attn_every=8,
    moe_every=2,
    applicable_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

register_arch(FULL, SMOKE)
