"""DBRX-132B — fine-grained MoE, 16 experts top-4, GQA (kv=8).
[hf:databricks/dbrx-base; unverified]

MIDAS integration: expert dispatch uses the paper's power-of-d routing over
the top-d gate candidates with capacity-aware steering (router="midas").
"""
from repro.config import ArchConfig, MoEConfig, register_arch

FULL = ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,                   # per-expert ffn hidden
    vocab_size=100352,
    head_dim=128,
    rope_theta=500000.0,
    norm="layernorm",
    act="silu",
    moe=MoEConfig(num_experts=16, experts_per_token=4, d_ff_expert=10752,
                  router="midas", midas_d=2),
    notes="long_500k skipped: pure full attention (quadratic).",
)

SMOKE = ArchConfig(
    name="dbrx-132b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    norm="layernorm",
    act="silu",
    moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff_expert=128,
                  router="midas", midas_d=2),
)

register_arch(FULL, SMOKE)
