"""StarCoder2-3B — dense decoder, GQA (kv=2), RoPE. [arXiv:2402.19173; hf]"""
from repro.config import ArchConfig, register_arch

FULL = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    head_dim=128,
    rope_theta=999999.0,          # starcoder2 long-context rope base
    norm="layernorm",
    act="gelu_plain",             # 4x non-gated MLP
    qkv_bias=True,
    notes="long_500k skipped: pure full attention (quadratic).",
)

SMOKE = ArchConfig(
    name="starcoder2-3b-smoke",
    family="dense",
    num_layers=2,
    d_model=96,
    num_heads=6,
    num_kv_heads=2,
    d_ff=384,
    vocab_size=256,
    head_dim=16,
    rope_theta=999999.0,
    norm="layernorm",
    act="gelu_plain",
    qkv_bias=True,
)

register_arch(FULL, SMOKE)
