"""Falcon-Mamba-7B — pure Mamba-1 (attention-free SSM), 64 layers.
[arXiv:2410.05355; unverified]

Attention-free -> O(1) decode state, sub-quadratic -> long_500k applies.
"""
from repro.config import ArchConfig, MambaConfig, register_arch

FULL = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,                  # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,                       # no FFN: mamba block is the whole layer
    vocab_size=65024,
    norm="rmsnorm",
    act="silu",
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    applicable_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

SMOKE = ArchConfig(
    name="falcon-mamba-7b-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=256,
    norm="rmsnorm",
    act="silu",
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
    applicable_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

register_arch(FULL, SMOKE)
