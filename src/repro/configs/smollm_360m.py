"""SmolLM-360M — llama-arch small dense, GQA (kv=5).
[hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.config import ArchConfig, register_arch

FULL = ArchConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    head_dim=64,
    rope_theta=10000.0,
    tie_embeddings=True,
    norm="rmsnorm",
    act="silu",
    notes="long_500k skipped: pure full attention (quadratic).",
)

SMOKE = ArchConfig(
    name="smollm-360m-smoke",
    family="dense",
    num_layers=3,
    d_model=60,
    num_heads=3,
    num_kv_heads=1,
    d_ff=160,
    vocab_size=256,
    head_dim=20,
    tie_embeddings=True,
    norm="rmsnorm",
    act="silu",
)

register_arch(FULL, SMOKE)
