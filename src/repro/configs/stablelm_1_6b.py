"""StableLM-2-1.6B — dense, MHA (kv=32), LayerNorm, SiLU-gated MLP.
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.config import ArchConfig, register_arch

FULL = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    head_dim=64,
    rope_theta=10000.0,
    norm="layernorm",
    act="silu",
    qkv_bias=True,
    notes="long_500k skipped: pure full attention (quadratic).",
)

SMOKE = ArchConfig(
    name="stablelm-1.6b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=176,
    vocab_size=256,
    head_dim=16,
    norm="layernorm",
    act="silu",
    qkv_bias=True,
)

register_arch(FULL, SMOKE)
