"""Architecture configs (one module per assigned architecture).

Importing this package registers every architecture into
``repro.config.ARCH_REGISTRY`` (full config) and the smoke registry
(reduced config of the same family, used by CPU smoke tests).
"""
from repro.configs import (  # noqa: F401
    starcoder2_3b,
    gemma2_2b,
    stablelm_1_6b,
    smollm_360m,
    musicgen_large,
    dbrx_132b,
    qwen3_moe_235b_a22b,
    jamba_v0_1_52b,
    llava_next_mistral_7b,
    falcon_mamba_7b,
)
