"""MusicGen-Large — decoder-only transformer over EnCodec tokens.
Backbone only; the EnCodec frontend is a stub providing precomputed frame
embeddings per spec. [arXiv:2306.05284; hf]"""
from repro.config import ArchConfig, register_arch

FULL = ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,              # EnCodec codebook size
    head_dim=64,
    norm="layernorm",
    act="gelu_plain",
    frontend="audio_frames",
    notes="long_500k skipped: pure full attention (quadratic).",
)

SMOKE = ArchConfig(
    name="musicgen-large-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=256,
    vocab_size=128,
    head_dim=16,
    norm="layernorm",
    act="gelu_plain",
    frontend="audio_frames",
)

register_arch(FULL, SMOKE)
