"""Gemma2-2B — dense, GQA (kv=4), alternating local/global attention,
logit softcapping, tied embeddings. [arXiv:2408.00118; hf]"""
from repro.config import ArchConfig, register_arch

FULL = ArchConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    d_ff=9216,
    vocab_size=256000,
    head_dim=256,                 # gemma2 decouples head_dim from d_model
    rope_theta=10000.0,
    window_size=4096,             # local layers use 4k sliding window
    alt_local_global=True,
    logit_softcap=50.0,           # attention logit softcap
    final_softcap=30.0,           # final LM-head logit softcap
    tie_embeddings=True,
    norm="rmsnorm",
    act="gelu",                   # GeGLU
    notes=("long_500k skipped: alternating stack still contains global "
           "full-attention layers (not sub-quadratic)."),
)

SMOKE = ArchConfig(
    name="gemma2-2b-smoke",
    family="dense",
    num_layers=4,                 # keep even so local/global alternation shows
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=256,
    head_dim=32,
    window_size=16,
    alt_local_global=True,
    logit_softcap=50.0,
    final_softcap=30.0,
    tie_embeddings=True,
    norm="rmsnorm",
    act="gelu",
)

register_arch(FULL, SMOKE)
