"""Qwen3-MoE-235B-A22B — 128 experts top-8, GQA (kv=4), 94 layers.
[hf:Qwen/Qwen3-30B-A3B; hf]

The 128-expert regime is where the paper's balls-into-bins analysis bites:
max-load gap ln(ln 128)/ln d. router="midas" applies power-of-d dispatch.
"""
from repro.config import ArchConfig, MoEConfig, register_arch

FULL = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,                    # per-expert ffn hidden (fine-grained)
    vocab_size=151936,
    head_dim=128,
    rope_theta=1000000.0,
    norm="rmsnorm",
    act="silu",
    moe=MoEConfig(num_experts=128, experts_per_token=8, d_ff_expert=1536,
                  router="midas", midas_d=2),
    notes="long_500k skipped: pure full attention (quadratic).",
)

SMOKE = ArchConfig(
    name="qwen3-moe-235b-a22b-smoke",
    family="moe",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=64,
    vocab_size=256,
    head_dim=16,
    norm="rmsnorm",
    act="silu",
    moe=MoEConfig(num_experts=8, experts_per_token=2, d_ff_expert=64,
                  router="midas", midas_d=2),
)

register_arch(FULL, SMOKE)
