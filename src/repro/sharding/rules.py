"""Logical-axis sharding rules (MaxText-style).

Model code annotates parameters and activations with *logical* axis names
("embed", "mlp", "heads", "expert", "batch", ...).  A rule-set maps logical
names to mesh axes.  Different rule-sets are the main §Perf hillclimb lever:
swapping a rule-set re-shards the whole model without touching model code.

Rules are applied through a context (set by the launcher / dryrun); with no
context active, ``shard()`` is a no-op so single-device smoke tests run
unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# ---------------------------------------------------------------------------
# Rule sets
# ---------------------------------------------------------------------------

# Training: DP over (pod, data) for the batch; FSDP shards weights' embed dim
# over (pod, data); TP over model for heads / mlp / vocab / experts.
TRAIN_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
    "embed": None,
    "embed_fsdp": ("pod", "data"),     # FSDP dim of 2D weights
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "expert": "model",
    "expert_embed": ("pod", "data"),   # FSDP dim of expert weights
    "expert_mlp": None,
    "mamba_inner": "model",
    "conv": None,
    "state": None,
    "layers": None,
    "cache_seq": None,
    "cache_heads": "model",
}

# Serving (decode/prefill): batch over (pod, data), TP over model, weights
# replicated over DP axes (no per-step all-gathers on the latency path).
SERVE_RULES: Dict[str, MeshAxes] = dict(TRAIN_RULES)
SERVE_RULES.update({
    "embed_fsdp": None,
    "expert_embed": None,
})

# Long-context decode (batch=1): sequence-parallel KV cache over data.
LONG_RULES: Dict[str, MeshAxes] = dict(SERVE_RULES)
LONG_RULES.update({
    "batch": "pod",
    "cache_seq": "data",
    "embed_fsdp": None,
})

# Serving for very large models that do not fit TP-only: 2D weight sharding.
SERVE_2D_RULES: Dict[str, MeshAxes] = dict(SERVE_RULES)
SERVE_2D_RULES.update({
    "embed_fsdp": "data",
    "expert_embed": "data",
})

# §Perf variants -----------------------------------------------------------
# head_dim TP: for archs whose head counts don't divide the model axis
# (smollm 15H/5KV, starcoder2 24H/2KV, gemma2 8H/4KV) shard the head_dim
# instead — QK/AV contractions pick up a psum but attention stops being
# replicated 16x.
SERVE_HD_RULES: Dict[str, MeshAxes] = dict(SERVE_RULES)
SERVE_HD_RULES.update({"heads": None, "kv_heads": None,
                       "head_dim": "model", "cache_heads": None})

TRAIN_HD_RULES: Dict[str, MeshAxes] = dict(TRAIN_RULES)
TRAIN_HD_RULES.update({"heads": None, "kv_heads": None,
                       "head_dim": "model", "cache_heads": None})

# KV-cache sequence sharding over the model axis for decode when kv_heads
# can't fill it (dbrx kv=8, qwen3 kv=4): flash-decoding style — partial
# softmax per shard, psum-logsumexp combine (XLA derives it from the
# sharded softmax).
SERVE_KVSEQ_RULES: Dict[str, MeshAxes] = dict(SERVE_2D_RULES)
SERVE_KVSEQ_RULES.update({"cache_seq": "model", "cache_heads": None})

# Expert-resident training: no FSDP on expert weights (they stay sharded
# over the model axis only) — trades optimizer-state memory for zero
# per-layer expert all-gathers.
TRAIN_EP_RESIDENT_RULES: Dict[str, MeshAxes] = dict(TRAIN_RULES)
TRAIN_EP_RESIDENT_RULES.update({"expert_embed": None})

# Weight-stationary MoE decode: KV-seq over model (flash-decoding combine),
# expert weights resident as f-chunks over data (partial_f path in
# moe.py — token batch is tiny at decode, so it is all-gathered and the
# down-proj partials psum'd instead of moving hundreds of GB of experts).
SERVE_DECODE_MOE_RULES: Dict[str, MeshAxes] = dict(SERVE_KVSEQ_RULES)
SERVE_DECODE_MOE_RULES.update({"expert_embed": None, "expert_mlp": "data"})

# Context parallelism for prefill: shard the q-sequence over the model
# axis, replicate K/V (tiny: S·KV·hd per layer) — the S² compute splits
# 16-way with only the KV gather as collective.
SERVE_SEQ_RULES: Dict[str, MeshAxes] = dict(SERVE_RULES)
SERVE_SEQ_RULES.update({"seq": "model", "kv_seq": None,
                        "heads": None, "kv_heads": None})

RULE_SETS: Dict[str, Dict[str, MeshAxes]] = {
    "train": TRAIN_RULES,
    "serve": SERVE_RULES,
    "long": LONG_RULES,
    "serve_2d": SERVE_2D_RULES,
    "serve_hd": SERVE_HD_RULES,
    "train_hd": TRAIN_HD_RULES,
    "serve_kvseq": SERVE_KVSEQ_RULES,
    "serve_decode_moe": SERVE_DECODE_MOE_RULES,
    "serve_seq": SERVE_SEQ_RULES,
    "train_ep_resident": TRAIN_EP_RESIDENT_RULES,
}


class Rules:
    def __init__(self, mapping: Dict[str, MeshAxes], mesh: Optional[Mesh]):
        self.mapping = dict(mapping)
        self.mesh = mesh

    def spec(self, *logical: Optional[str],
             shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for a tensor whose dims have these logical names.

        When ``shape`` is given, shardings that do not divide the dim size
        are DROPPED (replicated) — this is what makes a fixed production
        mesh usable across archs whose head counts (15, 24, 8, ...) do not
        divide the 16-way model axis.  The roofline analysis surfaces the
        replication cost; alternate rule-sets re-shard such dims (§Perf).
        """
        axes = []
        used = set()
        for i, name in enumerate(logical):
            if name is None:
                axes.append(None)
                continue
            ax = self.mapping.get(name)
            if ax is None:
                axes.append(None)
                continue
            flat = (ax,) if isinstance(ax, str) else tuple(ax)
            # the same mesh axis may appear only once in a PartitionSpec
            flat = tuple(a for a in flat if a not in used
                         and (self.mesh is None or a in self.mesh.axis_names))
            if shape is not None and flat and self.mesh is not None:
                n = 1
                for a in flat:
                    n *= self.mesh.shape[a]
                if shape[i] % n != 0:
                    # try the largest prefix of the axis tuple that divides
                    while flat:
                        flat = flat[:-1]
                        n = 1
                        for a in flat:
                            n *= self.mesh.shape[a]
                        if flat and shape[i] % n == 0:
                            break
            used.update(flat)
            if not flat:
                axes.append(None)
            elif len(flat) == 1:
                axes.append(flat[0])
            else:
                axes.append(flat)
        return P(*axes)

    def sharding(self, *logical: Optional[str],
                 shape: Optional[Sequence[int]] = None
                 ) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*logical, shape=shape))


_ctx = threading.local()


def current_rules() -> Optional[Rules]:
    return getattr(_ctx, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    prev = current_rules()
    _ctx.rules = rules
    try:
        yield rules
    finally:
        _ctx.rules = prev


def make_rules(rule_set: str, mesh: Optional[Mesh]) -> Rules:
    return Rules(RULE_SETS[rule_set], mesh)


def shard(x, *logical: Optional[str]):
    """Constrain an activation's sharding by logical axis names (no-op when
    no rules context is active, e.g. in CPU smoke tests)."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, rules.sharding(*logical, shape=x.shape))
