"""Mixture-of-Experts layer with capacity-bounded sort-based dispatch.

Router options:
  * "topk"  — vanilla top-k gating (baseline the paper compares against:
              static placement that ignores load)
  * "midas" — the paper's power-of-d steering over top-(k+d) gate
              candidates using stale per-expert load telemetry (EWMA
              across steps, threaded through the train state exactly like
              the paper's proxy telemetry).

Dispatch is sort-free scatter into an (E, C, d) buffer (capacity
C = ceil(k·T/E · capacity_factor)); tokens over capacity are dropped, and
the drop *rate* is the metadata-hotspot analogue we benchmark: MIDAS
steering lowers it because it routes around hot experts.
"""
from __future__ import annotations

import os
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.kernels.midas_route import ops as route_ops
from repro.models.layers import Maker
from repro.sharding.rules import shard


class MoEAux(NamedTuple):
    load: jnp.ndarray        # (E,) this-batch expert token share (mean 1)
    drop_rate: jnp.ndarray   # () fraction of (token, slot) pairs dropped
    steer_rate: jnp.ndarray  # () fraction of slots steered (midas only)
    aux_loss: jnp.ndarray    # () switch-style load-balance loss (topk only)


def moe_init(mk: Maker, cfg: ArchConfig):
    mo = cfg.moe
    d, f, E = cfg.d_model, mo.d_ff_expert, mo.num_experts
    return {
        "router": mk.param((d, E), ("embed", None), fan_in=d),
        "w_gate": mk.param((E, d, f), ("expert", "expert_embed",
                                       "expert_mlp"), fan_in=d),
        "w_up": mk.param((E, d, f), ("expert", "expert_embed",
                                     "expert_mlp"), fan_in=d),
        "w_down": mk.param((E, f, d), ("expert", "expert_mlp",
                                       "expert_embed"), fan_in=f),
    }


def _positions_within_expert(flat_e: jnp.ndarray, E: int) -> jnp.ndarray:
    """pos[i] = #{j < i : e_j == e_i}, vectorized via stable sort."""
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(E))      # first idx per e
    pos_sorted = jnp.arange(n) - start[sorted_e]
    pos = jnp.zeros((n,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))
    return pos


def _dispatch(cfg: ArchConfig, gate_logits, load_ewma, T, E):
    mo = cfg.moe
    k = mo.experts_per_token
    steered = jnp.zeros((T, k), bool)
    if mo.router == "midas":
        if load_ewma is None:
            load_ewma = jnp.ones((E,), jnp.float32)
        experts, weights, steered = route_ops.midas_dispatch(
            gate_logits, load_ewma, k, mo.midas_d,
            delta_l=float(mo.midas_delta_l), f_max=mo.midas_fmax)
    else:
        experts, weights = route_ops.topk_dispatch(gate_logits, k)
    return experts, weights, steered


def moe_apply_sharded(p, cfg: ArchConfig, x: jnp.ndarray,
                      load_ewma: Optional[jnp.ndarray],
                      ) -> Tuple[jnp.ndarray, MoEAux]:
    """shard_map MoE: the production dispatch path.

    Key facts the XLA SPMD partitioner cannot prove about the einsum path:
    tokens are sharded over the DP axes and REPLICATED over the model axis,
    so every model rank can (a) compute the gate for its local tokens,
    (b) build the dispatch buffer for ITS OWN experts entirely locally
    (no cross-device scatter => kills the TB-scale all-reduces), and
    (c) combine with one small psum of the (T_loc, d) partial outputs over
    the model axis.  Expert weights are all-gathered over the FSDP axes
    explicitly when sharded there ('expert_embed'); with the
    train_ep_resident rule-set they are resident and no gather happens.
    """
    from repro.sharding.rules import current_rules

    rules = current_rules()
    mesh = rules.mesh
    mo = cfg.moe
    B, S, d = x.shape
    E, k, f = mo.num_experts, mo.experts_per_token, mo.d_ff_expert
    tp = mesh.shape["model"]
    E_loc = E // tp
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu

    x_spec = rules.spec("batch", "seq", "embed", shape=x.shape)
    p_specs = {
        "router": rules.spec("embed", None, shape=p["router"].shape),
        "w_gate": rules.spec("expert", "expert_embed", "expert_mlp",
                             shape=p["w_gate"].shape),
        "w_up": rules.spec("expert", "expert_embed", "expert_mlp",
                           shape=p["w_up"].shape),
        "w_down": rules.spec("expert", "expert_mlp", "expert_embed",
                             shape=p["w_down"].shape),
    }
    fsdp_axes = tuple(a for a in (("pod", "data") if "pod" in
                                  mesh.axis_names else ("data",))
                      if p_specs["w_gate"][1] is not None
                      and a in ((p_specs["w_gate"][1],)
                                if isinstance(p_specs["w_gate"][1], str)
                                else tuple(p_specs["w_gate"][1])))
    mlp_ax = p_specs["w_gate"][2]
    partial_f_axes = tuple((mlp_ax,) if isinstance(mlp_ax, str)
                           else (mlp_ax or ()))
    partial_f = bool(partial_f_axes)

    def local(px, xl, load):
        # xl: (B_loc, S, d) local tokens (replicated over model)
        Bl, Sl, _ = xl.shape
        Tl = Bl * Sl
        xt = xl.reshape(Tl, d)
        if partial_f:
            # weight-stationary path: replicate the (tiny) token batch
            # across the f-sharding axes so partial-weight results can be
            # psum'd soundly
            xt = jax.lax.all_gather(xt, partial_f_axes, axis=0, tiled=True)
            Tl = xt.shape[0]
        logits = jnp.einsum("td,de->te", xt, px["router"]).astype(
            jnp.float32)
        experts, weights, steered = _dispatch(cfg, logits, load, Tl, E)

        C = max(int(-(-k * Tl // E) * mo.capacity_factor), 1)
        C = min(C, Tl)
        flat_e = experts.reshape(Tl * k)
        flat_w = weights.reshape(Tl * k)
        pos = _positions_within_expert(flat_e, E)
        keep = pos < C
        rank = jax.lax.axis_index("model")
        mine = (flat_e // E_loc) == rank
        e_loc = jnp.where(keep & mine, flat_e - rank * E_loc, E_loc)
        tok_idx = jnp.repeat(jnp.arange(Tl), k)

        buf = jnp.zeros((E_loc, C, d), xt.dtype)
        buf = buf.at[e_loc, pos].add(xt[tok_idx], mode="drop")

        wg, wu, wd = px["w_gate"], px["w_up"], px["w_down"]
        if partial_f:
            # weight-stationary decode path (rule-sets sharding
            # 'expert_mlp' over the DP axes): experts stay RESIDENT as
            # f-chunks; gated act is elementwise in f so g/u need no
            # collective; only the (E_loc, C, d) down-proj partials are
            # psum'd — tiny when C is a decode-sized capacity.  NOTE:
            # tokens were all-gathered over the DP axes up front (see
            # above), so every rank holds the SAME tokens and the psum is
            # sound — partial-weight math with rank-distinct tokens is
            # NOT (that failed the oracle check and was removed).
            g = jnp.einsum("ecd,edf->ecf", buf, wg)
            u = jnp.einsum("ecd,edf->ecf", buf, wu)
            out = jax.lax.psum(jnp.einsum("ecf,efd->ecd", act(g) * u, wd),
                               partial_f_axes)
        else:
            for ax in fsdp_axes:        # explicit FSDP gather (bf16)
                wg = jax.lax.all_gather(wg, ax, axis=1, tiled=True)
                wu = jax.lax.all_gather(wu, ax, axis=1, tiled=True)
                wd = jax.lax.all_gather(wd, ax, axis=2, tiled=True)
            g = jnp.einsum("ecd,edf->ecf", buf, wg)
            u = jnp.einsum("ecd,edf->ecf", buf, wu)
            out = jnp.einsum("ecf,efd->ecd", act(g) * u, wd)

        gathered = out[jnp.minimum(e_loc, E_loc - 1), pos]
        gathered = jnp.where((keep & mine)[:, None], gathered, 0.0)
        y = (gathered.astype(jnp.float32) * flat_w[:, None]
             ).reshape(Tl, k, d).sum(axis=1)
        y = jax.lax.psum(y.astype(xl.dtype), "model")
        if partial_f:
            idx = jnp.zeros((), jnp.int32)
            for ax in partial_f_axes:
                idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
            y = jax.lax.dynamic_slice_in_dim(y, idx * Bl * Sl, Bl * Sl,
                                             axis=0)

        axes = tuple(mesh.axis_names)      # replicate stats on all devices
        load_out = jax.lax.pmean(route_ops.expert_load(experts, E), axes)
        drop = jax.lax.pmean(1.0 - keep.mean(), axes)
        steer = jax.lax.pmean(steered.mean(), axes)
        probs = jax.nn.softmax(logits, axis=-1)
        aux_l = E * jnp.sum(load_out / E * jax.lax.pmean(
            probs.mean(axis=0), axes))
        return (y.reshape(Bl, Sl, d),
                MoEAux(load=load_out, drop_rate=drop, steer_rate=steer,
                       aux_loss=aux_l))

    from jax.sharding import PartitionSpec as P
    out_specs = (x_spec, MoEAux(load=P(), drop_rate=P(), steer_rate=P(),
                                aux_loss=P()))
    if hasattr(jax, "shard_map"):
        mapped = jax.shard_map(
            local, mesh=mesh,
            in_specs=(p_specs, x_spec, P()),
            out_specs=out_specs,
            check_vma=False,
        )
    else:  # jax < 0.5: pre-rename API (check_rep) under jax.experimental
        from jax.experimental.shard_map import shard_map as _shard_map
        mapped = _shard_map(
            local, mesh=mesh,
            in_specs=(p_specs, x_spec, P()),
            out_specs=out_specs,
            check_rep=False,
        )
    y, aux = mapped(p, x, load_ewma if load_ewma is not None
                    else jnp.ones((E,), jnp.float32))
    return y, aux


def moe_apply(p, cfg: ArchConfig, x: jnp.ndarray,
              load_ewma: Optional[jnp.ndarray] = None,
              ) -> Tuple[jnp.ndarray, MoEAux]:
    """x: (B, S, d).  load_ewma: (E,) stale telemetry (midas router)."""
    from repro.sharding.rules import current_rules
    rules = current_rules()
    if (rules is not None and rules.mesh is not None
            and cfg.moe.num_experts % rules.mesh.shape.get("model", 1) == 0
            and os.environ.get("REPRO_MOE_EINSUM") != "1"):
        return moe_apply_sharded(p, cfg, x, load_ewma)
    mo = cfg.moe
    B, S, d = x.shape
    E, k, f = mo.num_experts, mo.experts_per_token, mo.d_ff_expert
    T = B * S
    xt = x.reshape(T, d)

    gate_logits = jnp.einsum("td,de->te", xt, p["router"]).astype(
        jnp.float32)
    experts, weights, steered = _dispatch(cfg, gate_logits, load_ewma, T, E)

    # ---- capacity-bounded dispatch -----------------------------------
    C = max(int(-(-k * T // E) * mo.capacity_factor), 1)
    C = min(C, T)
    flat_e = experts.reshape(T * k)
    flat_w = weights.reshape(T * k)
    pos = _positions_within_expert(flat_e, E)
    keep = pos < C
    e_or_drop = jnp.where(keep, flat_e, E)                 # OOB => dropped
    tok_idx = jnp.repeat(jnp.arange(T), k)

    buf = jnp.zeros((E, C, d), xt.dtype)
    buf = buf.at[e_or_drop, pos].add(xt[tok_idx], mode="drop")
    buf = shard(buf, "expert", None, "embed")

    # ---- expert FFN (gated) -------------------------------------------
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = shard(act(g) * u, "expert", None, "expert_mlp")
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out = shard(out, "expert", None, "embed")

    # ---- combine -------------------------------------------------------
    gathered = out[jnp.minimum(e_or_drop, E - 1), pos]     # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    wsum = (gathered.astype(jnp.float32)
            * flat_w[:, None]).reshape(T, k, d).sum(axis=1)
    y = wsum.astype(x.dtype).reshape(B, S, d)
    y = shard(y, "batch", "seq", "embed")

    # ---- aux -------------------------------------------------------------
    load = route_ops.expert_load(experts, E)
    drop_rate = 1.0 - keep.mean()
    # switch-transformer aux loss (only meaningful for the topk baseline)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    frac_tokens = load / E
    frac_probs = probs.mean(axis=0)
    aux_loss = E * jnp.sum(frac_tokens * frac_probs)
    return y, MoEAux(load=load, drop_rate=drop_rate,
                     steer_rate=steered.mean(), aux_loss=aux_loss)


def update_load_ewma(load_ewma: jnp.ndarray, batch_load: jnp.ndarray,
                     alpha: float = 0.2) -> jnp.ndarray:
    """Paper's fast-loop EWMA over (stale) telemetry."""
    return (1.0 - alpha) * load_ewma + alpha * batch_load
