"""Modality frontends (STUBS per spec).

The assigned [audio]/[vlm] entries specify the transformer BACKBONE only;
``input_specs()`` provides precomputed frame/patch embeddings.  These stubs
add the minimal glue: sinusoidal positions for audio frames and a learned
projector for vision patches.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models.layers import Maker
from repro.sharding.rules import shard


def sinusoidal_positions(S: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    out = jnp.zeros((S, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang[:, : (d - d // 2)]))
    return out


def frontend_init(mk: Maker, cfg: ArchConfig):
    if cfg.frontend == "vlm_patches":
        # llava-style multimodal projector (single linear here; the vision
        # tower itself is stubbed away upstream)
        return {"proj": mk.param((cfg.d_model, cfg.d_model),
                                 ("embed", "embed_fsdp"), fan_in=cfg.d_model)}
    return {}


def audio_frontend(cfg: ArchConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, S, d_model) precomputed EnCodec frame embeddings."""
    S, d = frames.shape[1], frames.shape[2]
    x = frames + sinusoidal_positions(S, d).astype(frames.dtype)[None]
    return shard(x, "batch", "seq", "embed")


def vlm_frontend(p, cfg: ArchConfig, patches: jnp.ndarray,
                 token_embeds: jnp.ndarray) -> jnp.ndarray:
    """patches: (B, P, d_model) precomputed patch embeddings; prepended to
    the text token embeddings after the projector."""
    proj = jnp.einsum("bpd,de->bpe", patches, p["proj"])
    x = jnp.concatenate([proj.astype(token_embeds.dtype), token_embeds],
                        axis=1)
    return shard(x, "batch", "seq", "embed")
