"""Mamba-1 block (selective SSM) for falcon-mamba and Jamba hybrid layers."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.kernels.ssm_scan import ops as ssm_ops
from repro.models.layers import Maker
from repro.sharding.rules import shard


def _dims(cfg: ArchConfig):
    m = cfg.mamba
    di = m.expand * cfg.d_model
    dt_rank = m.dt_rank or -(-cfg.d_model // 16)
    return di, m.d_state, m.d_conv, dt_rank


def mamba_init(mk: Maker, cfg: ArchConfig):
    d = cfg.d_model
    di, st, dc, dtr = _dims(cfg)
    return {
        "in_proj": mk.param((d, 2 * di), ("embed_fsdp", "mamba_inner"),
                            fan_in=d),
        "conv_w": mk.param((di, dc), ("mamba_inner", "conv"), fan_in=dc),
        "conv_b": mk.param((di,), ("mamba_inner",), init="zeros"),
        "x_proj": mk.param((di, dtr + 2 * st), ("mamba_inner", None),
                           fan_in=di),
        "dt_w": mk.param((dtr, di), (None, "mamba_inner"), fan_in=dtr),
        "dt_b": mk.param((di,), ("mamba_inner",), init="ones"),
        "A_log": mk.param((di, st), ("mamba_inner", "state"),
                          init="mamba_a"),
        "D": mk.param((di,), ("mamba_inner",), init="ones"),
        "out_proj": mk.param((di, d), ("mamba_inner", "embed_fsdp"),
                             fan_in=di),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                 ) -> jnp.ndarray:
    """Depthwise causal conv over seq.  x: (B, S, DI); w: (DI, K)."""
    K = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[:, i] for i in range(K))
    return out + b


def _ssm_inputs(p, cfg: ArchConfig, xc: jnp.ndarray):
    di, st, _, dtr = _dims(cfg)
    proj = jnp.einsum("...d,dk->...k", xc, p["x_proj"])
    dt_r, Bm, Cm = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("...r,rd->...d", dt_r, p["dt_w"])
                         + p["dt_b"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    return dt, A, Bm, Cm


def mamba_apply(p, cfg: ArchConfig, x: jnp.ndarray,
                return_state: bool = False):
    """Full-sequence path.  x: (B, S, d_model)."""
    di, st, dc, _ = _dims(cfg)
    xz = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    xc_pre, z = jnp.split(xz, 2, axis=-1)
    xc_pre = shard(xc_pre, "batch", "seq", "mamba_inner")
    xc = jax.nn.silu(_causal_conv(xc_pre, p["conv_w"], p["conv_b"]))
    dt, A, Bm, Cm = _ssm_inputs(p, cfg, xc)
    y, h = ssm_ops.selective_scan(xc, dt, A, Bm, Cm, p["D"])
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    out = shard(out, "batch", "seq", "embed")
    if return_state:
        # decode-ready cache: SSM state + last (d_conv-1) pre-activation taps
        conv_tail = xc_pre[:, -(dc - 1):, :]
        return out, {"h": h, "conv": conv_tail}
    return out


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    di, st, dc, _ = _dims(cfg)
    return {
        "h": jnp.zeros((batch, di, st), jnp.float32),
        "conv": jnp.zeros((batch, dc - 1, di), dtype),
    }


def mamba_decode(p, cfg: ArchConfig, x: jnp.ndarray, cache: dict
                 ) -> Tuple[jnp.ndarray, dict]:
    """Single-token step.  x: (B, 1, d_model)."""
    di, st, dc, _ = _dims(cfg)
    xz = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    xc, z = jnp.split(xz, 2, axis=-1)
    xc = xc[:, 0]                                    # (B, DI)
    window = jnp.concatenate([cache["conv"],
                              xc[:, None].astype(cache["conv"].dtype)],
                             axis=1)                 # (B, dc, DI)
    conv = (jnp.einsum("bkd,dk->bd", window.astype(xc.dtype),
                       p["conv_w"]) + p["conv_b"])
    xcs = jax.nn.silu(conv)
    dt, A, Bm, Cm = _ssm_inputs(p, cfg, xcs)
    y, h = ssm_ops.selective_step(xcs, dt, A, Bm, Cm, p["D"], cache["h"])
    y = y * jax.nn.silu(z[:, 0])
    out = jnp.einsum("bk,kd->bd", y, p["out_proj"])[:, None]
    return out, {"h": h, "conv": window[:, 1:]}
