"""Core transformer layers: norms, RoPE, GQA attention, MLPs, embeddings.

Parameters are plain dict pytrees.  Every ``*_init`` function takes a
:class:`Maker`, which produces either real arrays (init mode), logical-axis
tuples (axes mode) or ShapeDtypeStructs (shape mode) from the SAME code
path — so sharding specs can never drift from the real parameter tree.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.sharding.rules import shard


class Maker:
    """Single-source param factory: arrays / logical axes / shapes."""

    def __init__(self, key=None, dtype=jnp.float32, mode: str = "init"):
        assert mode in ("init", "axes", "shape")
        self.key = key
        self.dtype = dtype
        self.mode = mode
        self._n = 0

    def param(self, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
              fan_in: Optional[int] = None, init: str = "normal"):
        assert len(shape) == len(axes), (shape, axes)
        if self.mode == "axes":
            return axes
        if self.mode == "shape":
            return jax.ShapeDtypeStruct(shape, self.dtype)
        self._n += 1
        k = jax.random.fold_in(self.key, self._n)
        if init == "zeros":
            return jnp.zeros(shape, self.dtype)
        if init == "ones":
            return jnp.ones(shape, self.dtype)
        if init == "mamba_a":
            # S4/Mamba A init: -log of 1..d_state broadcast over channels
            d_state = shape[-1]
            a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                         shape[:-1] + (1,))
            return jnp.log(a).astype(self.dtype)
        scale = 1.0 / (fan_in or shape[0]) ** 0.5
        return (jax.random.normal(k, shape, jnp.float32) * scale
                ).astype(self.dtype)


# ---------------------------------------------------------------------------
# Normalization (fp32 accumulation)
# ---------------------------------------------------------------------------


def norm_init(mk: Maker, d: int, kind: str):
    p = {"scale": mk.param((d,), ("embed",), init="ones")}
    if kind == "layernorm":
        p["bias"] = mk.param((d,), ("embed",), init="zeros")
    return p


def norm_apply(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(
            jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return theta ** (-jnp.arange(0, head_dim, 2, jnp.float32) / head_dim)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    if theta <= 0.0:
        return x
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0.0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def attn_init(mk: Maker, cfg: ArchConfig):
    d, H, KV = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    p = {
        "wq": mk.param((d, H, hd), ("embed_fsdp", "heads", "head_dim"),
                       fan_in=d),
        "wk": mk.param((d, KV, hd), ("embed_fsdp", "kv_heads", "head_dim"),
                       fan_in=d),
        "wv": mk.param((d, KV, hd), ("embed_fsdp", "kv_heads", "head_dim"),
                       fan_in=d),
        "wo": mk.param((H, hd, d), ("heads", "head_dim", "embed_fsdp"),
                       fan_in=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = mk.param((H, hd), ("heads", "head_dim"), init="zeros")
        p["bk"] = mk.param((KV, hd), ("kv_heads", "head_dim"), init="zeros")
        p["bv"] = mk.param((KV, hd), ("kv_heads", "head_dim"), init="zeros")
    return p


def _qkv(p, cfg: ArchConfig, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(p, cfg: ArchConfig, x: jnp.ndarray, *, is_local: bool,
               positions: Optional[jnp.ndarray] = None,
               return_kv: bool = False):
    """Full-sequence (train/prefill) attention.  x: (B, S, d_model)."""
    from repro.kernels.flash_attention import ops as fa
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, cfg, x, positions)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    # context parallelism (rule-sets mapping seq->model) keeps q sharded
    # over the sequence and replicates K/V ("kv_seq"), so attention needs
    # no S^2 collective — only the cheap KV gather.
    k = shard(k, "batch", "kv_seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "kv_seq", "kv_heads", "head_dim")
    window = cfg.window_size if (is_local and cfg.window_size > 0) else 0
    out = fa.flash_attention(q, k, v, causal=True, window=window,
                             softcap=cfg.logit_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    y = shard(y, "batch", "seq", "embed")
    if return_kv:
        return y, {"k": k, "v": v}
    return y


def attn_decode(p, cfg: ArchConfig, x: jnp.ndarray, kv_cache, pos,
                *, is_local: bool):
    """Single-token decode.  x: (B, 1, d); kv_cache: dict(k, v) with
    (B, S_max, KV, hd); pos: (B,) current positions (tokens written at pos).
    """
    from repro.kernels.decode_attention import ops as da
    q, k, v = _qkv(p, cfg, x, pos[:, None])
    kc = jax.lax.dynamic_update_slice_in_dim(
        kv_cache["k"], k.astype(kv_cache["k"].dtype), pos[0], axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(
        kv_cache["v"], v.astype(kv_cache["v"].dtype), pos[0], axis=1)
    kc = shard(kc, "batch", "cache_seq", "cache_heads", "head_dim")
    vc = shard(vc, "batch", "cache_seq", "cache_heads", "head_dim")
    window = cfg.window_size if (is_local and cfg.window_size > 0) else 0
    out = da.decode_attention(q[:, 0], kc, vc, pos, window=window,
                              softcap=cfg.logit_softcap)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None, :]
    return y, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_init(mk: Maker, cfg: ArchConfig):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "gelu_plain":
        return {
            "w1": mk.param((d, f), ("embed_fsdp", "mlp"), fan_in=d),
            "b1": mk.param((f,), ("mlp",), init="zeros"),
            "w2": mk.param((f, d), ("mlp", "embed_fsdp"), fan_in=f),
            "b2": mk.param((d,), ("embed",), init="zeros"),
        }
    return {
        "w_gate": mk.param((d, f), ("embed_fsdp", "mlp"), fan_in=d),
        "w_up": mk.param((d, f), ("embed_fsdp", "mlp"), fan_in=d),
        "w_down": mk.param((f, d), ("mlp", "embed_fsdp"), fan_in=f),
    }


def mlp_apply(p, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.act == "gelu_plain":
        h = jnp.einsum("bsd,df->bsf", x, p["w1"]) + p["b1"]
        h = shard(h, "batch", "seq", "mlp")
        h = jax.nn.gelu(h)
        y = jnp.einsum("bsf,fd->bsd", h, p["w2"]) + p["b2"]
        return shard(y, "batch", "seq", "embed")
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = shard(act(g) * u, "batch", "seq", "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return shard(y, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embed_init(mk: Maker, cfg: ArchConfig):
    p = {"tokens": mk.param((cfg.vocab_size, cfg.d_model),
                            ("vocab", "embed_fsdp"), fan_in=1)}
    if not cfg.tie_embeddings:
        p["head"] = mk.param((cfg.d_model, cfg.vocab_size),
                             ("embed_fsdp", "vocab"), fan_in=cfg.d_model)
    return p


def embed_apply(p, cfg: ArchConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    x = p["tokens"][tokens]
    if cfg.name.startswith("gemma2"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return shard(x, "batch", "seq", "embed")


def lm_head_apply(p, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["tokens"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["head"])
    logits = softcap(logits, cfg.final_softcap)
    return shard(logits, "batch", "seq", "vocab")
