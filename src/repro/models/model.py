"""Architecture builder: one code path for all 10 assigned families.

A model is a stack of ``num_blocks`` identical *blocks* scanned with
``lax.scan`` (keeps HLO size O(1) in depth — essential for the 94-layer
dry-runs).  A block is a short pattern of layers:

  dense/moe/audio/vlm : [attn]            (gemma2: [attn-local, attn-global])
  ssm                 : [mamba]
  hybrid (jamba)      : 8 layers, attention at position 7, MoE on odd
                        positions (1:7 attn:mamba, MoE every other layer)

MoE layers carry per-expert load telemetry (EWMA) through the step — the
MIDAS stale-telemetry loop — threaded as explicit state.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models import layers as L
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models import stubs


class LayerSpec(NamedTuple):
    kind: str        # "attn" | "mamba"
    is_moe: bool
    is_local: bool


def block_pattern(cfg: ArchConfig) -> List[LayerSpec]:
    if cfg.family == "ssm":
        return [LayerSpec("mamba", False, False)]
    if cfg.family == "hybrid":
        out = []
        for i in range(cfg.attn_every):
            kind, is_moe = cfg.layer_kind(i)
            out.append(LayerSpec(kind, is_moe, False))
        return out
    if cfg.alt_local_global:
        return [LayerSpec("attn", cfg.moe is not None, True),
                LayerSpec("attn", cfg.moe is not None, False)]
    return [LayerSpec("attn", cfg.moe is not None,
                      cfg.window_size > 0)]


def _scan_unroll():
    # REPRO_SCAN_FULL_UNROLL=1 removes the layer while-loop so XLA cost
    # analysis sees every block (dry-run cost compiles only — see
    # launch/dryrun._cost_extrapolated).
    return bool(os.environ.get("REPRO_SCAN_FULL_UNROLL"))


def num_blocks(cfg: ArchConfig) -> int:
    pat = block_pattern(cfg)
    assert cfg.num_layers % len(pat) == 0, (cfg.name, cfg.num_layers,
                                            len(pat))
    return cfg.num_layers // len(pat)


def _layer_has_ffn(cfg: ArchConfig) -> bool:
    return cfg.family != "ssm"


# ---------------------------------------------------------------------------
# Init (arrays / logical axes / shapes from one code path)
# ---------------------------------------------------------------------------


def _layer_init(mk: L.Maker, cfg: ArchConfig, spec: LayerSpec):
    p: Dict[str, Any] = {"pre_norm": L.norm_init(mk, cfg.d_model, cfg.norm)}
    if spec.kind == "attn":
        p["mixer"] = L.attn_init(mk, cfg)
    else:
        p["mixer"] = mamba_lib.mamba_init(mk, cfg)
    if _layer_has_ffn(cfg):
        p["post_norm"] = L.norm_init(mk, cfg.d_model, cfg.norm)
        if spec.is_moe:
            p["ffn"] = moe_lib.moe_init(mk, cfg)
        else:
            p["ffn"] = L.mlp_init(mk, cfg)
    return p


def _block_init(mk_factory, cfg: ArchConfig):
    return {str(i): _layer_init(mk_factory(i), cfg, spec)
            for i, spec in enumerate(block_pattern(cfg))}


def init_params(cfg: ArchConfig, key: Optional[jnp.ndarray] = None,
                dtype=jnp.float32, mode: str = "init"):
    """mode: "init" (arrays) | "axes" (logical names) | "shape"."""
    n = num_blocks(cfg)
    if mode == "init":
        k_emb, k_blocks, k_fe = jax.random.split(key, 3)

        def one_block(k):
            return _block_init(
                lambda i: L.Maker(jax.random.fold_in(k, i), dtype, "init"),
                cfg)

        blocks = jax.vmap(one_block)(jax.random.split(k_blocks, n))
        mk = L.Maker(k_emb, dtype, "init")
        mk_fe = L.Maker(k_fe, dtype, "init")
    else:
        blocks = _block_init(lambda i: L.Maker(None, dtype, mode), cfg)
        if mode == "axes":
            blocks = jax.tree_util.tree_map(
                lambda axes: ("layers",) + tuple(axes), blocks,
                is_leaf=lambda x: isinstance(x, tuple))
        else:
            blocks = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype),
                blocks)
        mk = L.Maker(None, dtype, mode)
        mk_fe = mk
    params = {
        "embed": L.embed_init(mk, cfg),
        "final_norm": L.norm_init(mk, cfg.d_model, cfg.norm),
        "blocks": blocks,
    }
    fe = stubs.frontend_init(mk_fe, cfg)
    if fe:
        params["frontend"] = fe
    return params


def param_logical_axes(cfg: ArchConfig):
    return init_params(cfg, mode="axes")


def param_shapes(cfg: ArchConfig, dtype=jnp.float32):
    return init_params(cfg, dtype=dtype, mode="shape")


# ---------------------------------------------------------------------------
# MoE telemetry state
# ---------------------------------------------------------------------------


def init_moe_state(cfg: ArchConfig) -> Dict[str, jnp.ndarray]:
    """Stale per-expert load telemetry per MoE block position, stacked over
    blocks: {pos: (num_blocks, E)} — balanced (ones) at init."""
    if cfg.moe is None:
        return {}
    n = num_blocks(cfg)
    return {str(i): jnp.ones((n, cfg.moe.num_experts), jnp.float32)
            for i, spec in enumerate(block_pattern(cfg)) if spec.is_moe}


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray]):
    if cfg.frontend == "audio_frames":
        return stubs.audio_frontend(cfg, batch["frames"])
    tok = L.embed_apply(params["embed"], cfg, batch["tokens"])
    if cfg.frontend == "vlm_patches":
        return stubs.vlm_frontend(params["frontend"], cfg, batch["patches"],
                                  tok)
    return tok


def _layer_apply(p, cfg: ArchConfig, spec: LayerSpec, x, moe_load):
    h = L.norm_apply(p["pre_norm"], x, cfg.norm)
    if spec.kind == "attn":
        mix = L.attn_apply(p["mixer"], cfg, h, is_local=spec.is_local)
    else:
        mix = mamba_lib.mamba_apply(p["mixer"], cfg, h)
    x = x + mix
    aux = None
    if _layer_has_ffn(cfg):
        h2 = L.norm_apply(p["post_norm"], x, cfg.norm)
        if spec.is_moe:
            y, aux = moe_lib.moe_apply(p["ffn"], cfg, h2, moe_load)
        else:
            y = L.mlp_apply(p["ffn"], cfg, h2)
        x = x + y
    return x, aux


def forward(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            moe_state: Optional[Dict[str, jnp.ndarray]] = None,
            remat_policy: str = "none"):
    """Full-sequence forward.  Returns (logits, new_moe_state, aux)."""
    pattern = block_pattern(cfg)
    moe_state = moe_state if moe_state is not None else init_moe_state(cfg)
    x = _embed_inputs(params, cfg, batch)

    def body(x, scanned):
        bp, loads = scanned
        auxes = {}
        for i, spec in enumerate(pattern):
            x, aux = _layer_apply(bp[str(i)], cfg, spec, x,
                                  loads.get(str(i)))
            if aux is not None:
                auxes[str(i)] = aux
        return x, auxes

    if remat_policy != "none":
        policy = {
            "full": None,
            "dots_saveable": jax.checkpoint_policies.dots_saveable,
            "dots_with_no_batch_dims_saveable":
                jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        }[remat_policy]
        body = jax.checkpoint(body, policy=policy)

    x, auxes = jax.lax.scan(body, x, (params["blocks"], moe_state),
                            unroll=_scan_unroll())

    new_state = {}
    aux_out = {}
    for key_, a in auxes.items():
        new_state[key_] = moe_lib.update_load_ewma(moe_state[key_], a.load)
        aux_out[key_] = a
    x = L.norm_apply(params["final_norm"], x, cfg.norm)
    logits = L.lm_head_apply(params["embed"], cfg, x)
    return logits, new_state, aux_out


def loss_fn(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            moe_state=None, remat_policy: str = "none",
            aux_coef: float = 0.01):
    """Next-token cross entropy (fp32), plus switch aux loss for the topk
    router baseline.  Returns (loss, (new_moe_state, metrics))."""
    logits, new_state, aux = forward(params, cfg, batch, moe_state,
                                     remat_policy)
    if cfg.frontend == "audio_frames":
        labels = batch["labels"]
        shift_logits, shift_labels = logits[:, :-1], labels[:, 1:]
    elif cfg.frontend == "vlm_patches":
        P = batch["patches"].shape[1]
        toks = batch["tokens"]
        shift_logits, shift_labels = logits[:, P:-1], toks[:, 1:]
    else:
        toks = batch["tokens"]
        shift_logits, shift_labels = logits[:, :-1], toks[:, 1:]
    lg = shift_logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, shift_labels[..., None],
                                 axis=-1)[..., 0]
    ce = (lse - picked).mean()
    metrics = {"ce": ce}
    loss = ce
    if aux:
        drop = jnp.stack([a.drop_rate.mean() for a in aux.values()]).mean()
        steer = jnp.stack([a.steer_rate.mean() for a in aux.values()]).mean()
        load_cv = jnp.stack(
            [jnp.std(a.load, axis=-1).mean() for a in aux.values()]).mean()
        metrics.update(moe_drop_rate=drop, moe_steer_rate=steer,
                       moe_load_cv=load_cv)
        if cfg.moe is not None and cfg.moe.router == "topk":
            aux_l = jnp.stack([a.aux_loss.mean() for a in aux.values()]
                              ).mean()
            loss = loss + aux_coef * aux_l
            metrics["aux_loss"] = aux_l
    return loss, (new_state, metrics)


# ---------------------------------------------------------------------------
# Prefill (forward + cache collection, logits for the last position only)
# ---------------------------------------------------------------------------


def prefill(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            cache_len: Optional[int] = None, cache_dtype=jnp.bfloat16,
            remat_policy: str = "none"):
    """Serving prefill: run the full sequence, emit last-position logits and
    a decode-ready cache (KV rings padded to ``cache_len``)."""
    pattern = block_pattern(cfg)
    moe_state = init_moe_state(cfg)
    x = _embed_inputs(params, cfg, batch)
    S = x.shape[1]
    cache_len = cache_len or S

    def body(x, scanned):
        bp, loads = scanned
        caches = {}
        for i, spec in enumerate(pattern):
            p = bp[str(i)]
            h = L.norm_apply(p["pre_norm"], x, cfg.norm)
            if spec.kind == "attn":
                mix, kv = L.attn_apply(p["mixer"], cfg, h,
                                       is_local=spec.is_local,
                                       return_kv=True)
                pad = ((0, 0), (0, cache_len - S), (0, 0), (0, 0))
                caches[str(i)] = {
                    "k": jnp.pad(kv["k"].astype(cache_dtype), pad),
                    "v": jnp.pad(kv["v"].astype(cache_dtype), pad)}
            else:
                mix, st = mamba_lib.mamba_apply(p["mixer"], cfg, h,
                                                return_state=True)
                caches[str(i)] = {"h": st["h"],
                                  "conv": st["conv"].astype(cache_dtype)}
            x = x + mix
            if _layer_has_ffn(cfg):
                h2 = L.norm_apply(p["post_norm"], x, cfg.norm)
                if spec.is_moe:
                    y, _ = moe_lib.moe_apply(p["ffn"], cfg, h2,
                                             loads.get(str(i)))
                else:
                    y = L.mlp_apply(p["ffn"], cfg, h2)
                x = x + y
        return x, caches

    if remat_policy != "none":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_saveable)
    x, cache = jax.lax.scan(body, x, (params["blocks"], moe_state),
                           unroll=_scan_unroll())
    x = L.norm_apply(params["final_norm"], x[:, -1:], cfg.norm)
    logits = L.lm_head_apply(params["embed"], cfg, x)
    return logits, cache


# ---------------------------------------------------------------------------
# Decode (single token with cache)
# ---------------------------------------------------------------------------


def init_decode_cache(cfg: ArchConfig, batch: int, max_seq: int,
                      dtype=jnp.bfloat16, mode: str = "init"):
    """Stacked per-block-position caches: attention positions get KV rings,
    mamba positions get (h, conv) states."""
    n = num_blocks(cfg)
    kv = cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    # mode="shape" must NEVER allocate (a 32k x 128 cache is tens of GB)
    make = (jax.ShapeDtypeStruct if mode == "shape"
            else lambda s, d: jnp.zeros(s, d))
    cache: Dict[str, Any] = {}
    for i, spec in enumerate(block_pattern(cfg)):
        if spec.kind == "attn":
            shp = (n, batch, max_seq, kv, hd)
            c = {"k": make(shp, dtype), "v": make(shp, dtype)}
        else:
            di, st, dc, _ = mamba_lib._dims(cfg)
            c = {"h": make((n, batch, di, st), jnp.float32),
                 "conv": make((n, batch, dc - 1, di), dtype)}
        cache[str(i)] = c
    return cache


def cache_logical_axes(cfg: ArchConfig):
    axes: Dict[str, Any] = {}
    for i, spec in enumerate(block_pattern(cfg)):
        if spec.kind == "attn":
            a = ("layers", "batch", "cache_seq", "cache_heads", "head_dim")
            axes[str(i)] = {"k": a, "v": a}
        else:
            axes[str(i)] = {
                "h": ("layers", "batch", "mamba_inner", "state"),
                "conv": ("layers", "batch", "conv", "mamba_inner")}
    return axes


def decode_step(params, cfg: ArchConfig, cache, tokens: jnp.ndarray,
                pos: jnp.ndarray):
    """One decode step.  tokens: (B, 1) int32; pos: (B,) current write
    position.  Returns (logits (B, 1, V), new_cache)."""
    pattern = block_pattern(cfg)
    x = L.embed_apply(params["embed"], cfg, tokens)

    def body(x, scanned):
        bp, cache_p = scanned
        new_c = {}
        for i, spec in enumerate(pattern):
            p = bp[str(i)]
            h = L.norm_apply(p["pre_norm"], x, cfg.norm)
            if spec.kind == "attn":
                mix, new_c[str(i)] = L.attn_decode(
                    p["mixer"], cfg, h, cache_p[str(i)], pos,
                    is_local=spec.is_local)
            else:
                mix, new_c[str(i)] = mamba_lib.mamba_decode(
                    p["mixer"], cfg, h, cache_p[str(i)])
            x = x + mix
            if _layer_has_ffn(cfg):
                h2 = L.norm_apply(p["post_norm"], x, cfg.norm)
                if spec.is_moe:
                    y, _ = moe_lib.moe_apply(p["ffn"], cfg, h2, None)
                else:
                    y = L.mlp_apply(p["ffn"], cfg, h2)
                x = x + y
        return x, new_c

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache),
                                unroll=_scan_unroll())
    x = L.norm_apply(params["final_norm"], x, cfg.norm)
    logits = L.lm_head_apply(params["embed"], cfg, x)
    return logits, new_cache
