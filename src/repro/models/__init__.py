from repro.models.model import (  # noqa: F401
    block_pattern,
    cache_logical_axes,
    decode_step,
    forward,
    init_decode_cache,
    init_moe_state,
    init_params,
    loss_fn,
    num_blocks,
    param_logical_axes,
    param_shapes,
    prefill,
)
