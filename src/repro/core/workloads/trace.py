"""Trace replay: drive the simulator from recorded metadata traces.

A trace is a ``.npz`` with three aligned 1-D arrays — ``t_ms`` (float
event times), ``key`` (int namespace keys), ``is_write`` (bool) — the
shape real MDS logs reduce to.  :class:`TraceReplay` re-buckets events
onto the simulator's ``(T, R)`` tick grid: tick ``floor(t_ms / dt_ms)``,
slots filled in trace order, keys folded into ``[0, N)``.  Traces shorter
than the horizon loop (each repetition offset by the trace span) so any
``T`` can be driven from a short recording; events past the per-tick slot
budget ``R`` are dropped, matching a proxy's bounded ingest.

A small synthetic trace ships in ``tests/data/synthetic_trace.npz`` (the
generator script next to it saves :func:`synthetic_events`) and is the
registry default, so ``make_workload("trace_replay", ...)`` works out of
the box; pass ``trace="/path/to/trace.npz"`` to replay a real recording.
"""

from __future__ import annotations

from pathlib import Path
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.workloads.base import (
    Workload,
    WorkloadParams,
    WorkloadSpec,
    register,
)

TRACE_FIELDS = ("t_ms", "key", "is_write")

#: Default trace: the synthetic recording checked into tests/data/ (an
#: in-repo checkout path; ``synthetic_events`` regenerates the identical
#: events when the file is absent, e.g. from an installed package).
DEFAULT_TRACE = (
    Path(__file__).resolve().parents[4]
    / "tests"
    / "data"
    / "synthetic_trace.npz"
)


def synthetic_events() -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The default synthetic MDS trace, ~20 s: light Poisson background
    reads over a 512-key namespace plus two job-startup bursts hammering
    small hot directory sets, with renames mixed into the bursts.

    Deterministic; ``tests/data/gen_synthetic_trace.py`` saves exactly
    these events as the checked-in ``.npz`` round-trip fixture.
    """
    rng = np.random.default_rng(42)
    events = []
    n_bg = rng.poisson(15 * 20)  # ~15 reads/s for 20 s
    events.append(
        (
            rng.uniform(0.0, 20_000.0, n_bg),
            rng.integers(0, 512, n_bg),
            np.zeros(n_bg, bool),
        )
    )
    # two bursts: 2 s each at ~120 req/s on 8 hot keys, 30% renames
    for t0, hot0 in ((4_000.0, 64), (13_000.0, 200)):
        n = rng.poisson(120 * 2)
        events.append(
            (
                rng.uniform(t0, t0 + 2_000.0, n),
                hot0 + rng.integers(0, 8, n),
                rng.random(n) < 0.3,
            )
        )
    t_ms = np.concatenate([e[0] for e in events])
    key = np.concatenate([e[1] for e in events]).astype(np.int64)
    is_write = np.concatenate([e[2] for e in events])
    order = np.argsort(t_ms, kind="stable")
    return t_ms[order], key[order], is_write[order]


def load_trace(path) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Load and validate a ``(t_ms, key, is_write)`` trace from ``.npz``."""
    path = Path(path)
    if not path.exists():
        raise FileNotFoundError(
            f"trace file {path} not found; a trace is a .npz with 1-D "
            f"arrays {TRACE_FIELDS} (see repro.core.workloads.trace)"
        )
    with np.load(path) as z:
        missing = [f for f in TRACE_FIELDS if f not in z]
        if missing:
            raise ValueError(
                f"trace {path} missing arrays: {missing}; "
                f"expected {TRACE_FIELDS}"
            )
        t_ms = np.asarray(z["t_ms"], np.float64)
        key = np.asarray(z["key"], np.int64)
        is_write = np.asarray(z["is_write"], bool)
    if not (t_ms.ndim == key.ndim == is_write.ndim == 1):
        raise ValueError(f"trace {path}: arrays must be 1-D")
    if not (t_ms.size == key.size == is_write.size):
        raise ValueError(
            f"trace {path}: array lengths differ "
            f"({t_ms.size}, {key.size}, {is_write.size})"
        )
    order = np.argsort(t_ms, kind="stable")
    return t_ms[order], key[order], is_write[order]


def rebucket(
    t_ms: np.ndarray,
    key: np.ndarray,
    is_write: np.ndarray,
    *,
    T: int,
    R: int,
    N: int,
    dt_ms: float,
    loop: bool = True,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Bucket trace events onto a ``(T, R)`` grid (host-side numpy).

    Returns ``(keys, mask, is_write)`` grids.  Events land at tick
    ``floor(t_ms / dt_ms)`` in trace order; within a tick the first ``R``
    events get slots and the rest are dropped.  With ``loop=True`` the
    trace repeats (offset by its span) until the horizon is covered.
    """
    if t_ms.size == 0:
        z = np.zeros((T, R), np.int32)
        return z, np.zeros((T, R), bool), np.zeros((T, R), bool)
    if loop:
        span = float(max(t_ms.max() + dt_ms, dt_ms))
        reps = int(np.ceil(T * dt_ms / span))
        offs = np.arange(reps, dtype=np.float64) * span
        t_ms = (t_ms[None, :] + offs[:, None]).reshape(-1)
        key = np.tile(key, reps)
        is_write = np.tile(is_write, reps)
    tick = np.floor(t_ms / dt_ms).astype(np.int64)
    keep = (tick >= 0) & (tick < T)
    tick, key, is_write = tick[keep], key[keep], is_write[keep]
    # stable sort by tick keeps trace order within each tick; slot index is
    # the running count since the tick's first event
    order = np.argsort(tick, kind="stable")
    tick, key, is_write = tick[order], key[order], is_write[order]
    uniq, start, counts = np.unique(
        tick, return_index=True, return_counts=True
    )
    slot = np.arange(tick.size) - np.repeat(start, counts)
    fits = slot < R
    tick, slot = tick[fits], slot[fits]
    key, is_write = key[fits], is_write[fits]
    keys = np.zeros((T, R), np.int32)
    mask = np.zeros((T, R), bool)
    writes = np.zeros((T, R), bool)
    keys[tick, slot] = (key % N).astype(np.int32)
    mask[tick, slot] = True
    writes[tick, slot] = is_write
    return keys, mask, writes


@register("trace_replay")
class TraceReplay(WorkloadSpec):
    """Replay a recorded ``(t_ms, key, is_write)`` trace onto the grid."""

    def __init__(self, trace=None, loop: bool = True):
        self.trace = Path(trace) if trace is not None else None
        self.loop = loop

    def build(self, p: WorkloadParams) -> Workload:
        if self.trace is not None:
            t_ms, key, is_write = load_trace(self.trace)
        elif DEFAULT_TRACE.exists():
            t_ms, key, is_write = load_trace(DEFAULT_TRACE)
        else:  # installed package: no repo checkout
            t_ms, key, is_write = synthetic_events()
        keys, mask, writes = rebucket(
            t_ms,
            key,
            is_write,
            T=p.T,
            R=p.R,
            N=p.N,
            dt_ms=p.dt_ms,
            loop=self.loop,
        )
        stem = self.trace.stem if self.trace is not None else "synthetic"
        return Workload(
            keys=jnp.asarray(keys),
            mask=jnp.asarray(mask),
            is_write=jnp.asarray(writes),
            name=f"trace_replay({stem})",
            N=p.N,
        )
