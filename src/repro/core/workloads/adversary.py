"""Parametric adversarial workload: traffic tuned to break controllers.

Every scenario so far was designed to be survivable; this family is
designed to be HOSTILE.  An :class:`AdversaryParams` vector shapes a
burst train out of the existing combinators — ``scale_rate`` over a
``skewed`` burst, ``shift_hotset`` rotating the hot directory set each
cycle, ``concat`` stitching burst/quiet phases, ``mix`` folding in a
light background tenant — with the parameters deliberately able to
resonate with the control plane's own cadences: the hysteresis
controller escalates after ``K_UP`` fast ticks above the band and
releases after ``K_DOWN`` below (15 / 40 engine ticks at the default
dt), so burst periods in the tens-of-ticks range can hold the d knob in
a sustained limit cycle.  The search driver
(``experiments/run_hillclimb.py advtraffic``) hill-climbs this vector
per controller against the E4 oscillation / worst-case-queue objective;
:func:`save_trace` exports any realized grid as a ``trace_replay``-
compatible ``.npz`` so the worst discovered input becomes a committed
regression fixture (``tests/data/redteam_worst.npz``).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.workloads import combinators
from repro.core.workloads.base import (
    Workload,
    WorkloadParams,
    WorkloadSpec,
    register,
)

#: (lo, hi) per parameter, the search box advtraffic explores.
BOUNDS = {
    "period": (20.0, 240.0),   # burst period in ticks
    "duty": (0.10, 0.90),      # burst fraction of each period
    "shift_frac": (0.0, 1.0),  # hotset rotation per cycle, × N
    "write_hi": (0.0, 0.80),   # write fraction inside bursts
    "amp": (0.5, 4.0),         # burst rate, × aggregate capacity
}

# skewed builds at 0.70 × capacity; amp is expressed in capacities
_SKEWED_RATE = 0.70
# background tenant share of (tick, slot) cells in the final mix
_BG_MIX = 0.15


@dataclasses.dataclass(frozen=True)
class AdversaryParams:
    """The continuous adversary vector.

    The defaults sit in the resonant regime for the default hysteresis
    cadence: each ~24-tick burst at ~capacity clears ``K_UP`` (escalate
    after 15 engine ticks above the band) and each ~136-tick quiet
    phase clears ``K_DOWN`` (release after 40 calm ticks), so d climbs
    and releases every cycle — a sustained limit cycle rather than a
    saturating overload (amp >> 1 just pins d at ``D_MAX``)."""

    period: float = 160.0
    duty: float = 0.15
    shift_frac: float = 0.37
    write_hi: float = 0.50
    amp: float = 1.0

    def to_vector(self) -> np.ndarray:
        return np.array([getattr(self, k) for k in BOUNDS], np.float64)

    @classmethod
    def from_vector(cls, v) -> "AdversaryParams":
        """Clip ``v`` into the search box and build the params."""
        kw = {}
        for (name, (lo, hi)), x in zip(BOUNDS.items(), np.asarray(v)):
            kw[name] = float(np.clip(x, lo, hi))
        return cls(**kw)

    def clipped(self) -> "AdversaryParams":
        return AdversaryParams.from_vector(self.to_vector())


def random_params(rng: np.random.Generator) -> AdversaryParams:
    """Uniform draw from the search box (a hill-climb restart)."""
    v = [rng.uniform(lo, hi) for lo, hi in BOUNDS.values()]
    return AdversaryParams.from_vector(v)


def perturb(
    params: AdversaryParams,
    rng: np.random.Generator,
    scale: float = 0.2,
) -> AdversaryParams:
    """Gaussian step in box-normalized coordinates (clipped)."""
    v = params.to_vector()
    for i, (lo, hi) in enumerate(BOUNDS.values()):
        v[i] += rng.normal(0.0, scale) * (hi - lo)
    return AdversaryParams.from_vector(v)


@register("adversarial")
class Adversarial(WorkloadSpec):
    """Resonant burst train shaped by an :class:`AdversaryParams`.

    ``make_workload("adversarial", ..., params=AdversaryParams(...))``
    or individual overrides (``period=..., duty=..., ...``).
    """

    def __init__(self, params: AdversaryParams = None, **overrides):
        base = params if params is not None else AdversaryParams()
        if overrides:
            unknown = set(overrides) - set(BOUNDS)
            if unknown:
                raise ValueError(
                    f"unknown adversary parameter(s) "
                    f"{sorted(unknown)}; available: {', '.join(BOUNDS)}"
                )
            base = dataclasses.replace(base, **overrides)
        self.params = base.clipped()

    def build(self, p: WorkloadParams) -> Workload:
        ap = self.params
        period = max(int(round(ap.period)), 2)
        burst_len = int(np.clip(round(period * ap.duty), 1, period - 1))
        cycles = -(-p.T // period)  # ceil: cover the horizon, then trim
        shift_step = int(round(ap.shift_frac * p.N))
        parts = []
        for c in range(cycles):
            # decorrelate cycles: each burst is a different hostile job
            sc = p.seed * 1_000_003 + 7919 * c
            burst = combinators.scale_rate(
                p.make(
                    "skewed",
                    T=burst_len,
                    seed=sc,
                    write_frac=ap.write_hi,
                ),
                ap.amp / _SKEWED_RATE,
                seed=sc + 1,
            )
            burst = combinators.shift_hotset(burst, (c * shift_step) % p.N)
            if burst_len < period:
                quiet = p.make("light", T=period - burst_len, seed=sc + 2)
                parts.append(combinators.concat(burst, quiet))
            else:
                parts.append(burst)
        train = parts[0]
        for part in parts[1:]:
            train = combinators.concat(train, part)
        train = train._replace(
            keys=train.keys[: p.T],
            mask=train.mask[: p.T],
            is_write=train.is_write[: p.T],
        )
        bg = p.make("light", seed=p.seed + 101)
        wl = combinators.mix(train, bg, _BG_MIX, seed=p.seed + 211)
        return wl._replace(name="adversarial")


# ---------------------------------------------------------------------------
# Trace export: realized grid -> trace_replay-compatible events
# ---------------------------------------------------------------------------


def to_events(
    wl: Workload, dt_ms: float = 50.0
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Flatten a realized grid into ``(t_ms, key, is_write)`` events.

    Slots spread inside their tick (preserving slot order, never
    crossing the tick boundary), so a ``trace_replay`` with the same
    ``T``/``R``/``N``/``dt_ms`` and ``loop=False`` reproduces each
    tick's event multiset exactly (rebucketing compacts valid slots to
    a prefix, so slot *positions* may differ) — the round-trip that
    makes a synthesized worst case replayable (tested).
    """
    keys = np.asarray(wl.keys)
    mask = np.asarray(wl.mask, bool)
    wr = np.asarray(wl.is_write, bool)
    t_idx, slot = np.nonzero(mask)  # row-major: slot order kept per tick
    R = mask.shape[1]
    t_ms = t_idx * dt_ms + (slot + 0.5) * (dt_ms / (R + 1))
    return (
        t_ms.astype(np.float64),
        keys[t_idx, slot].astype(np.int64),
        wr[t_idx, slot],
    )


def save_trace(path, wl: Workload, dt_ms: float = 50.0) -> None:
    """Write ``wl`` as a ``trace_replay`` ``.npz`` (TRACE_FIELDS)."""
    t_ms, key, is_write = to_events(wl, dt_ms)
    np.savez(path, t_ms=t_ms, key=key, is_write=is_write)
