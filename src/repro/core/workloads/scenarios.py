"""Composed scenarios: bursty metadata patterns beyond the Fig. 2 seven.

Each scenario is a registered spec *built from the combinators* over other
registered workloads — the declarative composition the registry exists
for.  Component seeds are derived from the scenario seed so scenarios stay
deterministic and components stay decorrelated.
"""

from __future__ import annotations

import functools

from repro.core.workloads.base import (
    Workload,
    WorkloadParams,
    WorkloadSpec,
    register,
)
from repro.core.workloads.combinators import (
    concat,
    mix,
    scale_rate,
    shift_hotset,
)

#: Scenarios introduced on top of the legacy seven (see fig2.WORKLOADS).
SCENARIOS = ("job_startup", "rename_storm", "flash_crowd", "multi_tenant")


def _phases(*parts: Workload) -> Workload:
    """Concat the non-empty phases (degenerate horizons drop to fewer
    phases but always yield exactly the requested T ticks)."""
    live = [w for w in parts if w.keys.shape[0] > 0]
    return functools.reduce(concat, live)


@register("job_startup")
class JobStartup(WorkloadSpec):
    """A cluster-wide job launch: every rank stats/opens the job's shared
    directories at once (a short skew-heavy crush at ~2x capacity), then
    the run settles into steady light traffic."""

    def build(self, p: WorkloadParams) -> Workload:
        t_start = min(max(p.T // 8, 1), p.T)
        crush = scale_rate(
            p.make("skewed", T=t_start, seed=p.seed + 101, write_frac=0.3),
            3.0,
            seed=p.seed + 1,
        )
        crush = shift_hotset(crush, p.N // 3)
        steady = p.make("light", T=p.T - t_start, seed=p.seed + 202)
        return _phases(crush, steady)


@register("rename_storm")
class RenameStorm(WorkloadSpec):
    """A directory restructure: a write-heavy (rename/unlink) stream over a
    skewed hot set, blended into light background reads.  Mutations defeat
    caching, so the hotspot lands squarely on the owning servers."""

    def build(self, p: WorkloadParams) -> Workload:
        background = p.make("light", seed=p.seed + 303)
        renames = scale_rate(
            p.make("skewed", seed=p.seed + 404, write_frac=0.85),
            1.3,
            seed=p.seed + 2,
        )
        return mix(background, renames, 0.7, seed=p.seed + 3)


@register("flash_crowd")
class FlashCrowd(WorkloadSpec):
    """A suddenly-popular dataset: light traffic, then every client reads
    the same namespace region at ~2x capacity, then the crowd drains."""

    def build(self, p: WorkloadParams) -> Workload:
        t_pre = min(max(p.T // 4, 1), p.T)
        t_peak = min(max(p.T // 3, 1), p.T - t_pre)
        t_post = p.T - t_pre - t_peak
        calm_a = p.make("light", T=t_pre, seed=p.seed + 505)
        crowd = scale_rate(
            p.make("skewed", T=t_peak, seed=p.seed + 606, write_frac=0.0),
            2.8,
            seed=p.seed + 4,
        )
        crowd = shift_hotset(crowd, 2 * p.N // 3)
        calm_b = p.make("light", T=t_post, seed=p.seed + 707)
        return _phases(calm_a, crowd, calm_b)


@register("multi_tenant")
class MultiTenant(WorkloadSpec):
    """Two tenants share the proxy tier: tenant A runs bursty job
    start-ups, tenant B runs periodic checkpoints in a shifted namespace
    region, interleaved per-slot — neither sees a clean pattern."""

    def build(self, p: WorkloadParams) -> Workload:
        tenant_a = p.make("bursty", seed=p.seed + 808)
        tenant_b = shift_hotset(
            p.make("periodic", seed=p.seed + 909, write_frac=0.4),
            p.N // 2,
        )
        return mix(tenant_a, tenant_b, 0.5, seed=p.seed + 5)
