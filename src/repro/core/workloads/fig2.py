"""The paper's Fig. 2 traffic patterns — the seven legacy generators.

Ported unchanged from the original closed-tuple ``workloads.py``; each is
now a registered :class:`~repro.core.workloads.base.WorkloadSpec` so the
scenario combinators (and third-party registrations) compose with them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.workloads.base import (
    Workload,
    WorkloadParams,
    WorkloadSpec,
    assemble,
    hot_subset_keys,
    register,
)

#: The legacy closed tuple, kept for backward compatibility; the live list
#: is ``workloads.available()``.
WORKLOADS = (
    "light",
    "uniform_heavy",
    "bursty",
    "periodic",
    "diurnal",
    "skewed",
    "storm",
)


@register("light")
class Light(WorkloadSpec):
    """Steady 40% utilization, uniform keys (the §III-B warmup regime)."""

    def build(self, p: WorkloadParams) -> Workload:
        rate = jnp.full((p.T,), 0.40 * p.cap)
        return assemble(p.rng, rate, p.R, p.N, 0.0, p.write_frac, "light")


@register("uniform_heavy")
class UniformHeavy(WorkloadSpec):
    """Steady 85% utilization, uniform keys — headroom stress, no skew."""

    def build(self, p: WorkloadParams) -> Workload:
        rate = jnp.full((p.T,), 0.85 * p.cap)
        return assemble(
            p.rng, rate, p.R, p.N, 0.0, p.write_frac, "uniform_heavy"
        )


@register("bursty")
class Bursty(WorkloadSpec):
    """Background 30% + job-startup bursts: every ~20 s, 2 s at 3x
    capacity, keys concentrated on a small hot directory set.  Each burst
    is a *different* job => different hot directories."""

    def build(self, p: WorkloadParams) -> Workload:
        k1, k2, k3 = jax.random.split(p.rng, 3)
        base = jnp.full((p.T,), 0.30 * p.cap)
        period_s, dur_s = 20.0, 2.0
        phase = jax.random.uniform(k3, ()) * period_s
        in_burst = ((p.sec + phase) % period_s) < dur_s
        burst_idx = ((p.sec + phase) // period_s).astype(jnp.int32)
        rate = base + jnp.where(in_burst, 3.0 * p.cap, 0.0)
        wl = assemble(k1, rate, p.R, p.N, 0.0, p.write_frac, "bursty")
        hot = hot_subset_keys(
            k2,
            wl.keys.shape,
            burst_idx,
            p.N,
            subset=32,
            alpha=1.1,
            salt=11,
        )
        keys = jnp.where(in_burst[:, None], hot, wl.keys)
        return wl._replace(keys=keys)


@register("periodic")
class Periodic(WorkloadSpec):
    """Sinusoid peaking slightly above capacity (checkpoint cadence)."""

    def build(self, p: WorkloadParams) -> Workload:
        rate = p.cap * jnp.clip(
            0.55 + 0.55 * jnp.sin(2 * jnp.pi * p.sec / 30.0), 0.0, None
        )
        return assemble(p.rng, rate, p.R, p.N, 0.6, p.write_frac, "periodic")


@register("diurnal")
class Diurnal(WorkloadSpec):
    """Slow horizon-long swell with a faster ripple on top."""

    def build(self, p: WorkloadParams) -> Workload:
        sec = p.sec
        horizon = jnp.maximum(sec[-1], 1.0)
        rate = p.cap * jnp.clip(
            0.5
            + 0.45 * jnp.sin(2 * jnp.pi * sec / horizon)
            + 0.08 * jnp.sin(2 * jnp.pi * sec / 13.0),
            0.0,
            None,
        )
        return assemble(p.rng, rate, p.R, p.N, 0.5, p.write_frac, "diurnal")


@register("skewed")
class Skewed(WorkloadSpec):
    """Steady 70% utilization under zipf(0.9) key popularity."""

    def build(self, p: WorkloadParams) -> Workload:
        rate = jnp.full((p.T,), 0.70 * p.cap)
        return assemble(p.rng, rate, p.R, p.N, 0.9, p.write_frac, "skewed")


@register("storm")
class Storm(WorkloadSpec):
    """Checkpoint storm: near-idle then all ranks write at once (5 s);
    each storm targets that job's checkpoint directories."""

    def build(self, p: WorkloadParams) -> Workload:
        k1, k2 = jax.random.split(p.rng)
        storm = (p.sec % 60.0) < 5.0
        storm_idx = (p.sec // 60.0).astype(jnp.int32)
        rate = jnp.where(storm, 4.0 * p.cap, 0.05 * p.cap)
        wl = assemble(k1, rate, p.R, p.N, 0.0, 0.5, "storm")
        hot = hot_subset_keys(
            k2,
            wl.keys.shape,
            storm_idx,
            p.N,
            subset=16,
            alpha=1.0,
            salt=17,
        )
        keys = jnp.where(storm[:, None], hot, wl.keys)
        return wl._replace(keys=keys)
