"""Workload combinators: compose realized grids into new scenarios.

Combinators are pure functions on :class:`Workload` grids, so anything —
built-ins, trace replays, third-party registrations — composes with
anything else.  All binary combinators require matching slot width ``R``
and namespace size ``N`` (``make_workload``/``WorkloadParams.make`` hand
every component the same ``R``, so this holds by construction).

Conservation contracts (exercised by ``tests/test_workloads.py``):

* ``concat`` — request counts add; time axes stack.
* ``mix`` — the Bernoulli selection partitions slots, so
  ``mix(a, b, p, seed=s)`` and ``mix(b, a, p, seed=s)`` together carry
  exactly the requests of ``a`` plus ``b``.
* ``scale_rate`` — ``factor=1`` is the identity on counts; thinning
  (``factor<1``) only removes; boosting (``factor>1``) replicates the
  tick's own keys, capped at ``R``.
* ``shift_hotset`` — mask and write flags are untouched; only keys move.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.workloads.base import Workload


def _check_compatible(w1: Workload, w2: Workload, op: str) -> None:
    if w1.keys.shape[1] != w2.keys.shape[1]:
        raise ValueError(
            f"{op}: slot widths differ "
            f"({w1.keys.shape[1]} vs {w2.keys.shape[1]})"
        )
    if w1.N != w2.N:
        raise ValueError(
            f"{op}: namespace sizes differ ({w1.N} vs {w2.N})"
        )


def mix(w1: Workload, w2: Workload, p: float, *, seed: int = 0) -> Workload:
    """Per-slot Bernoulli blend: each (tick, slot) cell comes from ``w2``
    with probability ``p``, else from ``w1`` (keys, mask, and write flag
    move together).  Models independent tenants sharing one proxy tier.
    """
    _check_compatible(w1, w2, "mix")
    if w1.keys.shape != w2.keys.shape:
        raise ValueError(
            f"mix: grid shapes differ ({w1.keys.shape} vs {w2.keys.shape})"
        )
    sel = jax.random.uniform(jax.random.PRNGKey(seed), w1.mask.shape) < p
    return Workload(
        keys=jnp.where(sel, w2.keys, w1.keys),
        mask=jnp.where(sel, w2.mask, w1.mask),
        is_write=jnp.where(sel, w2.is_write, w1.is_write),
        name=f"mix({w1.name},{w2.name},{p:g})",
        N=w1.N,
    )


def concat(w1: Workload, w2: Workload) -> Workload:
    """Play ``w1`` then ``w2``: time axes stack, counts add."""
    _check_compatible(w1, w2, "concat")
    return Workload(
        keys=jnp.concatenate([w1.keys, w2.keys], axis=0),
        mask=jnp.concatenate([w1.mask, w2.mask], axis=0),
        is_write=jnp.concatenate([w1.is_write, w2.is_write], axis=0),
        name=f"concat({w1.name},{w2.name})",
        N=w1.N,
    )


def scale_rate(w: Workload, factor: float, *, seed: int = 0) -> Workload:
    """Thin (``factor<1``) or boost (``factor>1``) the request rate.

    Thinning keeps each request independently with probability ``factor``.
    Boosting replicates the tick's own requests (cyclically, preserving the
    tick's key distribution) into free slots, capped at the grid width —
    per-tick counts become ``min(round(count * factor), R)``.
    """
    if factor < 0:
        raise ValueError(f"scale_rate: factor must be >= 0, got {factor}")
    if factor == 1.0:
        return w._replace(name=f"scale_rate({w.name},1)")
    T, R = w.mask.shape
    if factor < 1.0:
        u = jax.random.uniform(jax.random.PRNGKey(seed), w.mask.shape)
        mask = w.mask & (u < factor)
        return Workload(
            keys=w.keys,
            mask=mask,
            is_write=w.is_write & mask,
            name=f"scale_rate({w.name},{factor:g})",
            N=w.N,
        )
    # boost: compact valid slots to a prefix, then replicate cyclically
    order = jnp.argsort(~w.mask, axis=1, stable=True)  # valid slots first
    keys = jnp.take_along_axis(w.keys, order, axis=1)
    is_write = jnp.take_along_axis(w.is_write, order, axis=1)
    counts = w.mask.sum(axis=1)
    target = jnp.minimum(jnp.round(counts * factor), R).astype(jnp.int32)
    slot = jnp.arange(R)[None, :]
    src = slot % jnp.maximum(counts, 1)[:, None]
    mask = slot < target[:, None]
    return Workload(
        keys=jnp.take_along_axis(keys, src, axis=1),
        mask=mask,
        is_write=jnp.take_along_axis(is_write, src, axis=1) & mask,
        name=f"scale_rate({w.name},{factor:g})",
        N=w.N,
    )


def shift_hotset(w: Workload, offset: int) -> Workload:
    """Translate every key by ``offset`` (mod N): the same traffic shape
    aimed at a different namespace region, so two tenants' hotspots land on
    different servers."""
    keys = jnp.mod(w.keys + jnp.int32(offset), jnp.int32(w.N))
    return w._replace(
        keys=keys.astype(jnp.int32),
        name=f"shift_hotset({w.name},{offset})",
    )
