"""Pluggable workload registry + combinators for the MIDAS evaluation.

This package mirrors the policy registry (``repro.core.policies``) on the
traffic side: generators register with ``@workloads.register("name")``,
``make_workload(name, ...)`` resolves through the registry, and unknown
names raise a ``ValueError`` listing every alternative.  The modules:

``base``         Workload grid, WorkloadSpec protocol, params, registry
``fig2``         the paper's seven Fig. 2 generators (legacy built-ins)
``combinators``  mix / concat / scale_rate / shift_hotset on realized grids
``scenarios``    job_startup, rename_storm, flash_crowd, multi_tenant
``trace``        trace replay from recorded (t_ms, key, is_write) ``.npz``
``adversary``    parametric controller-adversarial burst trains (red team)

See ``base``'s docstring for a complete third-party registration (~10
lines) and DESIGN.md §7 for the architecture.
"""

from repro.core.workloads.base import (
    Workload,
    WorkloadParams,
    WorkloadSpec,
    assemble,
    available,
    get_class,
    hot_subset_keys,
    make_workload,
    register,
    sample_keys,
    unregister,
    zipf_cdf,
)
from repro.core.workloads.combinators import (
    concat,
    mix,
    scale_rate,
    shift_hotset,
)

# Built-in generators and scenarios self-register on import.
from repro.core.workloads.adversary import (
    AdversaryParams,
    random_params,
    perturb,
    save_trace,
    to_events,
)
from repro.core.workloads.fig2 import WORKLOADS
from repro.core.workloads.scenarios import SCENARIOS
from repro.core.workloads.trace import load_trace, rebucket

__all__ = [
    "AdversaryParams",
    "SCENARIOS",
    "WORKLOADS",
    "Workload",
    "WorkloadParams",
    "WorkloadSpec",
    "assemble",
    "available",
    "concat",
    "get_class",
    "hot_subset_keys",
    "load_trace",
    "make_workload",
    "mix",
    "perturb",
    "random_params",
    "rebucket",
    "register",
    "sample_keys",
    "save_trace",
    "scale_rate",
    "shift_hotset",
    "to_events",
    "unregister",
    "zipf_cdf",
]
