"""Workload spec protocol, build parameters, and the workload registry.

A *workload* is the traffic side of the MIDAS evaluation: a ``(T, R)`` grid
of request keys with a validity mask and a write flag.  Keys index a
namespace of ``N`` objects (directories/inodes); the key→server map comes
from the consistent-hash ring, so key skew creates server hotspots exactly
as in the paper's motivation (job start-ups / checkpoint storms hammer few
directories).

Workloads are self-contained specs that register themselves by name —
mirroring the policy registry (``repro.core.policies``) — and the engine
resolves names through :func:`make_workload`; there is no workload-name
branching anywhere else.  A complete registration looks like this:

    import jax.numpy as jnp
    from repro.core import workloads

    @workloads.register("half_capacity")
    class HalfCapacity(workloads.WorkloadSpec):
        '''Steady uniform traffic at 50% of aggregate service capacity.'''

        def build(self, p):
            rate = jnp.full((p.T,), 0.5 * p.cap)
            return workloads.assemble(
                p.rng, rate, p.R, p.N, 0.0, p.write_frac, "half_capacity"
            )

    # make_workload("half_capacity", T=..., m=...) now works everywhere:
    # simulate(), simulate_sweep(), every benchmark and example.

Rates are expressed as a fraction of aggregate service capacity
``cap = m * dt_ms / service_ms`` requests per tick.  ``available()`` lists
everything registered; unknown names raise a ``ValueError`` naming the
alternatives.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple, Type

import jax
import jax.numpy as jnp

from repro.core import registry as registry_lib


class Workload(NamedTuple):
    """A realized traffic grid; what ``simulate`` consumes."""

    keys: jnp.ndarray      # (T, R) int32 in [0, N)
    mask: jnp.ndarray      # (T, R) bool
    is_write: jnp.ndarray  # (T, R) bool (metadata-mutating ops)
    name: str
    N: int


@dataclasses.dataclass(frozen=True)
class WorkloadParams:
    """Grid shape + capacity context handed to ``WorkloadSpec.build``.

    ``R`` is always concrete here (``make_workload`` resolves the default),
    so every workload built under the same params shares the grid width —
    the invariant the combinators and the sweep batcher rely on.
    """

    T: int
    m: int
    seed: int = 0
    dt_ms: float = 50.0
    service_ms: float = 100.0
    N: int = 4096
    R: int = 0
    write_frac: float = 0.05

    @property
    def cap(self) -> float:
        """Aggregate service capacity in requests per tick."""
        return self.m * self.dt_ms / self.service_ms

    @property
    def sec(self) -> jnp.ndarray:
        """(T,) wall-clock seconds at each tick."""
        return jnp.arange(self.T, dtype=jnp.float32) * self.dt_ms / 1000.0

    @property
    def rng(self) -> jnp.ndarray:
        return jax.random.PRNGKey(self.seed)

    def make(self, name: str, **overrides) -> Workload:
        """Build another registered workload under these params — the
        composition hook scenarios use.  ``R`` is passed through explicitly
        so component grids always align."""
        kw: Dict[str, Any] = dict(
            T=self.T,
            m=self.m,
            seed=self.seed,
            dt_ms=self.dt_ms,
            service_ms=self.service_ms,
            N=self.N,
            R=self.R,
            write_frac=self.write_frac,
        )
        kw.update(overrides)
        return make_workload(name, **kw)


class WorkloadSpec:
    """Base class for registered workload generators.

    Subclasses implement :meth:`build`, producing the ``(T, R)`` grid from a
    :class:`WorkloadParams`.  Extra keyword arguments passed to
    ``make_workload`` are forwarded to the spec's constructor (see the
    trace-replay spec for an example that takes a ``trace`` path).
    """

    name: str = "?"

    def build(self, p: WorkloadParams) -> Workload:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

REGISTRY = registry_lib.Registry("workload")


def register(name: str):
    """Class decorator: ``@register("my_workload")`` adds a WorkloadSpec
    subclass to the registry under ``name``."""
    return REGISTRY.register(name)


def unregister(name: str) -> None:
    """Remove a registered workload (intended for tests/plugins)."""
    REGISTRY.unregister(name)


def available() -> Tuple[str, ...]:
    """Sorted names of every registered workload."""
    return REGISTRY.available()


def get_class(name: str) -> Type[WorkloadSpec]:
    return REGISTRY.get_class(name)


def make_workload(
    name: str,
    *,
    T: int,
    m: int,
    seed: int = 0,
    dt_ms: float = 50.0,
    service_ms: float = 100.0,
    N: int = 4096,
    R: int = 0,
    write_frac: float = 0.05,
    **spec_kw,
) -> Workload:
    """Resolve ``name`` through the registry and build its grid.

    ``R`` defaults to ``4 * cap + 8`` slots per tick; extra keyword
    arguments are forwarded to the spec's constructor.
    """
    cls = get_class(name)
    cap = m * dt_ms / service_ms
    p = WorkloadParams(
        T=T,
        m=m,
        seed=seed,
        dt_ms=dt_ms,
        service_ms=service_ms,
        N=N,
        R=R or int(4 * cap) + 8,
        write_frac=write_frac,
    )
    wl = cls(**spec_kw).build(p)
    return wl._replace(name=name)


# ---------------------------------------------------------------------------
# Shared samplers (used by the built-in generators and scenarios)
# ---------------------------------------------------------------------------


def zipf_cdf(N: int, alpha: float) -> jnp.ndarray:
    ranks = jnp.arange(1, N + 1, dtype=jnp.float32)
    w = ranks ** (-alpha)
    return jnp.cumsum(w) / jnp.sum(w)


def sample_keys(key, shape, N: int, alpha: float, perm_salt: int = 3):
    """Zipf(alpha) keys (alpha=0 → uniform), rank→id decorrelated by
    hashing so hot keys land on "random" servers."""
    if alpha <= 0.0:
        return jax.random.randint(key, shape, 0, N, dtype=jnp.int32)
    cdf = zipf_cdf(N, alpha)
    u = jax.random.uniform(key, shape)
    ranks = jnp.searchsorted(cdf, u).astype(jnp.int32)
    from repro.core.hashring import hash2

    hashed = hash2(ranks.astype(jnp.uint32), jnp.uint32(perm_salt))
    return (hashed % jnp.uint32(N)).astype(jnp.int32)


def hot_subset_keys(
    key,
    shape,
    epoch_idx: jnp.ndarray,
    N: int,
    *,
    subset: int,
    alpha: float,
    salt: int,
) -> jnp.ndarray:
    """Zipf(alpha) keys over a small hot subset that rotates per epoch
    (each burst/storm is a different job hitting different directories)."""
    from repro.core.hashring import hash2

    cdf = zipf_cdf(subset, alpha)
    u = jax.random.uniform(key, shape)
    ranks = jnp.searchsorted(cdf, u).astype(jnp.int32)
    epochs = epoch_idx[:, None].astype(jnp.uint32)
    mixed = hash2(
        ranks.astype(jnp.uint32) + jnp.uint32(subset) * epochs,
        jnp.uint32(salt),
    )
    return (mixed % jnp.uint32(N)).astype(jnp.int32)


def assemble(
    key,
    rate_per_tick: jnp.ndarray,
    R: int,
    N: int,
    alpha: float,
    write_frac: float,
    name: str,
    hot_subset: int = 0,
) -> Workload:
    """Poisson arrivals at rate_per_tick; keys zipf(alpha) (optionally over
    a hot subset of the namespace, modeling one hot directory)."""
    T = rate_per_tick.shape[0]
    k1, k2, k3 = jax.random.split(key, 3)
    counts = jax.random.poisson(k1, rate_per_tick).astype(jnp.int32)
    counts = jnp.minimum(counts, R)
    mask = jnp.arange(R)[None, :] < counts[:, None]
    keys = sample_keys(k2, (T, R), hot_subset or N, alpha)
    is_write = jax.random.uniform(k3, (T, R)) < write_frac
    return Workload(
        keys=keys, mask=mask, is_write=is_write & mask, name=name, N=N
    )
