"""Cooperative metadata cache with leases / invalidations / adaptive TTLs.

Semantics (paper §IV-C):
  * only read-mostly ops (lookup/getattr/readdir) are cacheable;
  * an entry is served only within its validity horizon — lease expiry,
    explicit invalidation, or adaptive TTL; never past it;
  * coherence modes:
      - "lease"         — CephFS/HyCache+-style: writes invalidate proxy
                          entries immediately; entries otherwise live until
                          lease expiry.  Staleness is zero by construction.
      - "ttl_aggregate" — BeeGFS-style fallback: one hazard estimator for
                          the whole class, slow-loop tuned:
                              ĥ ← (1−β)·ĥ + β·rate      (β = 0.1)
                              TTL = −ln(1−p*)/ĥ
                          shrunk ×γ (=0.5) when write fraction > W_high,
                          floored at one RTT.
      - "ttl_per_key"   — the same hazard formula applied per key
                          (class = key): ĥ_k ← (1−β)ĥ_k + β/Δt_k at each
                          write of k, TTL_k set at install time.  This is
                          what restores P(stale) ≈ p* under zipf-skewed
                          write traffic, where the aggregate estimator
                          underestimates hot-key invalidation hazards.

The proxy-side cooperative table is modeled per-namespace-key (the paper's
space bound is O(m + C)); gossip makes entries visible to all proxies — we
model the converged shared table directly.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

BETA = 0.1
GAMMA = 0.5
W_HIGH = 0.3
P_STAR = 1e-4
TTL_CAP_MS = 60_000.0
MODES = ("lease", "ttl_aggregate", "ttl_per_key")


class CacheState(NamedTuple):
    expiry_ms: jnp.ndarray        # (N,) float32 absolute expiry time
    cached_version: jnp.ndarray   # (N,) int32 version stored at insert
    global_version: jnp.ndarray   # (N,) int32 authoritative version
    last_write_ms: jnp.ndarray    # (N,) float32 last write time per key
    key_hazard: jnp.ndarray       # (N,) float32 per-key ĥ (1/ms)
    ttl_ms: jnp.ndarray           # () float32 aggregate adaptive TTL
    hazard: jnp.ndarray           # () float32 aggregate ĥ
    write_frac: jnp.ndarray       # () float32 EWMA of write mix W_c
    win_writes: jnp.ndarray       # () float32 slow-window writes
    win_reads: jnp.ndarray        # () float32 slow-window reads
    hits: jnp.ndarray             # () int32
    misses: jnp.ndarray           # () int32
    stale_serves: jnp.ndarray     # () int32


def init_cache(N: int, ttl_init_ms: float = 100.0) -> CacheState:
    z32 = jnp.zeros((), jnp.int32)
    zf = jnp.zeros((), jnp.float32)
    return CacheState(
        expiry_ms=jnp.zeros((N,), jnp.float32),
        cached_version=jnp.full((N,), -1, jnp.int32),
        global_version=jnp.zeros((N,), jnp.int32),
        last_write_ms=jnp.full((N,), -1.0, jnp.float32),
        key_hazard=jnp.zeros((N,), jnp.float32),
        ttl_ms=jnp.asarray(ttl_init_ms, jnp.float32),
        hazard=jnp.asarray(1e-6, jnp.float32),
        write_frac=zf, win_writes=zf, win_reads=zf,
        hits=z32, misses=z32, stale_serves=z32)


def lookup_batch(cache: CacheState, keys: jnp.ndarray, mask: jnp.ndarray,
                 is_write: jnp.ndarray, now_ms: jnp.ndarray, *,
                 mode: str = "lease", lease_ms: float = 5000.0,
                 rtt_ms: float = 2.0, p_star: float = P_STAR,
                 ) -> Tuple[CacheState, jnp.ndarray]:
    """Process one tick of requests against the cooperative cache.

    Reads hitting a valid entry are served at the proxy (no server load).
    Writes always reach the server, bump the authoritative version and, in
    lease mode, invalidate the proxy entry.  Returns
    (new_cache, served_locally: (R,) bool).
    """
    assert mode in MODES, mode
    N = cache.expiry_ms.shape[0]
    valid = mask & ~is_write
    entry_live = ((cache.expiry_ms[keys] > now_ms)
                  & (cache.cached_version[keys] >= 0))
    hit = valid & entry_live
    stale = hit & (cache.cached_version[keys] < cache.global_version[keys])

    # --- writes: version bump + hazard update (+ lease invalidation) ------
    # sentinel must be OOB (N): negative indices wrap in JAX; mode="drop"
    # only drops genuinely out-of-bounds scatters.
    w = is_write & mask
    wk = jnp.where(w, keys, N)
    gv = cache.global_version.at[wk].add(1, mode="drop")
    dt = jnp.maximum(now_ms - cache.last_write_ms[jnp.minimum(wk, N - 1)],
                     1.0)
    seen = cache.last_write_ms[jnp.minimum(wk, N - 1)] >= 0.0
    upd = jnp.where(seen,
                    (1.0 - BETA) * cache.key_hazard[jnp.minimum(wk, N - 1)]
                    + BETA / dt,
                    1.0 / jnp.maximum(dt, 1.0))
    key_hazard = cache.key_hazard.at[wk].set(upd, mode="drop")
    last_write = cache.last_write_ms.at[wk].set(now_ms, mode="drop")
    expiry = cache.expiry_ms
    if mode == "lease":
        expiry = expiry.at[wk].set(0.0, mode="drop")   # immediate invalidation

    # --- misses install the entry with the mode's validity horizon --------
    miss = valid & ~hit
    mk = jnp.where(miss, keys, N)
    mk_safe = jnp.minimum(mk, N - 1)
    if mode == "lease":
        ttl_k = jnp.full(keys.shape, lease_ms, jnp.float32)
    elif mode == "ttl_aggregate":
        ttl_k = jnp.full(keys.shape, 1.0, jnp.float32) * cache.ttl_ms
    else:  # ttl_per_key
        # hierarchical: per-key hazard when observed, class hazard as the
        # conservative prior for keys with no write history yet ("TTLs err
        # on freshness", §IV-C).
        h = jnp.maximum(key_hazard[mk_safe],
                        jnp.maximum(cache.hazard, 1e-9))
        ttl_k = -jnp.log1p(-p_star) / h
        ttl_k = jnp.clip(ttl_k, rtt_ms, TTL_CAP_MS)
    expiry = expiry.at[mk].set(now_ms + ttl_k, mode="drop")
    cached_v = cache.cached_version.at[mk].set(gv[mk_safe], mode="drop")

    new = cache._replace(
        expiry_ms=expiry, cached_version=cached_v, global_version=gv,
        last_write_ms=last_write, key_hazard=key_hazard,
        win_writes=cache.win_writes + jnp.sum(w),
        win_reads=cache.win_reads + jnp.sum(valid),
        hits=cache.hits + jnp.sum(hit).astype(jnp.int32),
        misses=cache.misses + jnp.sum(miss).astype(jnp.int32),
        stale_serves=cache.stale_serves + jnp.sum(stale).astype(jnp.int32))
    return new, hit


def slow_update(cache: CacheState, window_ms: float, rtt_ms: float,
                lease_remaining_ms: float = jnp.inf,
                p_star: float = P_STAR) -> CacheState:
    """T_slow retune of the aggregate TTL from the hazard estimator."""
    n_cached = jnp.maximum(jnp.sum(cache.cached_version >= 0), 1)
    rate = cache.win_writes / n_cached / window_ms   # invalidations/entry/ms
    hazard = (1.0 - BETA) * cache.hazard + BETA * rate
    hazard = jnp.maximum(hazard, 1e-9)
    ttl = -jnp.log1p(-p_star) / hazard
    ttl = jnp.minimum(ttl, lease_remaining_ms)
    wf = cache.win_writes / jnp.maximum(cache.win_writes + cache.win_reads,
                                        1.0)
    write_frac = (1.0 - BETA) * cache.write_frac + BETA * wf
    ttl = jnp.where(write_frac > W_HIGH, ttl * GAMMA, ttl)
    ttl = jnp.clip(ttl, rtt_ms, TTL_CAP_MS)  # transport floor: >= one RTT
    zf = jnp.zeros((), jnp.float32)
    return cache._replace(ttl_ms=ttl, hazard=hazard, write_frac=write_frac,
                          win_writes=zf, win_reads=zf)
