"""Cooperative metadata cache with leases / invalidations / adaptive TTLs.

Semantics (paper §IV-C):
  * only read-mostly ops (lookup/getattr/readdir) are cacheable;
  * an entry is served only within its validity horizon — lease expiry,
    explicit invalidation, or adaptive TTL; never past it;
  * coherence modes:
      - "lease"         — CephFS/HyCache+-style: writes invalidate proxy
                          entries immediately; entries otherwise live until
                          lease expiry.  Staleness is zero by construction.
      - "ttl_aggregate" — BeeGFS-style fallback: one hazard estimator for
                          the whole class, slow-loop tuned:
                              ĥ ← (1−β)·ĥ + β·rate      (β = 0.1)
                              TTL = −ln(1−p*)/ĥ
                          shrunk ×γ (=0.5) when write fraction > W_high,
                          floored at one RTT.
      - "ttl_per_key"   — the same hazard formula applied per key
                          (class = key): ĥ_k ← (1−β)ĥ_k + β/Δt_k at each
                          write of k, TTL_k set at install time.  This is
                          what restores P(stale) ≈ p* under zipf-skewed
                          write traffic, where the aggregate estimator
                          underestimates hot-key invalidation hazards.

Write-pressure guard: when the write-mix signal (slow-loop EWMA or the
live window once it has samples, see :func:`write_pressure`) exceeds
``W_HIGH``, the cache stops *installing* new entries (serve-through)
instead of merely shrinking TTLs — under mutation-dominated traffic
(rename storms) installs are invalidated before they can be reused, so
caching only adds staleness risk and churn.  Bypassed installs are
counted in ``CacheState.bypasses``.

This module holds the *converged shared table*: the state every proxy
agrees on once gossip has propagated (the paper's space bound is
O(m + C) per-namespace-key).  ``lookup_batch`` processes a tick against
that table directly — the Δ=0 gossip limit.  The multi-proxy view, where
announcements and invalidations take ``gossip_ms`` to travel, lives in
:mod:`repro.core.fleet`, which reuses :func:`classify` /
:func:`apply_batch` so the two models are bit-for-bit identical at Δ=0.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp

from repro.core.faults.base import AVAIL_FULL

BETA = 0.1
GAMMA = 0.5
W_HIGH = 0.3
P_STAR = 1e-4
TTL_CAP_MS = 60_000.0
GUARD_MIN_EVENTS = 64.0
MODES = ("lease", "ttl_aggregate", "ttl_per_key")


class CacheState(NamedTuple):
    expiry_ms: jnp.ndarray       # (N,) float32 absolute expiry time
    cached_version: jnp.ndarray  # (N,) int32 version stored at insert
    global_version: jnp.ndarray  # (N,) int32 authoritative version
    last_write_ms: jnp.ndarray   # (N,) float32 last write time per key
    key_hazard: jnp.ndarray      # (N,) float32 per-key ĥ (1/ms)
    ttl_ms: jnp.ndarray          # () float32 aggregate adaptive TTL
    hazard: jnp.ndarray          # () float32 aggregate ĥ
    write_frac: jnp.ndarray      # () float32 EWMA of write mix W_c
    win_writes: jnp.ndarray      # () float32 slow-window writes
    win_reads: jnp.ndarray       # () float32 slow-window reads
    hits: jnp.ndarray            # () int32
    misses: jnp.ndarray          # () int32
    stale_serves: jnp.ndarray    # () int32
    bypasses: jnp.ndarray        # () int32 installs skipped by the guard


def init_cache(N: int, ttl_init_ms: float = 100.0) -> CacheState:
    z32 = jnp.zeros((), jnp.int32)
    zf = jnp.zeros((), jnp.float32)
    return CacheState(
        expiry_ms=jnp.zeros((N,), jnp.float32),
        cached_version=jnp.full((N,), -1, jnp.int32),
        global_version=jnp.zeros((N,), jnp.int32),
        last_write_ms=jnp.full((N,), -1.0, jnp.float32),
        key_hazard=jnp.zeros((N,), jnp.float32),
        ttl_ms=jnp.asarray(ttl_init_ms, jnp.float32),
        hazard=jnp.asarray(1e-6, jnp.float32),
        write_frac=zf,
        win_writes=zf,
        win_reads=zf,
        hits=z32,
        misses=z32,
        stale_serves=z32,
        bypasses=z32,
    )


def write_pressure(cache: CacheState) -> jnp.ndarray:
    """Write-mix signal the install guard compares against ``W_HIGH``.

    The slow-loop EWMA (β=0.1 per T_slow window) carries hysteresis
    across windows but needs minutes to cross W_HIGH; a rename storm is
    over before it reacts.  So the guard also listens to the *live*
    window's mix once it holds enough events to be meaningful —
    whichever signal is higher wins.
    """
    n = cache.win_writes + cache.win_reads
    wf_window = cache.win_writes / jnp.maximum(n, 1.0)
    live = jnp.where(n >= GUARD_MIN_EVENTS, wf_window, 0.0)
    return jnp.maximum(cache.write_frac, live)


class BatchEffects(NamedTuple):
    """Per-request effect vectors of one ``apply_batch`` tick — the
    single source both models derive counters and gossip events from."""

    inv_keys: jnp.ndarray  # (R,) invalidation-event keys (sentinel N)
    ins_keys: jnp.ndarray  # (R,) install-event keys (sentinel N)
    miss: jnp.ndarray      # (R,) bool valid read misses
    bypassed: jnp.ndarray  # (R,) bool misses the guard served through


def classify(
    expiry_view: jnp.ndarray,
    version_view: jnp.ndarray,
    gv_view: jnp.ndarray,
    mask: jnp.ndarray,
    is_write: jnp.ndarray,
    now_ms: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Classify one tick's requests against a *view* of the table.

    ``expiry_view`` / ``version_view`` are the per-request (R,) entry
    fields as the serving proxy sees them (the converged table in the
    shared model; possibly gossip-lagged in the fleet model).  ``gv_view``
    is the authoritative version — staleness is an omniscient metric, so
    it is never lagged.  Returns ``(valid, hit, stale)`` bool vectors.
    """
    valid = mask & ~is_write
    live = (expiry_view > now_ms) & (version_view >= 0)
    hit = valid & live
    stale = hit & (version_view < gv_view)
    return valid, hit, stale


def apply_batch(
    cache: CacheState,
    keys: jnp.ndarray,
    mask: jnp.ndarray,
    is_write: jnp.ndarray,
    hit: jnp.ndarray,
    stale: jnp.ndarray,
    now_ms: jnp.ndarray,
    *,
    mode: str = "lease",
    lease_ms: float = 5000.0,
    rtt_ms: float = 2.0,
    p_star: float = P_STAR,
    avail: Optional[jnp.ndarray] = None,
) -> Tuple[CacheState, BatchEffects]:
    """Apply one tick's effects to the converged table, given hit flags.

    Writes always reach the server: they bump the authoritative version,
    feed the hazard estimators and, in lease mode, invalidate the entry.
    Misses install an entry with the mode's validity horizon — unless the
    write-pressure guard is active, in which case installs are bypassed
    and counted.  ``avail`` (optional () float32, the detected live
    fraction from the fault layer) extends the guard: while membership
    is degraded (``avail < AVAIL_FULL``) installs are bypassed too —
    entries installed against a shrunken ring would be invalidated
    wholesale at the next remap epoch, so installing only adds churn.

    Returns ``(new_cache, effects)``: the event-key vectors in
    ``effects`` (sentinel ``N`` where no event) are the gossip payload
    the fleet model propagates between proxies, and its flag vectors are
    what per-proxy counters must be derived from so they always sum to
    the aggregate counters updated here.
    """
    assert mode in MODES, mode
    N = cache.expiry_ms.shape[0]
    valid = mask & ~is_write

    # --- writes: version bump + hazard update (+ lease invalidation) -----
    # sentinel must be OOB (N): negative indices wrap in JAX; mode="drop"
    # only drops genuinely out-of-bounds scatters.
    w = is_write & mask
    wk = jnp.where(w, keys, N)
    wk_safe = jnp.minimum(wk, N - 1)
    gv = cache.global_version.at[wk].add(1, mode="drop")
    if mode == "ttl_per_key":
        dt = jnp.maximum(now_ms - cache.last_write_ms[wk_safe], 1.0)
        seen = cache.last_write_ms[wk_safe] >= 0.0
        decayed = (1.0 - BETA) * cache.key_hazard[wk_safe] + BETA / dt
        upd = jnp.where(seen, decayed, 1.0 / jnp.maximum(dt, 1.0))
        key_hazard = cache.key_hazard.at[wk].set(upd, mode="drop")
        last_write = cache.last_write_ms.at[wk].set(now_ms, mode="drop")
    else:
        # the per-key hazard log feeds only the ttl_per_key horizon;
        # lease / ttl_aggregate leave both (N,) tables untouched — two
        # fewer full-table scatters on every tick of the hot path
        key_hazard = cache.key_hazard
        last_write = cache.last_write_ms
    expiry = cache.expiry_ms
    if mode == "lease":
        # immediate invalidation at the (converged) proxy table
        expiry = expiry.at[wk].set(0.0, mode="drop")
        inv_k = wk
    else:
        inv_k = jnp.full_like(wk, N)  # TTL modes: expiry-only, no events

    # --- misses install the entry with the mode's validity horizon -------
    # ... unless the write-pressure guard trips: serve-through, no install
    miss = valid & ~hit
    bypass = write_pressure(cache) > W_HIGH
    if avail is not None:
        bypass = bypass | (avail < AVAIL_FULL)
    install = miss & ~bypass
    mk = jnp.where(install, keys, N)
    mk_safe = jnp.minimum(mk, N - 1)
    if mode == "lease":
        ttl_k = jnp.full(keys.shape, lease_ms, jnp.float32)
    elif mode == "ttl_aggregate":
        ttl_k = jnp.full(keys.shape, 1.0, jnp.float32) * cache.ttl_ms
    else:  # ttl_per_key
        # hierarchical: per-key hazard when observed, class hazard as the
        # conservative prior for keys with no write history yet ("TTLs
        # err on freshness", §IV-C).
        h = jnp.maximum(key_hazard[mk_safe], jnp.maximum(cache.hazard, 1e-9))
        ttl_k = -jnp.log1p(-p_star) / h
        ttl_k = jnp.clip(ttl_k, rtt_ms, TTL_CAP_MS)
    expiry = expiry.at[mk].set(now_ms + ttl_k, mode="drop")
    cached_v = cache.cached_version.at[mk].set(gv[mk_safe], mode="drop")

    new = cache._replace(
        expiry_ms=expiry,
        cached_version=cached_v,
        global_version=gv,
        last_write_ms=last_write,
        key_hazard=key_hazard,
        win_writes=cache.win_writes + jnp.sum(w),
        win_reads=cache.win_reads + jnp.sum(valid),
        hits=cache.hits + jnp.sum(hit).astype(jnp.int32),
        misses=cache.misses + jnp.sum(miss).astype(jnp.int32),
        stale_serves=cache.stale_serves + jnp.sum(stale).astype(jnp.int32),
        bypasses=cache.bypasses + jnp.sum(miss & bypass).astype(jnp.int32),
    )
    eff = BatchEffects(
        inv_keys=inv_k, ins_keys=mk, miss=miss, bypassed=miss & bypass
    )
    return new, eff


def lookup_batch(
    cache: CacheState,
    keys: jnp.ndarray,
    mask: jnp.ndarray,
    is_write: jnp.ndarray,
    now_ms: jnp.ndarray,
    *,
    mode: str = "lease",
    lease_ms: float = 5000.0,
    rtt_ms: float = 2.0,
    p_star: float = P_STAR,
    avail: Optional[jnp.ndarray] = None,
) -> Tuple[CacheState, jnp.ndarray]:
    """Process one tick of requests against the converged shared table.

    Reads hitting a valid entry are served at the proxy (no server load).
    Writes always reach the server, bump the authoritative version and,
    in lease mode, invalidate the proxy entry.  ``avail`` feeds the
    availability install guard (see :func:`apply_batch`).  Returns
    (new_cache, served_locally: (R,) bool).
    """
    assert mode in MODES, mode
    _, hit, stale = classify(
        cache.expiry_ms[keys],
        cache.cached_version[keys],
        cache.global_version[keys],
        mask,
        is_write,
        now_ms,
    )
    new, _ = apply_batch(
        cache,
        keys,
        mask,
        is_write,
        hit,
        stale,
        now_ms,
        mode=mode,
        lease_ms=lease_ms,
        rtt_ms=rtt_ms,
        p_star=p_star,
        avail=avail,
    )
    return new, hit


def remap_invalidate(
    cache: CacheState, moved: jnp.ndarray
) -> CacheState:
    """Drop every entry whose ring owner just changed (``moved``: (N,)
    bool from the fault layer's per-epoch owner diff).

    Placement shift makes a cached entry unverifiable — the proxy's
    lease/TTL was granted by a server that no longer owns the key — so
    expiry is zeroed (never-live) and the next read revalidates at the
    new owner.  Entries whose owner did not move are untouched
    (consistent-hashing minimal disruption carries over to the cache).
    """
    return cache._replace(
        expiry_ms=jnp.where(moved, 0.0, cache.expiry_ms)
    )


def slow_update(
    cache: CacheState,
    window_ms: float,
    rtt_ms: float,
    lease_remaining_ms: float = jnp.inf,
    p_star: float = P_STAR,
    ttl_scale=1.0,
) -> CacheState:
    """T_slow retune of the aggregate TTL from the hazard estimator.

    ``ttl_scale`` is the controller-emitted TTL multiplier
    (``Knobs.ttl_scale``, bounds in ``controllers.KNOB_SPECS``): the
    hazard estimator owns the horizon, the control plane scales it —
    applied before the transport floor/cap so a shrinking controller
    can never push a TTL below one RTT.  The default (1.0) is exact
    identity.
    """
    n_cached = jnp.maximum(jnp.sum(cache.cached_version >= 0), 1)
    rate = cache.win_writes / n_cached / window_ms  # invalidations/entry/ms
    hazard = (1.0 - BETA) * cache.hazard + BETA * rate
    hazard = jnp.maximum(hazard, 1e-9)
    ttl = -jnp.log1p(-p_star) / hazard
    ttl = jnp.minimum(ttl, lease_remaining_ms)
    n_events = jnp.maximum(cache.win_writes + cache.win_reads, 1.0)
    wf = cache.win_writes / n_events
    write_frac = (1.0 - BETA) * cache.write_frac + BETA * wf
    ttl = jnp.where(write_frac > W_HIGH, ttl * GAMMA, ttl)
    ttl = ttl * ttl_scale  # controller slow-loop retune (Knobs.ttl_scale)
    ttl = jnp.clip(ttl, rtt_ms, TTL_CAP_MS)  # transport floor: >= one RTT
    zf = jnp.zeros((), jnp.float32)
    return cache._replace(
        ttl_ms=ttl,
        hazard=hazard,
        write_frac=write_frac,
        win_writes=zf,
        win_reads=zf,
    )
