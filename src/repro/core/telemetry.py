"""Telemetry: EWMA smoothing, latency sketches, streaming accumulators.

Proxies observe *server-reported* telemetry — in-flight queue length and
recent latency quantiles — with at most one fast-interval of delay (paper
§IV-E assumption 1).  The windowed :class:`LatencySketch` is a per-server
ring buffer of recent latency observations; quantiles are computed over
the valid window.

Three helpers back the engine's hot path (DESIGN.md §9):

* :class:`HistSketch` — a fixed-bin log-histogram that accumulates
  weighted samples in O(bins) memory and answers arbitrary quantiles
  post-hoc; the accumulator behind ``simulate_sweep(metrics="summary")``.
* :func:`weighted_quantiles` — the one exact arrival-weighted quantile
  implementation (host-side), shared by ``SimResult`` and warmup (both
  previously carried their own copy of the same fp-clip workaround).
* :func:`ewma_series` — a vectorized closed-form EWMA filter over a
  timeline, replacing the O(T) Python loop that dominated warmup
  wall-time on long horizons.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


def ewma(prev: jnp.ndarray, x: jnp.ndarray, alpha: float) -> jnp.ndarray:
    """x̂_t = (1-α)·x̂_{t-1} + α·x_t   (paper eq., α=0.2 fast loop)."""
    return (1.0 - alpha) * prev + alpha * x


def ewma_series(
    x: np.ndarray, alpha: float, block: int = 512, init: float = 0.0
) -> np.ndarray:
    """EWMA-smooth a (T, ...) series along axis 0 (host-side, float64).

    Closed form per block: with decay ρ = 1-α and p_t = ρ^(t+1),
    x̂_t = p_t · (x̂_init + Σ_{j≤t} α·x_j / p_j), so one cumsum replaces
    the per-step recurrence.  Blocks bound the rescaling's dynamic range
    to ρ^(-block); contributions older than a block have decayed by the
    same factor they are scaled by, so relative precision is preserved
    for any horizon.  ``init`` is x̂ before the first sample — 0 matches
    the controller; the windowing detector (``repro.obs.windows``)
    passes ``init=x[0]`` so the filter adds no artificial ramp.
    """
    x = np.asarray(x, np.float64)
    if x.ndim == 0 or x.shape[0] == 0:
        return x.copy()
    rho = 1.0 - alpha
    if rho <= 0.0:
        return alpha * x
    # keep ρ^block well above the float64 underflow floor: past it the
    # rescale divides by 0 and poisons the tail with inf/NaN (fast-decay
    # alphas like 0.9 would underflow ρ^512)
    block = min(block, max(int(-575.0 / np.log(rho)), 1))
    out = np.empty_like(x)
    acc = np.full(x.shape[1:], float(init), np.float64)
    for s in range(0, x.shape[0], block):
        xb = x[s : s + block]
        n = xb.shape[0]
        p = rho ** np.arange(1, n + 1, dtype=np.float64)
        pb = p.reshape((n,) + (1,) * (x.ndim - 1))
        out[s : s + n] = pb * (acc + np.cumsum(alpha * xb / pb, axis=0))
        acc = out[s + n - 1]
    return out


def weighted_quantiles(
    values: np.ndarray, weights: np.ndarray, qs: Sequence[float]
) -> Tuple[float, ...]:
    """Exact weight-CDF quantiles of ``values`` (host-side numpy).

    Sorts by value and returns, for each q, the first value whose
    normalized cumulative weight reaches q/100.  fp rounding can leave
    the final cumulative weight below 1.0, which would push
    ``searchsorted`` past the last index — clip (regression-tested).
    Zero (or negative) total weight returns 0.0 for every q.
    """
    v = np.asarray(values, np.float64).reshape(-1)
    w = np.asarray(weights, np.float64).reshape(-1)
    total = w.sum()
    if total <= 0:
        return tuple(0.0 for _ in qs)
    order = np.argsort(v, kind="stable")
    v, w = v[order], w[order]
    cum = np.cumsum(w) / total
    last = v.size - 1
    return tuple(
        float(v[min(int(np.searchsorted(cum, q / 100.0)), last)])
        for q in qs
    )


CONSENSUS_REDUCERS = ("mean", "median", "max")


def reduce_views(views_p: jnp.ndarray, reducer: str = "mean") -> jnp.ndarray:
    """Collapse a (P, m) stack of per-proxy views along the proxy axis —
    the consensus the fleet's one logical control loop consumes
    (``SimConfig.consensus``).  ``mean`` is the paper's aggregate;
    ``median`` is robust to one badly lagged staggered view; ``max`` is
    the conservative worst-proxy consensus."""
    if reducer == "mean":
        return jnp.mean(views_p, axis=0)
    if reducer == "median":
        return jnp.median(views_p, axis=0)
    if reducer == "max":
        return jnp.max(views_p, axis=0)
    raise ValueError(
        f"unknown consensus reducer {reducer!r}; available: "
        f"{', '.join(CONSENSUS_REDUCERS)}"
    )


def staggered_phases(P: int, period_ticks: int) -> jnp.ndarray:
    """(P,) ingest phases spreading P proxies evenly over one fast
    interval.  Independent proxies poll server telemetry on their own
    clocks; staggering is what makes their smoothed views *diverge* —
    proxy p's view is up to ``period·(P-1)/P`` ticks staler than proxy
    p+1's at any instant (fleet mode, §IV-E assumption 1 per proxy)."""
    return (jnp.arange(P, dtype=jnp.int32) * period_ticks) // P


def ewma_staggered(
    views: jnp.ndarray,
    obs: jnp.ndarray,
    tick: jnp.ndarray,
    period_ticks: int,
    alpha: float,
) -> jnp.ndarray:
    """Update the (P, m) per-proxy EWMA views: proxy p ingests ``obs``
    only on its own staggered phase this tick; other views keep aging."""
    P = views.shape[0]
    due = (tick % period_ticks) == staggered_phases(P, period_ticks)
    return jnp.where(due[:, None], ewma(views, obs[None, :], alpha), views)


class LatencySketch(NamedTuple):
    buf: jnp.ndarray  # (m, K) float32 latency observations (ms)
    idx: jnp.ndarray  # () int32 next write slot (shared across servers)
    count: jnp.ndarray  # () int32 total observations so far


def make_sketch(m: int, K: int = 64) -> LatencySketch:
    return LatencySketch(
        buf=jnp.zeros((m, K), jnp.float32),
        idx=jnp.zeros((), jnp.int32),
        count=jnp.zeros((), jnp.int32),
    )


def sketch_add(sk: LatencySketch, obs: jnp.ndarray) -> LatencySketch:
    """Add one observation per server (obs: (m,) ms)."""
    K = sk.buf.shape[1]
    buf = sk.buf.at[:, sk.idx % K].set(obs)
    return LatencySketch(buf=buf, idx=sk.idx + 1, count=sk.count + 1)


def sketch_quantiles(sk: LatencySketch) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(p50, p99) per server over the valid window; zeros when empty."""
    K = sk.buf.shape[1]
    n = jnp.minimum(sk.count, K)
    # mask invalid slots with +inf then take sorted-order quantiles over n
    valid = jnp.arange(K) < n
    big = jnp.where(valid[None, :], sk.buf, jnp.inf)
    srt = jnp.sort(big, axis=1)
    nn = jnp.maximum(n, 1)
    i50 = jnp.clip((nn - 1) / 2, 0, K - 1)
    i99 = jnp.clip(jnp.ceil(0.99 * (nn.astype(jnp.float32) - 1)), 0, K - 1)

    def take(frac_idx):
        lo = jnp.floor(frac_idx).astype(jnp.int32)
        hi = jnp.minimum(lo + 1, nn - 1).astype(jnp.int32)
        w = frac_idx - lo
        return (1 - w) * srt[:, lo] + w * srt[:, hi]

    p50 = jnp.where(n > 0, take(i50.astype(jnp.float32)), 0.0)
    p99 = jnp.where(n > 0, take(i99.astype(jnp.float32)), 0.0)
    return p50, p99


def imbalance(L_hat: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """B(t) = std(L̂)/(mean(L̂)+ε)  — the paper's smoothed imbalance."""
    return jnp.std(L_hat) / (jnp.mean(L_hat) + eps)


def imbalance_masked(
    L_hat: jnp.ndarray, live: jnp.ndarray, eps: float = 1e-6
) -> jnp.ndarray:
    """:func:`imbalance` over the detected-live servers only.

    A crashed server's frozen queue would otherwise dominate B(t) and
    pin the controller at maximum pressure for the whole outage; the
    control question during a membership fault is whether the
    *survivors* are balanced.  With every server live this is exactly
    :func:`imbalance` (weights all one), so the fault engine can swap
    it in unconditionally on membership-fault paths.
    """
    w = jnp.asarray(live, L_hat.dtype)
    n = jnp.maximum(jnp.sum(w), 1.0)
    mu = jnp.sum(L_hat * w) / n
    var = jnp.sum(w * (L_hat - mu) ** 2) / n
    return jnp.sqrt(var) / (mu + eps)


# ---------------------------------------------------------------------------
# Streaming histogram sketch (metrics="summary" accumulator)
# ---------------------------------------------------------------------------

HIST_BINS = 512
HIST_LO = 1e-2
HIST_HI = 1e6


@functools.lru_cache(maxsize=None)
def _hist_edges() -> np.ndarray:
    """Log-spaced bin edges shared by every sketch (host constant)."""
    return np.geomspace(HIST_LO, HIST_HI, HIST_BINS + 1)


class HistSketch(NamedTuple):
    """Streaming weighted histogram over a fixed log-spaced grid.

    ``counts[0]`` is the underflow bin (values ≤ HIST_LO, including the
    exact zeros a queue timeline is full of; represented as 0.0) and
    ``counts[-1]`` the overflow bin.  Memory is O(HIST_BINS) no matter
    how many samples stream through, which is what lets a sweep carry
    its quantiles instead of materializing (T, m) timelines.  Quantile
    answers are bin-resolution approximations (geometric bin midpoints,
    ≤ ~2% relative error over the 8-decade range); the exact reference
    is :func:`weighted_quantiles` over a full timeline.
    """

    counts: jnp.ndarray  # (HIST_BINS + 2,) float32 weighted bin counts


def make_hist() -> HistSketch:
    return HistSketch(counts=jnp.zeros((HIST_BINS + 2,), jnp.float32))


def hist_add(
    sk: HistSketch, values: jnp.ndarray, weights: jnp.ndarray
) -> HistSketch:
    """Scatter-add ``weights`` at the bins of ``values`` (any shape)."""
    edges = jnp.asarray(_hist_edges())
    b = jnp.searchsorted(edges, values.reshape(-1), side="right")
    counts = sk.counts.at[b].add(weights.reshape(-1).astype(jnp.float32))
    return HistSketch(counts=counts)


def hist_quantile(counts: np.ndarray, q: float) -> float:
    """Approximate weight-CDF quantile from sketch counts (host-side):
    the geometric midpoint of the first bin whose cumulative weight
    reaches q/100.  Zero total weight returns 0.0."""
    counts = np.asarray(counts, np.float64)
    total = counts.sum()
    if total <= 0:
        return 0.0
    edges = _hist_edges()
    reps = np.concatenate(
        ([0.0], np.sqrt(edges[:-1] * edges[1:]), [edges[-1]])
    )
    cum = np.cumsum(counts)
    idx = int(np.searchsorted(cum, (q / 100.0) * total))
    return float(reps[min(idx, reps.size - 1)])
