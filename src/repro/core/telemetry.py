"""Telemetry: EWMA smoothing and windowed latency sketches (p50/p99).

Proxies observe *server-reported* telemetry — in-flight queue length and
recent latency quantiles — with at most one fast-interval of delay (paper
§IV-E assumption 1).  The sketch is a per-server ring buffer of recent
latency observations; quantiles are computed over the valid window.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp


def ewma(prev: jnp.ndarray, x: jnp.ndarray, alpha: float) -> jnp.ndarray:
    """x̂_t = (1-α)·x̂_{t-1} + α·x_t   (paper eq., α=0.2 fast loop)."""
    return (1.0 - alpha) * prev + alpha * x


def staggered_phases(P: int, period_ticks: int) -> jnp.ndarray:
    """(P,) ingest phases spreading P proxies evenly over one fast
    interval.  Independent proxies poll server telemetry on their own
    clocks; staggering is what makes their smoothed views *diverge* —
    proxy p's view is up to ``period·(P-1)/P`` ticks staler than proxy
    p+1's at any instant (fleet mode, §IV-E assumption 1 per proxy)."""
    return (jnp.arange(P, dtype=jnp.int32) * period_ticks) // P


def ewma_staggered(views: jnp.ndarray, obs: jnp.ndarray,
                   tick: jnp.ndarray, period_ticks: int,
                   alpha: float) -> jnp.ndarray:
    """Update the (P, m) per-proxy EWMA views: proxy p ingests ``obs``
    only on its own staggered phase this tick; other views keep aging."""
    P = views.shape[0]
    due = (tick % period_ticks) == staggered_phases(P, period_ticks)
    return jnp.where(due[:, None], ewma(views, obs[None, :], alpha), views)


class LatencySketch(NamedTuple):
    buf: jnp.ndarray    # (m, K) float32 latency observations (ms)
    idx: jnp.ndarray    # () int32 next write slot (shared across servers)
    count: jnp.ndarray  # () int32 total observations so far


def make_sketch(m: int, K: int = 64) -> LatencySketch:
    return LatencySketch(buf=jnp.zeros((m, K), jnp.float32),
                         idx=jnp.zeros((), jnp.int32),
                         count=jnp.zeros((), jnp.int32))


def sketch_add(sk: LatencySketch, obs: jnp.ndarray) -> LatencySketch:
    """Add one observation per server (obs: (m,) ms)."""
    K = sk.buf.shape[1]
    buf = sk.buf.at[:, sk.idx % K].set(obs)
    return LatencySketch(buf=buf, idx=sk.idx + 1, count=sk.count + 1)


def sketch_quantiles(sk: LatencySketch) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(p50, p99) per server over the valid window; zeros when empty."""
    K = sk.buf.shape[1]
    n = jnp.minimum(sk.count, K)
    # mask invalid slots with +inf then take sorted-order quantiles over n
    valid = jnp.arange(K) < n
    big = jnp.where(valid[None, :], sk.buf, jnp.inf)
    srt = jnp.sort(big, axis=1)
    nn = jnp.maximum(n, 1)
    i50 = jnp.clip((nn - 1) / 2, 0, K - 1)
    i99 = jnp.clip(jnp.ceil(0.99 * (nn.astype(jnp.float32) - 1)), 0, K - 1)

    def take(frac_idx):
        lo = jnp.floor(frac_idx).astype(jnp.int32)
        hi = jnp.minimum(lo + 1, nn - 1).astype(jnp.int32)
        w = frac_idx - lo
        return (1 - w) * srt[:, lo] + w * srt[:, hi]

    p50 = jnp.where(n > 0, take(i50.astype(jnp.float32)), 0.0)
    p99 = jnp.where(n > 0, take(i99.astype(jnp.float32)), 0.0)
    return p50, p99


def imbalance(L_hat: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """B(t) = std(L̂)/(mean(L̂)+ε)  — the paper's smoothed imbalance."""
    return jnp.std(L_hat) / (jnp.mean(L_hat) + eps)
