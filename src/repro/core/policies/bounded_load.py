"""Consistent hashing with bounded loads (registry proof-point #2).

CHBL (Mirrokni, Thorup & Zadimoghaddam, 2018): every request goes to its
ring primary unless the primary's load exceeds ``c`` times the mean; then it
walks the feasible-set successors clockwise and takes the first server
under the cap (falling back to the least-loaded successor when all are
over).  Unlike power-of-d it steers *deterministically* and only under
overload, so placement stays maximally stable — a useful middle ground
between static hash and JSQ(d), and exactly the kind of policy the paper's
middleware framing says should be pluggable.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.policies.base import (
    Policy,
    RouteStats,
    register,
    steering_dv,
)
from repro.kernels.midas_route import ops as route_ops

C_LOAD = 1.25  # CHBL capacity factor: cap = c * (mean load + 1)


def route_bounded_load(
    feas: jnp.ndarray,
    L_view: jnp.ndarray,
    mask: jnp.ndarray,
    c: float = C_LOAD,
    impl: str = "ref",
) -> jnp.ndarray:
    """First feasible successor under the load cap; primary when it fits.

    The cap is a mean over the full (m,) view, so it is computed here
    (outside any token tile) and handed to the kernel as a scalar — the
    same value both impls compare against, keeping parity bitwise.
    """
    cap = c * (jnp.mean(L_view) + 1.0)
    if impl == "pallas":
        z = jnp.zeros((), jnp.float32)
        assign, _ = route_ops.route_waves(
            feas,
            L_view,
            L_view,
            jnp.zeros(feas.shape, jnp.int32),
            jnp.zeros(feas.shape, jnp.float32),
            jnp.stack([z, z, jnp.asarray(cap, jnp.float32), z]),
            mode="chbl",
        )
    else:
        Lf = L_view[feas]  # (R, d_max)
        under = Lf <= cap
        first_under = jnp.argmax(under, axis=1)  # first True slot
        least_loaded = jnp.argmin(Lf, axis=1)  # fallback: all over cap
        slot = jnp.where(jnp.any(under, axis=1), first_under, least_loaded)
        assign = jnp.take_along_axis(feas, slot[:, None], axis=1)[:, 0]
    return jnp.where(mask, assign, -1)


@register("chbl")
class BoundedLoadHash(Policy):
    """Consistent hashing with bounded loads (cap = 1.25 * (mean + 1))."""

    def route(self, state, ctx):
        assign = route_bounded_load(
            ctx.feas, ctx.L_view, ctx.mask, impl=ctx.route_impl
        )
        moved = ctx.mask & (assign != ctx.primary)
        z = jnp.zeros((), jnp.float32)
        return state, assign, RouteStats(
            steered=jnp.sum(moved).astype(jnp.float32),
            eligible=z,
            dV=steering_dv(ctx, assign),
        )
