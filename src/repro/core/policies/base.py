"""Policy protocol, route context, and the policy registry.

A *policy* is the routing stage of the MIDAS middleware pipeline: given a
wave of requests and the proxies' (stale) view of server state, it assigns
each request to a metadata server.  Policies are self-contained modules that
register themselves by name; the simulator resolves ``cfg.policy`` through
the registry and never branches on policy names.

Protocol
--------
``Policy.init(cfg, ring) -> state`` builds the policy's carried pytree
(``()`` for stateless policies).  ``Policy.route(state, ctx) ->
(state, assign, RouteStats)`` routes one wave: ``assign`` is ``(R,)`` int32
server ids (−1 for masked-out slots) and ``RouteStats`` carries the
steering telemetry the control loop and benchmarks consume.

``RouteContext`` bundles everything a policy may consult: the request keys
and validity mask, the namespace-feasible set from the consistent-hash ring
(slot 0 is the primary), the stale telemetry views (L̂, p̃50), the control
knobs, the tick clock, and a per-wave PRNG key.  Policies read what they
need; XLA dead-code-eliminates the rest.

Scan contract (DESIGN.md §9).  The engine runs a tick's routing waves as
a single ``jax.lax.scan`` whose carry threads the policy state: the
feasible sets and per-wave PRNG keys in ``RouteContext`` are gathered /
pre-split for all waves up front, and ``route`` is traced ONCE per
compile regardless of ``n_groups``/``P``.  Two obligations follow:
``route`` must return a state pytree with the same structure and leaf
shapes it received (it is a scan carry), and it must not branch on a
Python-level wave index (waves are indistinguishable at trace time).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple, Type

import jax
import jax.numpy as jnp

from repro.core import registry as registry_lib
from repro.core.controllers.base import Knobs

# The control-plane view handed to policies is the declarative knob
# schema itself (repro.core.controllers.base.Knobs), emitted by the
# configured controller's ``view`` — ablation decorators already
# applied.  The pre-PR5 name survives as an alias.
ControlKnobs = Knobs


class RouteContext(NamedTuple):
    """One routing wave, as seen by a policy."""

    keys: jnp.ndarray  # (R,) int32 namespace keys
    mask: jnp.ndarray  # (R,) bool validity
    feas: jnp.ndarray  # (R, d_max) int32 feasible set; slot 0 = primary
    L_view: jnp.ndarray  # (m,) float32 stale EWMA queue + own sends
    p50_view: jnp.ndarray  # (m,) float32 stale EWMA p50 (ms)
    knobs: Knobs  # controller-emitted knob bundle
    now_ms: jnp.ndarray  # () float32 tick clock
    rng: jnp.ndarray  # per-wave PRNG key
    m: int  # static: number of servers
    fixed_d: int  # static: d for non-adaptive power-of-d
    # static: resolved routing implementation for this trace — "ref"
    # (pure-jnp policy expressions, the golden path) or "pallas" (the
    # midas_route.route_select kernel; bit-parity contract, DESIGN.md
    # §15).  Policies without a kernel branch simply ignore it.
    route_impl: str = "ref"

    @property
    def primary(self) -> jnp.ndarray:
        """Ring-primary server per request (feasible-set slot 0)."""
        return self.feas[:, 0]


class RouteStats(NamedTuple):
    """Per-wave steering telemetry; summed across waves into TickOut."""

    steered: jnp.ndarray  # () float32 requests steered off primary
    eligible: jnp.ndarray  # () float32 steer-eligible requests
    dV: jnp.ndarray  # () float32 Lyapunov ΔV of admitted steers

    @classmethod
    def zeros(cls) -> "RouteStats":
        z = jnp.zeros((), jnp.float32)
        return cls(steered=z, eligible=z, dV=z)

    def __add__(self, other: "RouteStats") -> "RouteStats":
        """Fieldwise accumulation (replaces tuple concatenation): the
        wave scan's carry reduction across a tick's routing waves."""
        return RouteStats(
            steered=self.steered + other.steered,
            eligible=self.eligible + other.eligible,
            dV=self.dV + other.dV,
        )


def steering_dv(ctx: RouteContext, assign: jnp.ndarray) -> jnp.ndarray:
    """ΔV contribution of steering away from primary (paper eq. 2)."""
    prim = ctx.primary
    moved = ctx.mask & (assign != prim) & (assign >= 0)
    return jnp.sum(
        jnp.where(
            moved, 2.0 * (ctx.L_view[assign] - ctx.L_view[prim]) + 2.0, 0.0
        )
    )


class Policy:
    """Base class for registered routing policies.

    Subclasses override :meth:`route` (and :meth:`init` when they carry
    state).  Set ``adaptive = True`` when the policy consumes the
    warmup-derived control targets (§III-B) so ``simulate`` knows to run the
    warmup pass — a capability flag, not a name check.
    """

    name: str = "?"
    adaptive: bool = False

    def init(self, cfg, ring) -> Any:
        """Build the policy's carried state pytree (default: stateless)."""
        return ()

    def route(
        self, state: Any, ctx: RouteContext
    ) -> Tuple[Any, jnp.ndarray, RouteStats]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

REGISTRY = registry_lib.Registry("policy")


def register(name: str):
    """Class decorator: ``@register("my_policy")`` adds a Policy subclass
    to the registry under ``name`` (usable as ``SimConfig(policy=name)``)."""
    return REGISTRY.register(name)


def unregister(name: str) -> None:
    """Remove a registered policy (intended for tests/plugins)."""
    REGISTRY.unregister(name)


def available() -> Tuple[str, ...]:
    """Sorted names of every registered policy."""
    return REGISTRY.available()


def get_class(name: str) -> Type[Policy]:
    return REGISTRY.get_class(name)


def get(name: str) -> Policy:
    """Instantiate the policy registered under ``name``."""
    return REGISTRY.get(name)


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def sample_candidates(
    rng: jnp.ndarray, feas: jnp.ndarray, d: jnp.ndarray
) -> jnp.ndarray:
    """Mark which of the d_max feasible slots are sampled (size-d subset).

    Slot 0 (the primary) is always in S; the remaining d-1 picks are a
    uniform subset of slots 1..d_max-1 via random ranking.
    """
    R, d_max = feas.shape
    scores = jax.random.uniform(rng, (R, d_max))
    scores = scores.at[:, 0].set(-1.0)  # primary always sampled
    order = jnp.argsort(scores, axis=1)
    rank = jnp.argsort(order, axis=1)  # rank of each slot
    return rank < d  # (R, d_max) bool
