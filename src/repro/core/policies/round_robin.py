"""Round-robin placements: the Lustre baseline and the per-request ablation.

Faithfulness note: real round-robin is run by P independent proxies with
random phases, which is how RR actually behaves at scale (aggregate ≈
random placement).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.policies.base import Policy, RouteStats, register


def route_round_robin(
    keys: jnp.ndarray, mask: jnp.ndarray, m: int
) -> jnp.ndarray:
    """Lustre (Round-Robin) baseline: namespace objects are assigned to
    metadata targets *sequentially at creation time* (DNE round-robin
    striping), and every request follows its object's placement.  Object
    ids are creation-ordered, so placement is ``key mod m``.  Under skewed
    or bursty namespace access this is what produces the paper's hotspots:
    the placement never reacts to load."""
    return jnp.where(mask, (keys % m).astype(jnp.int32), -1)


class RRState(NamedTuple):
    rr_count: jnp.ndarray  # (P,) int32 per-proxy RR counters
    rr_phase: jnp.ndarray  # (P,) int32 per-proxy RR phases


def init_rr(P: int, seed: int = 0) -> RRState:
    phases = jax.random.randint(
        jax.random.PRNGKey(seed ^ 0xA5A5),
        (P,),
        0,
        1_000_000,
        dtype=jnp.int32,
    )
    return RRState(rr_count=jnp.zeros((P,), jnp.int32), rr_phase=phases)


def route_rr_per_request(
    rs: RRState, proxy: jnp.ndarray, mask: jnp.ndarray, m: int
) -> Tuple[RRState, jnp.ndarray]:
    """Ablation: P independent per-proxy per-request round-robin streams
    (ignores namespace placement entirely; not a valid metadata policy —
    requests must reach their object's server — but useful as a fairness
    upper bound on *counts*)."""
    P = rs.rr_count.shape[0]
    oh = (proxy[:, None] == jnp.arange(P)[None, :]) & mask[:, None]  # (R,P)
    prior = jnp.cumsum(oh, axis=0) - oh  # same-proxy requests before r
    rank = jnp.sum(prior * oh, axis=1)  # (R,)
    base = rs.rr_phase[proxy] + rs.rr_count[proxy]
    assign = ((base + rank) % m).astype(jnp.int32)
    new_count = rs.rr_count + jnp.sum(oh, axis=0).astype(jnp.int32)
    return rs._replace(rr_count=new_count), jnp.where(mask, assign, -1)


@register("round_robin")
class RoundRobin(Policy):
    """Static creation-time round-robin placement (Lustre DNE baseline)."""

    def route(self, state, ctx):
        return (
            state,
            route_round_robin(ctx.keys, ctx.mask, ctx.m),
            RouteStats.zeros(),
        )


@register("rr_request")
class RRPerRequest(Policy):
    """Per-request round-robin across P independent proxies (ablation)."""

    def init(self, cfg, ring) -> RRState:
        return init_rr(cfg.P, cfg.seed)

    def route(self, state: RRState, ctx):
        P = state.rr_count.shape[0]
        proxy = jax.random.randint(
            jax.random.fold_in(ctx.rng, 11),
            ctx.keys.shape,
            0,
            P,
            dtype=jnp.int32,
        )
        state, assign = route_rr_per_request(state, proxy, ctx.mask, ctx.m)
        return state, assign, RouteStats.zeros()
