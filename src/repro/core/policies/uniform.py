"""Uniform-random placement baseline (balls-into-bins, d = 1)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policies.base import Policy, RouteStats, register


def route_uniform(rng: jnp.ndarray, mask: jnp.ndarray, m: int) -> jnp.ndarray:
    a = jax.random.randint(rng, mask.shape, 0, m, dtype=jnp.int32)
    return jnp.where(mask, a, -1)


@register("uniform")
class Uniform(Policy):
    """Each request picks a server uniformly at random (§V d=1 bound)."""

    def route(self, state, ctx):
        return (
            state,
            route_uniform(ctx.rng, ctx.mask, ctx.m),
            RouteStats.zeros(),
        )
