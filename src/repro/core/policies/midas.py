"""Full MIDAS routing: margins + pinning + exact sliding-window leaky bucket.

Faithfulness notes:
  * Proxies act on *stale* telemetry — the EWMA view from the last fast-loop
    ingest (≤ one fast interval of delay, paper assumption 1) — never on
    instantaneous queue state.
  * MIDAS steering needs BOTH margins:  L̂_j ≤ L̂_p − Δ_L  and
    p̃50_j ≤ p̃50_p − Δ_t;  winner is argmin L̂ with random tie-break.
  * Steered keys are pinned to their chosen server for C ms.
  * A sliding-window leaky bucket caps steered/eligible ≤ f_max exactly.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.policies.base import (
    Policy,
    RouteStats,
    register,
    sample_candidates,
    steering_dv,
)
from repro.kernels.midas_route import ops as route_ops


class MidasState(NamedTuple):
    pin_server: jnp.ndarray  # (N,) int32 pinned server per key (-1 none)
    pin_expiry: jnp.ndarray  # (N,) float32 absolute pin expiry (ms)
    steer_hist: jnp.ndarray  # (W,) float32 per-tick steered counts
    elig_hist: jnp.ndarray  # (W,) float32 per-tick eligible counts
    hist_idx: jnp.ndarray  # () int32


def init_midas(N: int, w_ticks: int) -> MidasState:
    return MidasState(
        pin_server=jnp.full((N,), -1, jnp.int32),
        pin_expiry=jnp.zeros((N,), jnp.float32),
        steer_hist=jnp.zeros((w_ticks,), jnp.float32),
        elig_hist=jnp.zeros((w_ticks,), jnp.float32),
        hist_idx=jnp.zeros((), jnp.int32),
    )


class MidasTickStats(NamedTuple):
    eligible: jnp.ndarray  # () number of steer-eligible requests
    steered: jnp.ndarray  # () number actually steered


def route_midas(
    rs: MidasState,
    rng: jnp.ndarray,
    keys: jnp.ndarray,
    feas: jnp.ndarray,
    L_view: jnp.ndarray,
    p50_view: jnp.ndarray,
    mask: jnp.ndarray,
    d,
    delta_l,
    delta_t,
    f_max,
    now_ms,
    pin_c_ms: float,
    w_ticks: int,
    impl: str = "ref",
) -> Tuple[MidasState, jnp.ndarray, MidasTickStats]:
    """Full MIDAS routing for one request batch (Alg. 1 lines 36–47).

    The margin-eligibility + tie-broken argmin core runs either as the
    jnp expression below or, with ``impl="pallas"``, as the
    ``route_select`` kernel — fed the SAME host-drawn sampling mask and
    tie scores, so the two are bitwise identical.  Pins, the leaky
    bucket, and the window histories are sequential scalar state and
    stay jnp either way.
    """
    primary = feas[:, 0]
    sampled = sample_candidates(rng, feas, d)
    sampled = sampled.at[:, 0].set(False)  # candidates exclude primary
    tie = jax.random.uniform(jax.random.fold_in(rng, 2), feas.shape) * 1e-3

    if impl == "pallas":
        z = jnp.zeros((), jnp.float32)
        scalars = jnp.stack(
            [
                jnp.asarray(delta_l, jnp.float32),
                jnp.asarray(delta_t, jnp.float32),
                z,
                z,
            ]
        )
        best, ok_any = route_ops.route_waves(
            feas, L_view, p50_view, sampled, tie, scalars, mode="midas"
        )
        has_candidate = ok_any & mask
    else:
        Lp = L_view[primary][:, None]
        p50p = p50_view[primary][:, None]
        ok = (
            sampled
            & (L_view[feas] <= Lp - delta_l)
            & (p50_view[feas] <= p50p - delta_t)
        )  # eligibility per candidate
        load = jnp.where(ok, L_view[feas], jnp.inf)
        best_slot = jnp.argmin(load + tie, axis=1)
        best = jnp.take_along_axis(feas, best_slot[:, None], axis=1)[:, 0]
        has_candidate = jnp.any(ok, axis=1) & mask

    # honor active pins: pinned keys go to their pinned server, no steering
    pinned = (
        (rs.pin_expiry[keys] > now_ms) & (rs.pin_server[keys] >= 0) & mask
    )
    # leaky bucket (exact sliding window): allow at most
    #   f_max * (eligible in window incl. now) - (steered in window)
    i = rs.hist_idx % w_ticks  # slot about to be evicted
    elig_now = jnp.sum(has_candidate & ~pinned)
    elig_win = jnp.sum(rs.elig_hist) - rs.elig_hist[i] + elig_now
    steer_win = jnp.sum(rs.steer_hist) - rs.steer_hist[i]
    budget = jnp.floor(f_max * elig_win) - steer_win
    want = has_candidate & ~pinned
    order_rank = jnp.cumsum(want.astype(jnp.int32)) - 1
    allowed = want & (order_rank < budget)

    assign = jnp.where(
        pinned, rs.pin_server[keys], jnp.where(allowed, best, primary)
    )
    assign = jnp.where(mask, assign, -1)

    # pin steered keys for C ms (sentinel N is out-of-bounds => dropped)
    N = rs.pin_server.shape[0]
    steer_keys = jnp.where(allowed, keys, N)
    pin_server = rs.pin_server.at[steer_keys].set(best, mode="drop")
    pin_expiry = rs.pin_expiry.at[steer_keys].set(
        now_ms + pin_c_ms, mode="drop"
    )

    # window histories
    steer_hist = rs.steer_hist.at[i].set(
        jnp.sum(allowed).astype(jnp.float32)
    )
    elig_hist = rs.elig_hist.at[i].set(elig_now.astype(jnp.float32))

    new = rs._replace(
        pin_server=pin_server,
        pin_expiry=pin_expiry,
        steer_hist=steer_hist,
        elig_hist=elig_hist,
        hist_idx=rs.hist_idx + 1,
    )
    stats = MidasTickStats(
        eligible=elig_now.astype(jnp.float32),
        steered=jnp.sum(allowed).astype(jnp.float32),
    )
    return new, assign, stats


@register("midas")
class Midas(Policy):
    """Margined power-of-d with pinning and a leaky steering bucket, driven
    by the adaptive control knobs (d, Δ_L, Δ_t, f_max)."""

    adaptive = True  # consumes warmup-derived control targets (§III-B)

    def init(self, cfg, ring) -> MidasState:
        return init_midas(cfg.N, cfg.w_ticks)

    def route(self, state: MidasState, ctx):
        k = ctx.knobs
        state, assign, stats = route_midas(
            state,
            ctx.rng,
            ctx.keys,
            ctx.feas,
            ctx.L_view,
            ctx.p50_view,
            ctx.mask,
            k.d,
            k.delta_l,
            k.delta_t,
            k.f_max,
            ctx.now_ms,
            k.pin_ms,
            state.steer_hist.shape[0],
            impl=ctx.route_impl,
        )
        return state, assign, RouteStats(
            steered=stats.steered,
            eligible=stats.eligible,
            dV=steering_dv(ctx, assign),
        )
