"""Static consistent-hash placement (the no-steering MIDAS substrate)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import hashring
from repro.core.policies.base import Policy, RouteStats, register


def route_hash(
    ring: hashring.Ring, keys: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    return jnp.where(mask, hashring.primary(ring, keys), -1)


@register("hash")
class StaticHash(Policy):
    """Every request goes to its ring primary — stable placement, no load
    awareness.  This is what the warmup pass (§III-B) runs."""

    def route(self, state, ctx):
        assign = jnp.where(ctx.mask, ctx.primary, -1)
        return state, assign, RouteStats.zeros()
