"""Full join-shortest-queue (registry proof-point #1).

JSQ samples ALL m servers — the d = m limit of power-of-d — ignoring
namespace feasibility.  It is not a deployable metadata policy (requests
must reach a server that can resolve their object), but it bounds how much
balance any sampling policy can buy, which makes the power-of-d gap
measurable.  Lives entirely outside the simulator core: registering this
module is all it takes to make ``SimConfig(policy="jsq")`` work.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policies.base import (
    Policy,
    RouteStats,
    register,
    steering_dv,
)


def route_jsq(
    rng: jnp.ndarray, L_view: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """Each request joins the globally shortest queue (random tie-break)."""
    R, m = mask.shape[0], L_view.shape[0]
    load = jnp.broadcast_to(L_view[None, :], (R, m))
    tie = jax.random.uniform(rng, (R, m)) * 1e-3
    assign = jnp.argmin(load + tie, axis=1).astype(jnp.int32)
    return jnp.where(mask, assign, -1)


@register("jsq")
class JoinShortestQueue(Policy):
    """Global JSQ over the stale telemetry view (d = m upper bound)."""

    def route(self, state, ctx):
        assign = route_jsq(ctx.rng, ctx.L_view, ctx.mask)
        z = jnp.zeros((), jnp.float32)
        return state, assign, RouteStats(
            steered=z, eligible=z, dV=steering_dv(ctx, assign)
        )
