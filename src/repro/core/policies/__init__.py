"""Pluggable routing-policy registry for the MIDAS middleware pipeline.

The simulator resolves ``SimConfig.policy`` through this registry — there
is no policy-name branching in ``sim.py`` — so third-party policies plug in
without touching the engine.  A complete registration looks like this
(~15 lines):

    import jax.numpy as jnp
    from repro.core import policies

    @policies.register("hot_shard_split")
    class HotShardSplit(policies.Policy):
        '''Send every request whose primary is overloaded to primary+1.'''

        def route(self, state, ctx):
            hot = ctx.L_view[ctx.primary] > 2.0 * jnp.mean(ctx.L_view)
            alt = (ctx.primary + 1) % ctx.m
            assign = jnp.where(ctx.mask,
                               jnp.where(hot, alt, ctx.primary), -1)
            return state, assign, policies.RouteStats.zeros()

    # SimConfig(policy="hot_shard_split") now works everywhere:
    # simulate(), simulate_sweep(), every benchmark and example.

Stateful policies override ``init(cfg, ring)`` and thread their pytree
through ``route`` (see ``midas.py``); ``adaptive = True`` opts into the
§III-B warmup-derived control targets.  ``available()`` lists everything
registered; unknown names raise a ``ValueError`` naming the alternatives.
"""

from repro.core.policies.base import (
    ControlKnobs,
    Knobs,
    Policy,
    RouteContext,
    RouteStats,
    available,
    get,
    get_class,
    register,
    sample_candidates,
    steering_dv,
    unregister,
)

# Built-in policies self-register on import.
from repro.core.policies import (  # noqa: F401, E402
    bounded_load,
    jsq,
    midas,
    power_of_d,
    round_robin,
    static_hash,
    uniform,
)

__all__ = [
    "ControlKnobs",
    "Knobs",
    "Policy",
    "RouteContext",
    "RouteStats",
    "available",
    "get",
    "get_class",
    "register",
    "sample_candidates",
    "steering_dv",
    "unregister",
]
