"""Power-of-d within the namespace-feasible set (paper's headline policy)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policies.base import (
    Policy,
    RouteStats,
    register,
    sample_candidates,
    steering_dv,
)


def route_power_of_d(
    rng: jnp.ndarray,
    feas: jnp.ndarray,
    L_view: jnp.ndarray,
    mask: jnp.ndarray,
    d,
) -> jnp.ndarray:
    """Pure JSQ(d) within the feasible set (paper §VI eval policy)."""
    sampled = sample_candidates(rng, feas, d)
    load = jnp.where(sampled, L_view[feas], jnp.inf)
    # random tie-break
    tie = jax.random.uniform(jax.random.fold_in(rng, 1), feas.shape) * 1e-3
    best = jnp.argmin(load + tie, axis=1)
    assign = jnp.take_along_axis(feas, best[:, None], axis=1)[:, 0]
    return jnp.where(mask, assign, -1)


@register("power_of_d")
class PowerOfD(Policy):
    """JSQ(d) over the feasible set with fixed d = cfg.fixed_d."""

    def route(self, state, ctx):
        assign = route_power_of_d(
            ctx.rng, ctx.feas, ctx.L_view, ctx.mask, ctx.fixed_d
        )
        z = jnp.zeros((), jnp.float32)
        return state, assign, RouteStats(
            steered=z, eligible=z, dV=steering_dv(ctx, assign)
        )
