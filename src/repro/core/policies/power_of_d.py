"""Power-of-d within the namespace-feasible set (paper's headline policy)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policies.base import (
    Policy,
    RouteStats,
    register,
    sample_candidates,
    steering_dv,
)
from repro.kernels.midas_route import ops as route_ops


def route_power_of_d(
    rng: jnp.ndarray,
    feas: jnp.ndarray,
    L_view: jnp.ndarray,
    mask: jnp.ndarray,
    d,
    impl: str = "ref",
) -> jnp.ndarray:
    """Pure JSQ(d) within the feasible set (paper §VI eval policy).

    The sampling mask and tie-break draws are made here for BOTH impls,
    so the Pallas branch consumes the exact same randomness as the jnp
    expression — bit-for-bit parity, not distributional equivalence.
    """
    sampled = sample_candidates(rng, feas, d)
    # random tie-break
    tie = jax.random.uniform(jax.random.fold_in(rng, 1), feas.shape) * 1e-3
    if impl == "pallas":
        assign, _ = route_ops.route_waves(
            feas, L_view, L_view, sampled, tie,
            jnp.zeros((4,), jnp.float32), mode="power_of_d",
        )
    else:
        load = jnp.where(sampled, L_view[feas], jnp.inf)
        best = jnp.argmin(load + tie, axis=1)
        assign = jnp.take_along_axis(feas, best[:, None], axis=1)[:, 0]
    return jnp.where(mask, assign, -1)


@register("power_of_d")
class PowerOfD(Policy):
    """JSQ(d) over the feasible set with fixed d = cfg.fixed_d."""

    def route(self, state, ctx):
        assign = route_power_of_d(
            ctx.rng,
            ctx.feas,
            ctx.L_view,
            ctx.mask,
            ctx.fixed_d,
            impl=ctx.route_impl,
        )
        z = jnp.zeros((), jnp.float32)
        return state, assign, RouteStats(
            steered=z, eligible=z, dV=steering_dv(ctx, assign)
        )
