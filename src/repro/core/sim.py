"""Queue-network simulator for the MIDAS evaluation (paper §VI).

m metadata servers, each a FIFO queue with constant 100 ms service time
(the paper's stress bound).  Time advances in dt_ms ticks under
``jax.lax.scan``; each tick first runs the middleware pipeline (stages may
absorb requests at the proxy — the cooperative cache is the reference
stage), then routes the surviving batch with the policy resolved from the
registry (``repro.core.policies``), applies service, refreshes (delayed)
telemetry, and runs the fast/slow control loops on their paper cadences.

Within a tick, requests are processed in ``n_groups`` sequential waves:
every wave sees the stale EWMA telemetry *plus* the proxies' own
assignments from earlier waves (a proxy knows what it already sent), which
is the honest middle ground between full per-request sequencing and pure
batch routing.  The waves themselves run as an inner ``jax.lax.scan``
(DESIGN.md §9): the feasible-set gather is one batched
``hashring.feasible_set`` call per tick, per-wave RNG keys are pre-split,
and the policy state threads through the wave carry — so trace/HLO size
and compile time are O(1) in ``n_groups`` and ``P`` instead of O(G).
``SimConfig(unroll_waves=True)`` keeps the pre-scan Python-loop engine as
the bit-for-bit parity reference (tests) and the E10 "before" baseline.

``simulate`` runs one config; ``simulate_sweep`` batches seeds and
workload grids with nested ``jax.vmap`` (one compiled scan per policy)
and fans out across policies — the API the benchmark suite uses.  Its
``metrics="summary"`` mode carries O(m) streaming accumulators
(:class:`SummaryResult`) through the scan instead of stacking (T, m)
timelines, collapsing sweep memory from O(B·T·m) to O(B·m).
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Dict, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cache_lib
from repro.core import controllers as ctrl_lib
from repro.core import faults as faults_lib
from repro.core import fleet as fleet_lib
from repro.core import hashring, telemetry
from repro.core import middleware as mw_lib
from repro.core import policies as policy_lib
from repro.core import registry as registry_lib
from repro.core.controllers.base import Knobs, Signals
from repro.core.policies.base import RouteContext, RouteStats
from repro.core.workloads import Workload
from repro.kernels import common as kernels_common
from repro.obs import trace as obs_trace

# Snapshot of the registry at import time; prefer policies.available().
POLICIES = policy_lib.available()

METRICS_MODES = ("full", "summary")


@dataclasses.dataclass(frozen=True)
class SimConfig:
    m: int = 8  # metadata servers
    P: int = 8  # independent proxies (fleet size)
    N: int = 4096  # namespace size (keys)
    dt_ms: float = 50.0
    service_ms: float = 100.0  # paper: constant 100 ms per RPC
    policy: str = "midas"  # any name in policies.available()
    d_max: int = 4
    V: int = 64  # virtual nodes per server
    rtt_ms: float = 2.0
    n_groups: int = 8  # routing waves per tick
    middleware: Tuple[str, ...] = ()  # pipeline stages, applied in order
    cache_enabled: bool = False  # legacy alias for middleware=("cache",)
    cache_mode: str = "lease"  # lease | ttl_aggregate | ttl_per_key
    lease_ms: float = 5000.0
    p_star: float = 1e-4
    # fleet knobs (repro.core.fleet): gossip propagation delay for the
    # "fleet_cache" stage, and per-proxy routing (one wave per proxy, own
    # staggered telemetry view, no within-tick sharing across proxies —
    # replaces the n_groups waves when enabled)
    gossip_ms: float = 0.0
    fleet_routing: bool = False
    fixed_d: int = 2  # d for power_of_d policy
    # control plane: any name in controllers.available(), plus the §IV-E
    # ablation decorators and the fleet-consensus reducer feeding it
    controller: str = "hysteresis"
    consensus: str = "mean"  # mean | median | max (fleet view reducer)
    ablate: str = ""  # comma-joined subset of controllers.ABLATIONS
    # oscillation guard (controllers.guard): wrap the controller in the
    # limit-cycle circuit breaker.  False (default) is the identically-
    # untouched engine (golden contract).
    guard: bool = False
    # fault injection (repro.core.faults): tuple of registered fault
    # names and/or FaultEvent instances, compiled host-side into
    # time-indexed schedules riding the scan xs.  None and () are both
    # the identically-untouched zero-fault engine (golden contract).
    faults: Optional[Tuple] = None
    # reference engine: unroll the routing waves as a Python loop (the
    # pre-scan semantics, O(G) trace size) — parity tests and the E10
    # "before" baseline; production always uses the wave scan
    unroll_waves: bool = False
    # wave-routing implementation (DESIGN.md §15): "auto" resolves per
    # backend (Pallas iff TPU, REPRO_KERNEL_IMPL override), "ref" pins
    # the pure-jnp policy expressions (the golden-parity path on CPU),
    # "pallas" forces the midas_route.route_select kernel (interpret
    # mode off-TPU) — bit-for-bit with "ref" by contract.
    route_impl: str = "auto"
    seed: int = 0

    def __post_init__(self):
        """Eager validation: bad names/sizes fail at construction with the
        alternatives spelled out, not deep inside the jitted scan."""
        for name in ("m", "P", "N", "V", "n_groups", "d_max", "fixed_d"):
            v = getattr(self, name)
            if not isinstance(v, int) or isinstance(v, bool) or v <= 0:
                raise ValueError(
                    f"SimConfig.{name} must be a positive int, got {v!r}"
                )
        # registry / enum membership: all routed through the shared
        # repro.core.registry helpers, so every axis raises the same
        # "unknown <kind> ...; available: ..." text
        policy_lib.get_class(self.policy)
        for stage in self.middleware:
            registry_lib.validate_choice(
                stage, "middleware stage", mw_lib.available()
            )
        ctrl_lib.get_class(self.controller)
        registry_lib.validate_choice(
            self.consensus,
            "consensus reducer",
            telemetry.CONSENSUS_REDUCERS,
        )
        ctrl_lib.parse_ablations(self.ablate)  # raises on unknown tokens
        if not isinstance(self.guard, bool):
            raise ValueError(
                f"SimConfig.guard must be a bool, got {self.guard!r}"
            )
        registry_lib.validate_choice(
            self.cache_mode, "cache_mode", cache_lib.MODES
        )
        registry_lib.validate_choice(
            self.route_impl, "route_impl", kernels_common.ROUTE_IMPLS
        )
        if self.gossip_ms < 0:
            raise ValueError(
                f"SimConfig.gossip_ms must be >= 0, got {self.gossip_ms!r}"
            )
        if self.faults is not None:
            if not isinstance(self.faults, (tuple, list)):
                raise ValueError(
                    f"SimConfig.faults must be a tuple of fault names "
                    f"or FaultEvent, got {self.faults!r}"
                )
            # canonicalize eagerly (frozen dataclass): names become
            # default events, lists become tuples — keeps the config
            # hashable for jit static args and the fault compiler cache
            object.__setattr__(
                self, "faults", faults_lib.normalize(self.faults)
            )
            faults_lib.validate_events(self.faults, m=self.m, P=self.P)

    @property
    def fault_events(self) -> Tuple:
        """Canonical tuple of FaultEvent (empty when faults is None)."""
        return faults_lib.normalize(self.faults)

    @property
    def t_fast_ticks(self) -> int:
        return max(int(round(ctrl_lib.T_FAST_MS / self.dt_ms)), 1)

    @property
    def t_slow_ticks(self) -> int:
        return max(int(round(ctrl_lib.T_SLOW_MS / self.dt_ms)), 1)

    @property
    def w_ticks(self) -> int:
        return max(int(round(ctrl_lib.W_WINDOW_MS / self.dt_ms)), 1)

    @property
    def serve_per_tick(self) -> float:
        return self.dt_ms / self.service_ms

    @property
    def middleware_chain(self) -> Tuple[str, ...]:
        """Resolved pipeline: the legacy cache flag prepends the cache."""
        chain = tuple(self.middleware)
        if self.cache_enabled and "cache" not in chain:
            chain = ("cache",) + chain
        return chain


class SimState(NamedTuple):
    L: jnp.ndarray  # (m,) float32 queue length
    L_hat: jnp.ndarray  # (m,) float32 EWMA of observed L
    L_hat_p: jnp.ndarray  # (P, m) float32 per-proxy views (fleet)
    p50_hat: jnp.ndarray  # (m,) float32 EWMA p50 (ms)
    p99_hat: jnp.ndarray  # (m,) float32 EWMA p99 (ms)
    sketch: telemetry.LatencySketch
    policy: tuple  # policy-owned pytree (see policies.base)
    ctrl: ctrl_lib.ControlState  # knobs + targets + controller inner
    mw: tuple  # per-stage middleware pytrees, chain order
    win_writes: jnp.ndarray  # () float32 writes this T_slow window
    win_events: jnp.ndarray  # () float32 valid requests this window
    rng: jnp.ndarray


class TickOut(NamedTuple):
    L: jnp.ndarray  # (m,) queue snapshot after tick
    arrivals: jnp.ndarray  # (m,) arrivals routed this tick
    lat_pred: jnp.ndarray  # (m,) predicted latency of a new arrival (ms)
    d: jnp.ndarray  # () int32 control knob
    delta_l: jnp.ndarray  # ()
    f_max: jnp.ndarray  # () steering-bucket cap this tick
    pressure: jnp.ndarray  # ()
    steered: jnp.ndarray  # ()
    eligible: jnp.ndarray  # ()
    cache_hits: jnp.ndarray  # () requests absorbed by the pipeline
    dV: jnp.ndarray  # () potential change from steering this tick


class SimResult(NamedTuple):
    queue_timeline: np.ndarray  # (T, m)
    arrivals: np.ndarray  # (T, m)
    lat_pred: np.ndarray  # (T, m)
    d_timeline: np.ndarray  # (T,)
    delta_l_timeline: np.ndarray
    pressure: np.ndarray  # (T,)
    steered: np.ndarray  # (T,)
    eligible: np.ndarray  # (T,)
    cache_hits: np.ndarray  # (T,)
    final_cache: Optional[object]
    config: SimConfig
    f_max_timeline: Optional[np.ndarray] = None  # (T,) bucket cap

    # ---- paper metrics -------------------------------------------------
    def mean_queue(self) -> float:
        return float(self.queue_timeline.mean())

    def max_queue(self) -> float:
        return float(self.queue_timeline.max())

    def worst_case_queue(self, q: float = 99.9) -> float:
        return float(np.percentile(self.queue_timeline, q))

    def dispersion(self) -> float:
        """CV of per-server time-averaged queue length (paper §VI-C)."""
        per_server = self.queue_timeline.mean(axis=0)
        mu = per_server.mean()
        if mu < 1e-9:
            return 0.0
        return float(per_server.std() / mu)

    def dispersion_t(self) -> float:
        """Time-average of instantaneous CV across servers."""
        mu = self.queue_timeline.mean(axis=1)
        sd = self.queue_timeline.std(axis=1)
        ok = mu > 1e-9
        if not ok.any():
            return 0.0
        return float((sd[ok] / mu[ok]).mean())

    def latency_quantiles(self, qs=(50, 99)) -> Tuple[float, ...]:
        """Arrival-weighted request latency quantiles (ms)."""
        return telemetry.weighted_quantiles(self.lat_pred, self.arrivals, qs)


# ---------------------------------------------------------------------------
# Streaming summary metrics (metrics="summary")
# ---------------------------------------------------------------------------


class KnobTrace(NamedTuple):
    """Per-tick control-plane scalars emitted as the summary scan's ys:
    O(T) total — knob trajectories survive ``metrics="summary"`` even
    though the O(T·m) queue timelines do not, so E4/E8/E9-style cells
    can report oscillation, settling, and churn (DESIGN.md §10).
    ``q_mean`` (the across-server mean queue per tick) rides along so
    the ``repro.obs.windows`` warmup/stable/cooldown detector has a
    steady-state series in BOTH metrics modes (DESIGN.md §13)."""

    d: jnp.ndarray  # (T,) int32
    delta_l: jnp.ndarray  # (T,) float32
    f_max: jnp.ndarray  # (T,) float32
    pressure: jnp.ndarray  # (T,) float32
    q_mean: jnp.ndarray  # (T,) float32 across-server mean queue


class SummaryAcc(NamedTuple):
    """O(m) accumulators carried through the tick scan instead of a
    stacked (T, m) ``TickOut`` timeline (DESIGN.md §9)."""

    n_ticks: jnp.ndarray  # () int32
    queue_sum: jnp.ndarray  # (m,) per-server queue-length sums
    queue_max: jnp.ndarray  # ()
    cv_sum: jnp.ndarray  # () sum of instantaneous CV over ok ticks
    cv_count: jnp.ndarray  # () number of ok ticks
    queue_hist: telemetry.HistSketch  # all (t, server) queue samples
    lat_hist: telemetry.HistSketch  # lat_pred weighted by arrivals
    arrivals: jnp.ndarray  # ()
    steered: jnp.ndarray  # ()
    eligible: jnp.ndarray  # ()
    cache_hits: jnp.ndarray  # ()


def _summary_init(m: int) -> SummaryAcc:
    z = jnp.zeros((), jnp.float32)
    return SummaryAcc(
        n_ticks=jnp.zeros((), jnp.int32),
        queue_sum=jnp.zeros((m,), jnp.float32),
        queue_max=z,
        cv_sum=z,
        cv_count=z,
        queue_hist=telemetry.make_hist(),
        lat_hist=telemetry.make_hist(),
        arrivals=z,
        steered=z,
        eligible=z,
        cache_hits=z,
    )


def _summary_update(acc: SummaryAcc, out: TickOut) -> SummaryAcc:
    L = out.L
    mu = jnp.mean(L)
    ok = mu > 1e-9
    cv = jnp.where(ok, jnp.std(L) / jnp.where(ok, mu, 1.0), 0.0)
    return SummaryAcc(
        n_ticks=acc.n_ticks + 1,
        queue_sum=acc.queue_sum + L,
        queue_max=jnp.maximum(acc.queue_max, jnp.max(L)),
        cv_sum=acc.cv_sum + cv,
        cv_count=acc.cv_count + ok.astype(jnp.float32),
        queue_hist=telemetry.hist_add(acc.queue_hist, L, jnp.ones_like(L)),
        lat_hist=telemetry.hist_add(acc.lat_hist, out.lat_pred, out.arrivals),
        arrivals=acc.arrivals + jnp.sum(out.arrivals),
        steered=acc.steered + out.steered,
        eligible=acc.eligible + out.eligible,
        cache_hits=acc.cache_hits + out.cache_hits,
    )


@dataclasses.dataclass(frozen=True)
class SummaryResult:
    """Streaming summary of one (policy, workload, seed) run.

    Exposes the same paper-metric API as :class:`SimResult` so benchmark
    code is agnostic to ``metrics=``.  Mean / max / dispersion are exact
    up to fp accumulation order; worst-case and latency quantiles come
    from :class:`telemetry.HistSketch` (bin-resolution approximations).
    The parity contract — a summary row equals :func:`summarize` of the
    corresponding full-timeline row — is tested in tests/test_engine.py.
    """

    n_ticks: int
    queue_sum: np.ndarray  # (m,)
    queue_max_v: float
    cv_sum: float
    cv_count: float
    queue_hist: np.ndarray  # (HIST_BINS + 2,)
    lat_hist: np.ndarray  # (HIST_BINS + 2,)
    arrivals_total: float
    steered_total: float
    eligible_total: float
    cache_hits_total: float
    config: SimConfig
    # control-plane trajectories (KnobTrace ys): O(T) scalars per tick,
    # kept even in summary mode so cells can report control behaviour
    d_timeline: Optional[np.ndarray] = None  # (T,)
    delta_l_timeline: Optional[np.ndarray] = None  # (T,)
    f_max_timeline: Optional[np.ndarray] = None  # (T,)
    pressure: Optional[np.ndarray] = None  # (T,)
    q_mean_timeline: Optional[np.ndarray] = None  # (T,) mean queue

    # ---- paper metrics (SimResult-compatible) --------------------------
    def mean_queue(self) -> float:
        n = max(self.n_ticks * self.queue_sum.shape[0], 1)
        return float(self.queue_sum.sum() / n)

    def max_queue(self) -> float:
        return float(self.queue_max_v)

    def worst_case_queue(self, q: float = 99.9) -> float:
        return telemetry.hist_quantile(self.queue_hist, q)

    def dispersion(self) -> float:
        """CV of per-server time-averaged queue length (paper §VI-C)."""
        per_server = self.queue_sum / max(self.n_ticks, 1)
        mu = per_server.mean()
        if mu < 1e-9:
            return 0.0
        return float(per_server.std() / mu)

    def dispersion_t(self) -> float:
        """Time-average of instantaneous CV across servers."""
        if self.cv_count <= 0:
            return 0.0
        return float(self.cv_sum / self.cv_count)

    def latency_quantiles(self, qs=(50, 99)) -> Tuple[float, ...]:
        """Arrival-weighted latency quantiles (ms), sketch resolution."""
        return tuple(telemetry.hist_quantile(self.lat_hist, q) for q in qs)


def _to_summary(
    cfg: SimConfig, acc: SummaryAcc, trace: Optional[KnobTrace] = None
) -> SummaryResult:
    """Host-side SummaryResult from a (device or host) SummaryAcc."""
    return SummaryResult(
        n_ticks=int(acc.n_ticks),
        queue_sum=np.asarray(acc.queue_sum),
        queue_max_v=float(acc.queue_max),
        cv_sum=float(acc.cv_sum),
        cv_count=float(acc.cv_count),
        queue_hist=np.asarray(acc.queue_hist.counts),
        lat_hist=np.asarray(acc.lat_hist.counts),
        arrivals_total=float(acc.arrivals),
        steered_total=float(acc.steered),
        eligible_total=float(acc.eligible),
        cache_hits_total=float(acc.cache_hits),
        config=cfg,
        d_timeline=None if trace is None else np.asarray(trace.d),
        delta_l_timeline=(
            None if trace is None else np.asarray(trace.delta_l)
        ),
        f_max_timeline=None if trace is None else np.asarray(trace.f_max),
        pressure=None if trace is None else np.asarray(trace.pressure),
        q_mean_timeline=(
            None if trace is None else np.asarray(trace.q_mean)
        ),
    )


@functools.partial(jax.jit, static_argnums=(0,))
def _reduce_ticks(m: int, outs: TickOut) -> SummaryAcc:
    """Fold a stacked (T, ...) TickOut through the summary accumulators —
    the same per-tick updates the streaming mode applies in-scan."""

    def step(acc, out):
        return _summary_update(acc, out), None

    acc, _ = jax.lax.scan(step, _summary_init(m), outs)
    return acc


def summarize(result: SimResult) -> SummaryResult:
    """Post-hoc reduction of a full-timeline result through the SAME
    streaming accumulators as ``metrics="summary"`` — the reference side
    of the summary parity contract (tests/test_engine.py)."""
    T, m = result.queue_timeline.shape
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    zeros = jnp.zeros((T,), jnp.float32)
    outs = TickOut(
        L=f32(result.queue_timeline),
        arrivals=f32(result.arrivals),
        lat_pred=f32(result.lat_pred),
        d=jnp.zeros((T,), jnp.int32),
        delta_l=zeros,
        f_max=zeros,
        pressure=zeros,
        steered=f32(result.steered),
        eligible=f32(result.eligible),
        cache_hits=f32(result.cache_hits),
        dV=zeros,
    )
    f_max_tl = (
        np.zeros_like(np.asarray(result.d_timeline, np.float32))
        if result.f_max_timeline is None
        else np.asarray(result.f_max_timeline)
    )
    trace = KnobTrace(
        d=np.asarray(result.d_timeline),
        delta_l=np.asarray(result.delta_l_timeline),
        f_max=f_max_tl,
        pressure=np.asarray(result.pressure),
        # same jnp float32 mean as the in-scan ys — keeps the summary
        # parity contract bitwise, not merely approximate
        q_mean=np.asarray(jnp.mean(outs.L, axis=1)),
    )
    return _to_summary(
        result.config, jax.device_get(_reduce_ticks(m, outs)), trace
    )


# ---------------------------------------------------------------------------
# The tick: middleware pipeline -> wave-scanned routing -> dynamics
# ---------------------------------------------------------------------------


def _middlewares(cfg: SimConfig) -> Tuple[mw_lib.Middleware, ...]:
    return tuple(mw_lib.get(name) for name in cfg.middleware_chain)


def _controller(cfg: SimConfig) -> ctrl_lib.Controller:
    """The configured controller, with the §IV-E ablation decorators
    (``cfg.ablate``) wrapped around its emitted knob view and the
    oscillation guard (``cfg.guard``) as the outermost decorator."""
    ctrl = ctrl_lib.wrap_ablations(ctrl_lib.get(cfg.controller), cfg.ablate)
    return ctrl_lib.wrap_guard(ctrl, cfg.guard)


def _wave_split(cfg: SimConfig, x):
    """Reshape a (..., R) batch into (..., G, R/G) routing waves.

    Legacy: G = n_groups contiguous waves.  Fleet: one wave per proxy —
    wave g holds slots r ≡ g (mod P), served by proxy (g + tick) % P to
    match fleet.proxy_assign.  Works on one tick's (R,) vector or a whole
    (T, R) grid — the scan engine hoists the key split (and the feasible
    gather on it) out of the tick loop entirely.
    """
    R = x.shape[-1]
    G = cfg.P if cfg.fleet_routing else cfg.n_groups
    pad = (-R) % G
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    if cfg.fleet_routing:
        xg = xp.reshape(xp.shape[:-1] + (-1, G))
        return jnp.swapaxes(xg, -1, -2)
    return xp.reshape(xp.shape[:-1] + (G, -1))


def _wave_counts(m: int, mask, assign) -> jnp.ndarray:
    """(m,) routed-arrival counts of one wave (masked scatter-add)."""
    sink = jnp.where(mask, assign, 0)
    return jnp.zeros((m,), jnp.float32).at[sink].add(
        jnp.where(mask, 1.0, 0.0)
    )


# Trace counter for the wave-scan body: increments once per (re)trace of
# the body — NOT once per wave — letting tests assert that trace size
# stays O(1) in n_groups/P (the unrolled reference executes its loop body
# G times per trace instead).
_WAVE_TRACES = [0]


def _route_waves_scan(
    cfg: SimConfig,
    ring: hashring.Ring,
    policy: policy_lib.Policy,
    state: SimState,
    knobs: Knobs,
    t,
    now_ms,
    r_route,
    keysg,
    maskg,
    feasg,
):
    """Route a tick's G waves as one ``jax.lax.scan`` over waves.

    Hoisted out of the wave loop: the feasible sets (ONE batched
    ``hashring.feasible_set`` gather over the whole horizon, riding the
    tick scan's inputs), the per-wave RNG keys (vmapped fold_in —
    bitwise identical to the unrolled engine's per-wave fold_in), and,
    in fleet mode, the wave-rotation gather of per-proxy telemetry views
    (fleet.wave_views).  The wave carry threads the policy state, the
    within-tick own-sends accumulator, and the RouteStats sum.
    """
    G = keysg.shape[0]
    rngs = jax.vmap(lambda g: jax.random.fold_in(r_route, g))(jnp.arange(G))
    impl = kernels_common.resolve_route_impl(cfg.route_impl)

    def wave(carry, xs):
        _WAVE_TRACES[0] += 1
        ps, sent, stats = carry
        if cfg.fleet_routing:
            k, mk, feas, rng, L_view = xs
        else:
            k, mk, feas, rng = xs
            # own sends this tick on top of the stale EWMA view
            L_view = state.L_hat + sent
        ctx = RouteContext(
            keys=k,
            mask=mk,
            feas=feas,
            L_view=L_view,
            p50_view=state.p50_hat,
            knobs=knobs,
            now_ms=now_ms,
            rng=rng,
            m=cfg.m,
            fixed_d=cfg.fixed_d,
            route_impl=impl,
        )
        ps, assign, st = policy.route(ps, ctx)
        counts = _wave_counts(cfg.m, mk, assign)
        return (ps, sent + counts, stats + st), None

    xs = (keysg, maskg, feasg, rngs)
    if cfg.fleet_routing:
        # each proxy routes from its OWN staggered telemetry view, with
        # no within-tick sharing across proxies
        xs = xs + (fleet_lib.wave_views(state.L_hat_p, t),)
    init = (
        state.policy,
        jnp.zeros((cfg.m,), jnp.float32),
        RouteStats.zeros(),
    )
    (ps, arrivals, stats), _ = jax.lax.scan(wave, init, xs)
    return ps, arrivals, stats


def _route_waves_unrolled(
    cfg: SimConfig,
    ring: hashring.Ring,
    policy: policy_lib.Policy,
    state: SimState,
    knobs: Knobs,
    t,
    now_ms,
    r_route,
    keysg,
    maskg,
    fc=None,
    fx=None,
):
    """Reference engine: the pre-scan Python loop over waves, O(G) trace
    size, per-wave feasible-set gathers and fold_ins.  Kept for the
    bit-for-bit parity contract and as the E10 "before" baseline.
    Under a membership-changing fault schedule the in-tick gathers go
    member-aware (this tick's detected mask), matching the scan
    engine's per-epoch hoisted gathers key for key."""
    G = keysg.shape[0]
    ps = state.policy
    arrivals = jnp.zeros((cfg.m,), jnp.float32)
    stats = RouteStats.zeros()
    impl = kernels_common.resolve_route_impl(cfg.route_impl)
    member_aware = fc is not None and fc.has_remap
    for g in range(G):
        if cfg.fleet_routing:
            L_view = state.L_hat_p[(g + t) % G]
        else:
            L_view = state.L_hat + arrivals
        if member_aware:
            feas_g = hashring.feasible_set(
                ring, keysg[g], cfg.d_max,
                scan_width=fc.scan_width, member=fx.detected,
            )
        else:
            feas_g = hashring.feasible_set(ring, keysg[g], cfg.d_max)
        ctx = RouteContext(
            keys=keysg[g],
            mask=maskg[g],
            feas=feas_g,
            L_view=L_view,
            p50_view=state.p50_hat,
            knobs=knobs,
            now_ms=now_ms,
            rng=jax.random.fold_in(r_route, g),
            m=cfg.m,
            fixed_d=cfg.fixed_d,
            route_impl=impl,
        )
        ps, assign, st = policy.route(ps, ctx)
        arrivals = arrivals + _wave_counts(cfg.m, maskg[g], assign)
        stats = stats + st
    return ps, arrivals, stats


def _tick(
    cfg: SimConfig,
    ring: hashring.Ring,
    policy: policy_lib.Policy,
    mws: Tuple[mw_lib.Middleware, ...],
    controller: ctrl_lib.Controller,
    fc,
    state: SimState,
    inputs,
) -> Tuple[SimState, TickOut]:
    # ``t`` rides the scan's xs (an unbatched arange) rather than the
    # carried state: under the sweep's vmap a carried counter would be
    # batched, degrading every ``lax.cond`` below to a both-branches
    # ``select`` — with t unbatched the fast/slow cadence work really
    # runs only on its cadence, even inside vmapped sweeps.  The scan
    # engine additionally receives the tick's pre-gathered feasible sets
    # (computed for the whole horizon before the scan — keys don't
    # depend on middleware, so the gather hoists); the unrolled
    # reference keeps its in-tick per-wave gathers, as pre-PR.  With a
    # compiled fault program (``fc``, a trace-time constant), this
    # tick's fault rows (faults.FaultXs) arrive as the last xs entry.
    if fc is not None:
        inputs, fx = inputs[:-1], inputs[-1]
    else:
        fx = None
    if cfg.unroll_waves:
        t, keys, mask, is_write = inputs
        feasg = None
    else:
        t, feasg, keys, mask, is_write = inputs
    now_ms = t.astype(jnp.float32) * cfg.dt_ms
    rng, r_mw, r_route = jax.random.split(state.rng, 3)
    state = state._replace(rng=rng)

    # accumulate the offered batch's write mix (pre-middleware) into the
    # T_slow window counters — Signals.write_mix is the WINDOWED
    # fraction, never a single-tick sample (it would make slow-loop
    # decisions flap on per-tick noise); the slow branch resets the
    # window after the controller consumed it.  Controllers that ignore
    # the signal cost nothing (XLA DCE).
    state = state._replace(
        win_writes=state.win_writes
        + jnp.sum((is_write & mask).astype(jnp.float32)),
        win_events=state.win_events
        + jnp.sum(mask.astype(jnp.float32)),
    )

    # --- fault context: remap invalidation BEFORE any stage serves -------
    finfo = None
    if fx is not None:
        finfo = faults_lib.tick_info(fc, fx)
        if finfo.inval is not None:
            state = state._replace(
                mw=tuple(
                    mw.on_fault(ms, finfo, cfg)
                    for mw, ms in zip(mws, state.mw)
                )
            )

    # --- middleware pipeline: stages may absorb requests at the proxy ----
    absorbed = jnp.zeros((), jnp.float32)
    mw_states = list(state.mw)
    for i, mw in enumerate(mws):
        batch = mw_lib.BatchView(
            keys=keys,
            mask=mask,
            is_write=is_write,
            now_ms=now_ms,
            rng=jax.random.fold_in(r_mw, i),
            faults=finfo,
        )
        mw_states[i], mask, took = mw.on_batch(mw_states[i], batch, cfg)
        absorbed = absorbed + took
    state = state._replace(mw=tuple(mw_states))

    # --- route in waves (scan engine; unrolled reference on request) -----
    keysg = _wave_split(cfg, keys)
    maskg = _wave_split(cfg, mask)
    knobs = controller.view(state.ctrl)
    if cfg.unroll_waves:
        ps, arrivals, stats = _route_waves_unrolled(
            cfg,
            ring,
            policy,
            state,
            knobs,
            t,
            now_ms,
            r_route,
            keysg,
            maskg,
            fc,
            fx,
        )
    else:
        ps, arrivals, stats = _route_waves_scan(
            cfg,
            ring,
            policy,
            state,
            knobs,
            t,
            now_ms,
            r_route,
            keysg,
            maskg,
            feasg,
        )
    state = state._replace(policy=ps)

    # --- queue dynamics: constant-rate servers, work-conserving ----------
    L = state.L + arrivals
    if fc is not None and (fc.has_brownout or fc.has_downtime):
        # ground-truth faults bite immediately: browned-out servers
        # drain slower, dead servers not at all (their queue freezes
        # until rejoin)
        rate = jnp.full((cfg.m,), cfg.serve_per_tick, jnp.float32)
        if fc.has_brownout:
            rate = rate * fx.scale
        if fc.has_downtime:
            rate = rate * fx.member.astype(jnp.float32)
        served = jnp.minimum(L, rate)
    else:
        served = jnp.minimum(L, cfg.serve_per_tick)
    L = L - served
    lat_pred = (state.L + arrivals) * cfg.service_ms  # wait of new arrival

    state = state._replace(L=L)
    t1 = t + 1  # post-tick clock, the cadence the control loops count on

    # --- telemetry ingest + fast control (every T_fast) ------------------
    is_fast = (t1 % cfg.t_fast_ticks) == 0
    sketch = telemetry.sketch_add(state.sketch, lat_pred)

    if cfg.fleet_routing:
        # per-proxy views: each proxy polls on its own staggered phase, so
        # the P views carry genuinely different staleness at any instant
        state = state._replace(
            L_hat_p=telemetry.ewma_staggered(
                state.L_hat_p,
                state.L,
                t1,
                cfg.t_fast_ticks,
                ctrl_lib.ALPHA_FAST,
            )
        )

    def _signals(s: SimState, B, p99, jitter) -> Signals:
        # availability / membership telemetry: constants (full) on the
        # zero-fault path, this tick's detected view under a schedule
        if fx is None:
            avail = jnp.ones(())
            member = jnp.ones((cfg.m,))
        else:
            avail = fx.avail
            member = fx.detected.astype(jnp.float32)
        return Signals(
            B=B,
            p99=p99,
            L_hat=s.L_hat,
            views_p=s.L_hat_p,
            write_mix=s.win_writes / jnp.maximum(s.win_events, 1.0),
            jitter=jitter,
            rtt_ms=cfg.rtt_ms,
            avail=avail,
            member=member,
        )

    def ingest(s: SimState) -> SimState:
        # quantile extraction (a per-server sort) lives INSIDE the fast
        # branch: with t unbatched the sort really runs once per fast
        # interval, not every tick
        p50_o, p99_o = telemetry.sketch_quantiles(s.sketch)
        if cfg.fleet_routing:
            # one control loop fed by the fleet's consensus view
            L_hat = ctrl_lib.consensus_view(s.L_hat_p, cfg.consensus)
        else:
            L_hat = telemetry.ewma(s.L_hat, s.L, ctrl_lib.ALPHA_FAST)
        p50 = telemetry.ewma(s.p50_hat, p50_o, ctrl_lib.ALPHA_FAST)
        p99 = telemetry.ewma(s.p99_hat, p99_o, ctrl_lib.ALPHA_FAST)
        if fc is not None and fc.has_remap:
            # survivors-only imbalance: a dead server's frozen queue
            # must not pin B(t) for the whole outage
            B = telemetry.imbalance_masked(L_hat, fx.detected)
        else:
            B = telemetry.imbalance(L_hat)
        jit = jax.random.uniform(
            jax.random.fold_in(s.rng, 3), (), minval=-1.0, maxval=1.0
        )
        s = s._replace(L_hat=L_hat, p50_hat=p50, p99_hat=p99)
        ctrl, _ = controller.fast(
            s.ctrl, _signals(s, B, jnp.max(p99), jit)
        )
        return s._replace(ctrl=ctrl)

    state = state._replace(sketch=sketch)
    state = jax.lax.cond(is_fast, ingest, lambda s: s, state)

    is_slow = (t1 % cfg.t_slow_ticks) == 0

    def slow(s: SimState) -> SimState:
        if fc is not None and fc.has_remap:
            B_slow = telemetry.imbalance_masked(s.L_hat, fx.detected)
        else:
            B_slow = telemetry.imbalance(s.L_hat)
        ctrl, k = controller.slow(
            s.ctrl,
            _signals(
                s,
                B_slow,
                jnp.max(s.p99_hat),
                jnp.zeros((), jnp.float32),
            ),
        )
        return s._replace(
            ctrl=ctrl,
            mw=tuple(
                mw.on_slow(ms, cfg, k) for mw, ms in zip(mws, s.mw)
            ),
            # window consumed: write-mix restarts for the next T_slow
            win_writes=jnp.zeros((), jnp.float32),
            win_events=jnp.zeros((), jnp.float32),
        )

    state = jax.lax.cond(is_slow, slow, lambda s: s, state)

    out = TickOut(
        L=L,
        arrivals=arrivals,
        lat_pred=lat_pred,
        d=state.ctrl.knobs.d,
        delta_l=state.ctrl.knobs.delta_l,
        f_max=state.ctrl.knobs.f_max,
        pressure=state.ctrl.pressure,
        steered=stats.steered,
        eligible=stats.eligible,
        cache_hits=absorbed,
        dV=stats.dV,
    )
    return state, out


def init_state(
    cfg: SimConfig, b_tgt: float = 0.15, p99_tgt: float = 500.0
) -> SimState:
    policy = policy_lib.get(cfg.policy)  # raises with available() names
    ring = hashring.make_ring(cfg.m, cfg.V)
    return SimState(
        L=jnp.zeros((cfg.m,), jnp.float32),
        L_hat=jnp.zeros((cfg.m,), jnp.float32),
        L_hat_p=jnp.zeros((cfg.P, cfg.m), jnp.float32),
        p50_hat=jnp.zeros((cfg.m,), jnp.float32),
        p99_hat=jnp.zeros((cfg.m,), jnp.float32),
        sketch=telemetry.make_sketch(cfg.m),
        policy=policy.init(cfg, ring),
        ctrl=_controller(cfg).init(cfg, (b_tgt, p99_tgt)),
        mw=tuple(mw.init(cfg) for mw in _middlewares(cfg)),
        win_writes=jnp.zeros((), jnp.float32),
        win_events=jnp.zeros((), jnp.float32),
        rng=jax.random.PRNGKey(cfg.seed),
    )


def _scan_inputs(
    cfg: SimConfig, ring: hashring.Ring, keys, mask, is_write, fc=None
):
    """Per-tick scan inputs for one (T, R) workload grid.

    The tick clock is an unbatched arange (see ``_tick``).  For the scan
    engine, the feasible sets for the ENTIRE horizon are gathered here in
    one batched call — (T, G, R/G, d_max) riding the scan's xs — so key
    hashing and the first-occurrence scan leave the per-tick path
    completely.  The unrolled reference keeps its in-tick gathers.

    With a compiled fault schedule (``fc``), storm traffic is overlaid
    on the workload grid first (so the hoisted gathers see the storm
    keys), membership epochs make the hoisted gathers member-aware, and
    the per-tick fault rows (``faults.FaultXs``) join the xs tuple.
    """
    if fc is not None and fc.has_storm:
        keys, mask, is_write = faults_lib.apply_traffic(
            fc, keys, mask, is_write
        )
    ticks = jnp.arange(keys.shape[0], dtype=jnp.int32)
    if cfg.unroll_waves:
        base = (ticks, keys, mask, is_write)
    else:
        keysg = _wave_split(cfg, keys)
        if fc is not None:
            feasg = faults_lib.feasible_by_epoch(
                ring, keysg, cfg.d_max, fc
            )
        else:
            feasg = hashring.feasible_set(ring, keysg, cfg.d_max)
        base = (ticks, feasg, keys, mask, is_write)
    if fc is not None:
        base = base + (faults_lib.make_xs(fc),)
    return base


# Trace counter for _run_scan: increments once per (re)trace, so the
# host-side obs spans can tag whether a call paid compilation — a
# Python-list mutation at trace time, invisible to the compiled math
# (the golden-parity contract is untouched).
_RUN_TRACES = [0]


@functools.partial(jax.jit, static_argnums=(0,))
def _run_scan(cfg: SimConfig, state: SimState, keys, mask, is_write):
    _RUN_TRACES[0] += 1
    ring = hashring.make_ring(cfg.m, cfg.V)
    fc = faults_lib.compile_faults(cfg, int(keys.shape[0]))
    step = functools.partial(
        _tick,
        cfg,
        ring,
        policy_lib.get(cfg.policy),
        _middlewares(cfg),
        _controller(cfg),
        fc,
    )
    xs = _scan_inputs(cfg, ring, keys, mask, is_write, fc)
    return jax.lax.scan(step, state, xs)


# Trace counter for _run_scan_sweep: increments only when the sweep scan is
# (re)compiled, letting tests assert "one compile per policy, any #seeds".
_SWEEP_TRACES = [0]


def _sweep_vmapped(
    cfg: SimConfig,
    states: SimState,
    keys,
    mask,
    is_write,
    metrics: str = "full",
):
    """The sweep body shared by the single-device jit (below) and the
    sharded runner (``repro.core.sweep``): nested vmap over (W, S) with
    identical per-cell math — what makes the sharded-vs-vmap parity
    contract bit-for-bit rather than merely approximate."""
    ring = hashring.make_ring(cfg.m, cfg.V)
    fc = faults_lib.compile_faults(cfg, int(keys.shape[1]))
    step = functools.partial(
        _tick,
        cfg,
        ring,
        policy_lib.get(cfg.policy),
        _middlewares(cfg),
        _controller(cfg),
        fc,
    )

    def run(st, k, mk, w):
        # unbatched tick clock + per-workload hoisted feasible sets: both
        # stay unbatched under the seed vmap (computed once per workload)
        grids = _scan_inputs(cfg, ring, k, mk, w, fc)
        if metrics == "summary":

            def tick(carry, xs):
                s, acc = carry
                s, out = step(s, xs)
                ys = KnobTrace(
                    d=out.d,
                    delta_l=out.delta_l,
                    f_max=out.f_max,
                    pressure=out.pressure,
                    q_mean=jnp.mean(out.L),
                )
                return (s, _summary_update(acc, out)), ys

            (final, acc), trace = jax.lax.scan(
                tick, (st, _summary_init(cfg.m)), grids
            )
            return final, (acc, trace)
        return jax.lax.scan(step, st, grids)

    return jax.vmap(
        lambda k, mk, w: jax.vmap(lambda st: run(st, k, mk, w))(states)
    )(keys, mask, is_write)


@functools.partial(jax.jit, static_argnums=(0, 5))
def _run_scan_sweep(
    cfg: SimConfig,
    states: SimState,
    keys,
    mask,
    is_write,
    metrics: str = "full",
):
    """Batched scan: ``states`` carries a leading seed axis (S, ...) and
    the workload grids a leading workload axis (W, T, R).

    The seed axis rides an INNER vmap with the grids held constant
    (closed over, i.e. ``in_axes=None`` semantics), so per-tick work
    that does not depend on the seed — key hashing, the batched
    feasible-set gather — is computed once per workload, not once per
    (workload, seed) combo, and nothing is ``jnp.repeat``-duplicated.
    Returns ``(final, outs)`` pytrees with leading (W, S) axes; ``outs``
    is the stacked TickOut timeline under ``metrics="full"`` and the
    O(m) :class:`SummaryAcc` under ``"summary"``.
    """
    _SWEEP_TRACES[0] += 1
    return _sweep_vmapped(cfg, states, keys, mask, is_write, metrics)


def warmup(
    cfg: SimConfig, T: int = 1200, seed: int = 99
) -> Tuple[float, float]:
    """§III-B: run at ≤30% utilization with no middleware, derive
    targets."""
    from repro.core.workloads import make_workload

    wl = make_workload(
        "light",
        T=T,
        m=cfg.m,
        seed=seed,
        dt_ms=cfg.dt_ms,
        service_ms=cfg.service_ms,
        N=cfg.N,
    )
    warm_cfg = dataclasses.replace(
        cfg, policy="hash", cache_enabled=False, middleware=(), faults=None
    )
    st = init_state(warm_cfg)
    with obs_trace.span("sim/warmup", cat="warmup", T=T, m=cfg.m):
        _, outs = _run_scan(warm_cfg, st, wl.keys, wl.mask, wl.is_write)
        jax.block_until_ready(outs.L)
    L = np.asarray(outs.L)
    # EWMA'd imbalance series, same smoothing as the controller —
    # vectorized closed-form filter (was an O(T) host-side Python loop)
    L_hat = telemetry.ewma_series(L, ctrl_lib.ALPHA_FAST)
    B = L_hat.std(axis=1) / (L_hat.mean(axis=1) + ctrl_lib.EPS)
    w = np.asarray(outs.arrivals)
    if w.sum() > 0:
        (p99_warm,) = telemetry.weighted_quantiles(
            np.asarray(outs.lat_pred), w, (99,)
        )
    else:
        p99_warm = cfg.service_ms
    b_tgt = float(np.median(B) + 0.05)
    p99_tgt = float(max(1.25 * p99_warm, cfg.rtt_ms + 2.0))
    return b_tgt, p99_tgt


def _final_cache(cfg: SimConfig, final: SimState):
    """Final cache pytree: the shared-table CacheState for "cache", the
    FleetState (converged table + per-proxy counters) for "fleet_cache"."""
    chain = cfg.middleware_chain
    for name in ("cache", "fleet_cache"):
        if name in chain:
            return jax.device_get(final.mw[chain.index(name)])
    return None


def _to_result(cfg: SimConfig, outs: TickOut, final_cache) -> SimResult:
    return SimResult(
        queue_timeline=np.asarray(outs.L),
        arrivals=np.asarray(outs.arrivals),
        lat_pred=np.asarray(outs.lat_pred),
        d_timeline=np.asarray(outs.d),
        delta_l_timeline=np.asarray(outs.delta_l),
        pressure=np.asarray(outs.pressure),
        steered=np.asarray(outs.steered),
        eligible=np.asarray(outs.eligible),
        cache_hits=np.asarray(outs.cache_hits),
        final_cache=final_cache,
        config=cfg,
        f_max_timeline=np.asarray(outs.f_max),
    )


def _targets(cfg: SimConfig, do_warmup: bool) -> Tuple[float, float]:
    if do_warmup and policy_lib.get_class(cfg.policy).adaptive:
        return warmup(cfg)
    return 0.15, 5.0 * cfg.service_ms


def simulate(
    cfg: SimConfig, wl: Workload, do_warmup: bool = True
) -> SimResult:
    b_tgt, p99_tgt = _targets(cfg, do_warmup)
    state = init_state(cfg, b_tgt, p99_tgt)
    traces0 = _RUN_TRACES[0]
    with obs_trace.span(
        "sim/run",
        cat="execute",
        policy=cfg.policy,
        controller=cfg.controller,
        T=int(wl.keys.shape[0]),
    ) as sp:
        final, outs = _run_scan(cfg, state, wl.keys, wl.mask, wl.is_write)
        jax.block_until_ready(outs.L)
        sp["compiled"] = _RUN_TRACES[0] > traces0
    with obs_trace.span("sim/host_result", cat="host"):
        return _to_result(cfg, outs, _final_cache(cfg, final))


# per-seed rows for one (policy, workload) combo
SweepRows = Tuple[Union[SimResult, SummaryResult], ...]

# Module-level once-per-process guard for the simulate_sweep
# DeprecationWarning: sweeps call the shim in loops, and one nag per
# process is signal while one per call is noise.  Tests reset it to
# assert the exactly-once contract.
_SWEEP_DEPRECATION_WARNED = [False]


def simulate_sweep(
    cfg: SimConfig,
    wl: Union[Workload, Sequence[Workload]],
    policies: Optional[Tuple[str, ...]] = None,
    seeds: Tuple[int, ...] = (0,),
    do_warmup: bool = True,
    metrics: str = "full",
    targets: Optional[Tuple[float, float]] = None,
) -> Union[Dict[str, SweepRows], Dict[str, Dict[str, SweepRows]]]:
    """Batched simulation: fan-out over ``policies × workloads × seeds``.

    ``wl`` is a single :class:`Workload` or a sequence of them (same grid
    shape, e.g. built under one set of ``make_workload`` params).  For
    each policy the scan is traced and compiled exactly once: workload
    grids ride an outer ``vmap`` axis and seeds an inner one that shares
    the grids (so seed-independent work — key hashing, the feasible-set
    gather — runs once per workload; per-seed/per-workload ``simulate``
    calls would each retrace, since ``cfg.seed`` is static).

    ``metrics="full"`` (default) returns :class:`SimResult` rows with
    complete (T, m) timelines.  ``metrics="summary"`` carries O(m)
    streaming accumulators through the scan instead and returns
    :class:`SummaryResult` rows — same paper-metric API, sweep memory
    O(B·m) instead of O(B·T·m), which is what lets E8/E9-scale matrices
    run many seeds per cell (DESIGN.md §9).

    ``targets`` pins the §III-B control targets ``(b_tgt, p99_tgt)``
    explicitly, skipping the per-policy warmup pass entirely — the
    warmup is policy- and controller-independent (it runs the ``hash``
    policy bare), so callers sweeping a grid of configs over one
    environment (e.g. E4's controller matrix) can run it once and share
    the result instead of recompiling it per cell.

    Returns ``{policy: (row per seed, ...)}`` for a single workload (the
    legacy shape) and ``{policy: {workload_name: (row per seed, ...)}}``
    for a sequence; per-combo full-metrics results match individual
    ``simulate`` runs.

    .. deprecated::
        ``simulate_sweep`` is a thin shim over the declarative API —
        build a :class:`repro.core.sweep.SweepSpec` and call
        :func:`repro.core.sweep.run_sweep` instead, which adds the
        controller axis, multi-device sharding, and a coordinate-
        addressable :class:`repro.core.sweep.SweepResult`.
    """
    if not _SWEEP_DEPRECATION_WARNED[0]:
        _SWEEP_DEPRECATION_WARNED[0] = True
        warnings.warn(
            "simulate_sweep is deprecated; build a repro.core.sweep."
            "SweepSpec and call run_sweep (DESIGN.md §12)",
            DeprecationWarning,
            stacklevel=2,
        )
    from repro.core import sweep as sweep_lib

    single = isinstance(wl, Workload)
    spec = sweep_lib.SweepSpec(
        config=cfg,
        workloads=wl,
        policies=tuple(policies) if policies is not None else None,
        seeds=tuple(seeds),
        metrics=metrics,
        do_warmup=do_warmup,
        targets=targets,
    )
    return sweep_lib.run_sweep(spec).to_legacy(single=single)
