"""Queue-network simulator for the MIDAS evaluation (paper §VI).

m metadata servers, each a FIFO queue with constant 100 ms service time
(the paper's stress bound).  Time advances in dt_ms ticks under
``jax.lax.scan``; each tick routes a padded batch of requests with one of
the policies in routing.py, applies service, refreshes (delayed) telemetry,
and runs the fast/slow control loops on their paper cadences.

Within a tick, requests are processed in ``n_groups`` sequential waves:
every wave sees the stale EWMA telemetry *plus* the proxies' own
assignments from earlier waves (a proxy knows what it already sent), which
is the honest middle ground between full per-request sequencing and pure
batch routing.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cache_lib
from repro.core import control as ctl
from repro.core import hashring, routing, telemetry
from repro.core.workloads import Workload

POLICIES = ("round_robin", "rr_request", "uniform", "hash", "power_of_d",
            "midas")


@dataclasses.dataclass(frozen=True)
class SimConfig:
    m: int = 8                     # metadata servers
    P: int = 8                     # independent proxies (RR phases)
    N: int = 4096                  # namespace size (keys)
    dt_ms: float = 50.0
    service_ms: float = 100.0      # paper: constant 100 ms per RPC
    policy: str = "midas"
    d_max: int = 4
    V: int = 64                    # virtual nodes per server
    rtt_ms: float = 2.0
    n_groups: int = 8              # routing waves per tick
    cache_enabled: bool = False    # cooperative cache in front of routing
    cache_mode: str = "lease"      # lease | ttl_aggregate | ttl_per_key
    lease_ms: float = 5000.0
    p_star: float = 1e-4
    fixed_d: int = 2               # d for power_of_d policy
    ablate: str = ""               # "no_margin" | "no_pin" | "no_bucket"
    seed: int = 0

    @property
    def t_fast_ticks(self) -> int:
        return max(int(round(ctl.T_FAST_MS / self.dt_ms)), 1)

    @property
    def t_slow_ticks(self) -> int:
        return max(int(round(ctl.T_SLOW_MS / self.dt_ms)), 1)

    @property
    def w_ticks(self) -> int:
        return max(int(round(ctl.W_WINDOW_MS / self.dt_ms)), 1)

    @property
    def serve_per_tick(self) -> float:
        return self.dt_ms / self.service_ms


class SimState(NamedTuple):
    tick: jnp.ndarray            # () int32
    L: jnp.ndarray               # (m,) float32 queue length
    L_hat: jnp.ndarray           # (m,) float32 EWMA of observed L
    p50_hat: jnp.ndarray         # (m,) float32 EWMA p50 (ms)
    p99_hat: jnp.ndarray         # (m,) float32 EWMA p99 (ms)
    sketch: telemetry.LatencySketch
    router: routing.RouterState
    ctrl: ctl.ControlState
    cache: cache_lib.CacheState
    rng: jnp.ndarray


class TickOut(NamedTuple):
    L: jnp.ndarray               # (m,) queue snapshot after tick
    arrivals: jnp.ndarray        # (m,) arrivals routed this tick
    lat_pred: jnp.ndarray        # (m,) predicted latency of a new arrival (ms)
    d: jnp.ndarray               # () int32 control knob
    delta_l: jnp.ndarray         # ()
    pressure: jnp.ndarray        # ()
    steered: jnp.ndarray         # ()
    eligible: jnp.ndarray        # ()
    cache_hits: jnp.ndarray      # ()
    dV: jnp.ndarray              # () potential change from steering this tick


class SimResult(NamedTuple):
    queue_timeline: np.ndarray   # (T, m)
    arrivals: np.ndarray         # (T, m)
    lat_pred: np.ndarray         # (T, m)
    d_timeline: np.ndarray       # (T,)
    delta_l_timeline: np.ndarray
    pressure: np.ndarray         # (T,)
    steered: np.ndarray          # (T,)
    eligible: np.ndarray         # (T,)
    cache_hits: np.ndarray       # (T,)
    final_cache: Optional[cache_lib.CacheState]
    config: SimConfig

    # ---- paper metrics -------------------------------------------------
    def mean_queue(self) -> float:
        return float(self.queue_timeline.mean())

    def max_queue(self) -> float:
        return float(self.queue_timeline.max())

    def worst_case_queue(self, q: float = 99.9) -> float:
        return float(np.percentile(self.queue_timeline, q))

    def dispersion(self) -> float:
        """CV of per-server time-averaged queue length (paper §VI-C)."""
        per_server = self.queue_timeline.mean(axis=0)
        mu = per_server.mean()
        if mu < 1e-9:
            return 0.0
        return float(per_server.std() / mu)

    def dispersion_t(self) -> float:
        """Time-average of instantaneous CV across servers."""
        mu = self.queue_timeline.mean(axis=1)
        sd = self.queue_timeline.std(axis=1)
        ok = mu > 1e-9
        if not ok.any():
            return 0.0
        return float((sd[ok] / mu[ok]).mean())

    def latency_quantiles(self, qs=(50, 99)) -> Tuple[float, ...]:
        """Arrival-weighted request latency quantiles (ms)."""
        lat = self.lat_pred.reshape(-1)
        w = self.arrivals.reshape(-1)
        if w.sum() <= 0:
            return tuple(0.0 for _ in qs)
        order = np.argsort(lat)
        lat, w = lat[order], w[order]
        cum = np.cumsum(w) / w.sum()
        return tuple(float(lat[np.searchsorted(cum, q / 100.0)])
                     for q in qs)


def _route_group(cfg: SimConfig, ring: hashring.Ring, state: SimState,
                 L_view, keys, mask, rng, now_ms):
    """Dispatch one wave of requests under the configured policy."""
    if cfg.policy == "round_robin":
        return state, routing.route_round_robin(keys, mask, cfg.m), None
    if cfg.policy == "rr_request":
        proxy = jax.random.randint(jax.random.fold_in(rng, 11), keys.shape,
                                   0, cfg.P, dtype=jnp.int32)
        router, assign = routing.route_rr_per_request(state.router, proxy,
                                                      mask, cfg.m)
        return state._replace(router=router), assign, None
    if cfg.policy == "uniform":
        return state, routing.route_uniform(rng, mask, cfg.m), None
    if cfg.policy == "hash":
        return state, routing.route_hash(ring, keys, mask), None
    feas = hashring.feasible_set(ring, keys, cfg.d_max)
    if cfg.policy == "power_of_d":
        assign = routing.route_power_of_d(rng, feas, L_view, mask,
                                          cfg.fixed_d)
        return state, assign, None
    if cfg.policy == "midas":
        # stability-mechanism ablations (benchmarks/ablations.py)
        delta_l = (jnp.zeros(()) if "no_margin" in cfg.ablate
                   else state.ctrl.delta_l)
        delta_t = (jnp.zeros(()) - 1e9 if "no_margin" in cfg.ablate
                   else state.ctrl.delta_t)
        f_max = (jnp.ones(()) if "no_bucket" in cfg.ablate
                 else state.ctrl.f_max)
        pin_ms = 0.0 if "no_pin" in cfg.ablate else ctl.PIN_C_MS
        router, assign, stats = routing.route_midas(
            state.router, rng, keys, feas, L_view, state.p50_hat, mask,
            state.ctrl.d, delta_l, delta_t, f_max, now_ms, pin_ms,
            cfg.w_ticks)
        return state._replace(router=router), assign, stats
    raise ValueError(f"unknown policy {cfg.policy!r}")


def _tick(cfg: SimConfig, ring: hashring.Ring, state: SimState,
          inputs) -> Tuple[SimState, TickOut]:
    keys, mask, is_write = inputs
    now_ms = state.tick.astype(jnp.float32) * cfg.dt_ms
    rng, r_cache, r_route = jax.random.split(state.rng, 3)
    state = state._replace(rng=rng)

    cache_hits = jnp.zeros((), jnp.float32)
    if cfg.cache_enabled:
        new_cache, hit = cache_lib.lookup_batch(
            state.cache, keys, mask, is_write, now_ms,
            mode=cfg.cache_mode, lease_ms=cfg.lease_ms, rtt_ms=cfg.rtt_ms,
            p_star=cfg.p_star)
        state = state._replace(cache=new_cache)
        mask = mask & ~hit                      # hits never reach the servers
        cache_hits = jnp.sum(hit).astype(jnp.float32)

    # --- route in waves; later waves see earlier waves' own assignments ---
    R = keys.shape[0]
    G = cfg.n_groups
    pad = (-R) % G
    keysg = jnp.pad(keys, (0, pad)).reshape(G, -1)
    maskg = jnp.pad(mask, (0, pad)).reshape(G, -1)

    L_self = jnp.zeros((cfg.m,), jnp.float32)   # own sends this tick
    arrivals = jnp.zeros((cfg.m,), jnp.float32)
    steered = jnp.zeros((), jnp.float32)
    eligible = jnp.zeros((), jnp.float32)
    dV = jnp.zeros((), jnp.float32)
    for g in range(G):
        rg = jax.random.fold_in(r_route, g)
        L_view = state.L_hat + L_self
        state, assign, stats = _route_group(cfg, ring, state, L_view,
                                            keysg[g], maskg[g], rg, now_ms)
        counts = jnp.zeros((cfg.m,), jnp.float32).at[
            jnp.where(maskg[g], assign, 0)].add(
            jnp.where(maskg[g], 1.0, 0.0))
        # Lyapunov bookkeeping: ΔV contribution of steering away from primary
        if cfg.policy in ("power_of_d", "midas"):
            prim = hashring.primary(ring, keysg[g])
            moved = maskg[g] & (assign != prim) & (assign >= 0)
            dV = dV + jnp.sum(jnp.where(
                moved, 2.0 * (L_view[assign] - L_view[prim]) + 2.0, 0.0))
        L_self = L_self + counts
        arrivals = arrivals + counts
        if stats is not None:
            steered = steered + stats.steered
            eligible = eligible + stats.eligible

    # --- queue dynamics: constant-rate servers, work-conserving ----------
    L = state.L + arrivals
    served = jnp.minimum(L, cfg.serve_per_tick)
    L = L - served
    lat_pred = (state.L + arrivals) * cfg.service_ms  # wait of a new arrival

    state = state._replace(L=L, tick=state.tick + 1)

    # --- telemetry ingest + fast control (every T_fast) ------------------
    is_fast = (state.tick % cfg.t_fast_ticks) == 0
    sketch = telemetry.sketch_add(state.sketch, lat_pred)
    p50_o, p99_o = telemetry.sketch_quantiles(sketch)

    def ingest(s: SimState) -> SimState:
        L_hat = telemetry.ewma(s.L_hat, s.L, ctl.ALPHA_FAST)
        p50 = telemetry.ewma(s.p50_hat, p50_o, ctl.ALPHA_FAST)
        p99 = telemetry.ewma(s.p99_hat, p99_o, ctl.ALPHA_FAST)
        B = telemetry.imbalance(L_hat)
        jit = jax.random.uniform(jax.random.fold_in(s.rng, 3), (),
                                 minval=-1.0, maxval=1.0)
        ctrl = ctl.fast_update(s.ctrl, B, jnp.max(p99), cfg.rtt_ms, jit)
        return s._replace(L_hat=L_hat, p50_hat=p50, p99_hat=p99, ctrl=ctrl)

    state = state._replace(sketch=sketch)
    state = jax.lax.cond(is_fast, ingest, lambda s: s, state)

    if cfg.cache_enabled:
        is_slow = (state.tick % cfg.t_slow_ticks) == 0
        lease = cfg.lease_ms if cfg.cache_mode == "lease" else jnp.inf

        def slow(s: SimState) -> SimState:
            return s._replace(cache=cache_lib.slow_update(
                s.cache, ctl.T_SLOW_MS, cfg.rtt_ms, lease, cfg.p_star))

        state = jax.lax.cond(is_slow, slow, lambda s: s, state)

    out = TickOut(L=L, arrivals=arrivals, lat_pred=lat_pred,
                  d=state.ctrl.d, delta_l=state.ctrl.delta_l,
                  pressure=state.ctrl.pressure, steered=steered,
                  eligible=eligible, cache_hits=cache_hits, dV=dV)
    return state, out


def init_state(cfg: SimConfig, b_tgt: float = 0.15,
               p99_tgt: float = 500.0) -> SimState:
    return SimState(
        tick=jnp.zeros((), jnp.int32),
        L=jnp.zeros((cfg.m,), jnp.float32),
        L_hat=jnp.zeros((cfg.m,), jnp.float32),
        p50_hat=jnp.zeros((cfg.m,), jnp.float32),
        p99_hat=jnp.zeros((cfg.m,), jnp.float32),
        sketch=telemetry.make_sketch(cfg.m),
        router=routing.init_router(cfg.P, cfg.N, cfg.w_ticks, cfg.seed),
        ctrl=ctl.init_control(cfg.rtt_ms, b_tgt, p99_tgt),
        cache=cache_lib.init_cache(cfg.N),
        rng=jax.random.PRNGKey(cfg.seed))


@functools.partial(jax.jit, static_argnums=(0,))
def _run_scan(cfg: SimConfig, state: SimState, keys, mask, is_write):
    ring = hashring.make_ring(cfg.m, cfg.V)
    step = functools.partial(_tick, cfg, ring)
    return jax.lax.scan(step, state, (keys, mask, is_write))


def warmup(cfg: SimConfig, T: int = 1200, seed: int = 99
           ) -> Tuple[float, float]:
    """§III-B: run at ≤30% utilization with no middleware, derive targets."""
    from repro.core.workloads import make_workload
    wl = make_workload("light", T=T, m=cfg.m, seed=seed, dt_ms=cfg.dt_ms,
                       service_ms=cfg.service_ms, N=cfg.N)
    warm_cfg = dataclasses.replace(cfg, policy="hash", cache_enabled=False)
    st = init_state(warm_cfg)
    _, outs = _run_scan(warm_cfg, st, wl.keys, wl.mask, wl.is_write)
    L = np.asarray(outs.L)
    # EWMA'd imbalance series, same smoothing as the controller
    L_hat = np.zeros_like(L)
    acc = np.zeros(L.shape[1])
    for t in range(L.shape[0]):
        acc = (1 - ctl.ALPHA_FAST) * acc + ctl.ALPHA_FAST * L[t]
        L_hat[t] = acc
    B = L_hat.std(axis=1) / (L_hat.mean(axis=1) + ctl.EPS)
    lat = np.asarray(outs.lat_pred)
    w = np.asarray(outs.arrivals)
    flat, fw = lat.reshape(-1), w.reshape(-1)
    if fw.sum() > 0:
        order = np.argsort(flat)
        cum = np.cumsum(fw[order]) / fw.sum()
        p99_warm = float(flat[order][np.searchsorted(cum, 0.99)])
    else:
        p99_warm = cfg.service_ms
    b_tgt = float(np.median(B) + 0.05)
    p99_tgt = float(max(1.25 * p99_warm, cfg.rtt_ms + 2.0))
    return b_tgt, p99_tgt


def simulate(cfg: SimConfig, wl: Workload,
             do_warmup: bool = True) -> SimResult:
    if do_warmup and cfg.policy == "midas":
        b_tgt, p99_tgt = warmup(cfg)
    else:
        b_tgt, p99_tgt = 0.15, 5.0 * cfg.service_ms
    state = init_state(cfg, b_tgt, p99_tgt)
    final, outs = _run_scan(cfg, state, wl.keys, wl.mask, wl.is_write)
    return SimResult(
        queue_timeline=np.asarray(outs.L),
        arrivals=np.asarray(outs.arrivals),
        lat_pred=np.asarray(outs.lat_pred),
        d_timeline=np.asarray(outs.d),
        delta_l_timeline=np.asarray(outs.delta_l),
        pressure=np.asarray(outs.pressure),
        steered=np.asarray(outs.steered),
        eligible=np.asarray(outs.eligible),
        cache_hits=np.asarray(outs.cache_hits),
        final_cache=jax.device_get(final.cache) if cfg.cache_enabled else None,
        config=cfg)
