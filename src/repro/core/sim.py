"""Queue-network simulator for the MIDAS evaluation (paper §VI).

m metadata servers, each a FIFO queue with constant 100 ms service time
(the paper's stress bound).  Time advances in dt_ms ticks under
``jax.lax.scan``; each tick first runs the middleware pipeline (stages may
absorb requests at the proxy — the cooperative cache is the reference
stage), then routes the surviving batch with the policy resolved from the
registry (``repro.core.policies``), applies service, refreshes (delayed)
telemetry, and runs the fast/slow control loops on their paper cadences.

Within a tick, requests are processed in ``n_groups`` sequential waves:
every wave sees the stale EWMA telemetry *plus* the proxies' own
assignments from earlier waves (a proxy knows what it already sent), which
is the honest middle ground between full per-request sequencing and pure
batch routing.

``simulate`` runs one config; ``simulate_sweep`` batches seeds with
``jax.vmap`` (one compiled scan per policy, regardless of seed count) and
fans out across policies — the API the benchmark suite uses.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cache_lib
from repro.core import control as ctl
from repro.core import hashring, telemetry
from repro.core import middleware as mw_lib
from repro.core import policies as policy_lib
from repro.core.policies.base import ControlKnobs, RouteContext
from repro.core.workloads import Workload

# Snapshot of the registry at import time; prefer policies.available().
POLICIES = policy_lib.available()


@dataclasses.dataclass(frozen=True)
class SimConfig:
    m: int = 8                     # metadata servers
    P: int = 8                     # independent proxies (fleet size)
    N: int = 4096                  # namespace size (keys)
    dt_ms: float = 50.0
    service_ms: float = 100.0      # paper: constant 100 ms per RPC
    policy: str = "midas"          # any name in policies.available()
    d_max: int = 4
    V: int = 64                    # virtual nodes per server
    rtt_ms: float = 2.0
    n_groups: int = 8              # routing waves per tick
    middleware: Tuple[str, ...] = ()  # pipeline stages, applied in order
    cache_enabled: bool = False    # legacy alias for middleware=("cache",)
    cache_mode: str = "lease"      # lease | ttl_aggregate | ttl_per_key
    lease_ms: float = 5000.0
    p_star: float = 1e-4
    # fleet knobs (repro.core.fleet): gossip propagation delay for the
    # "fleet_cache" stage, and per-proxy routing (one wave per proxy, own
    # staggered telemetry view, no within-tick sharing across proxies —
    # replaces the n_groups waves when enabled)
    gossip_ms: float = 0.0
    fleet_routing: bool = False
    fixed_d: int = 2               # d for power_of_d policy
    ablate: str = ""               # "no_margin" | "no_pin" | "no_bucket"
    seed: int = 0

    def __post_init__(self):
        """Eager validation: bad names/sizes fail at construction with the
        alternatives spelled out, not deep inside the jitted scan."""
        for name in ("m", "P", "N", "V", "n_groups", "d_max", "fixed_d"):
            v = getattr(self, name)
            if not isinstance(v, int) or isinstance(v, bool) or v <= 0:
                raise ValueError(
                    f"SimConfig.{name} must be a positive int, got {v!r}")
        if self.policy not in policy_lib.available():
            raise ValueError(
                f"unknown policy {self.policy!r}; available: "
                f"{', '.join(policy_lib.available())}")
        for stage in self.middleware:
            if stage not in mw_lib.available():
                raise ValueError(
                    f"unknown middleware stage {stage!r}; available: "
                    f"{', '.join(mw_lib.available())}")
        if self.cache_mode not in cache_lib.MODES:
            raise ValueError(
                f"unknown cache_mode {self.cache_mode!r}; available: "
                f"{', '.join(cache_lib.MODES)}")
        if self.gossip_ms < 0:
            raise ValueError(
                f"SimConfig.gossip_ms must be >= 0, got {self.gossip_ms!r}")

    @property
    def t_fast_ticks(self) -> int:
        return max(int(round(ctl.T_FAST_MS / self.dt_ms)), 1)

    @property
    def t_slow_ticks(self) -> int:
        return max(int(round(ctl.T_SLOW_MS / self.dt_ms)), 1)

    @property
    def w_ticks(self) -> int:
        return max(int(round(ctl.W_WINDOW_MS / self.dt_ms)), 1)

    @property
    def serve_per_tick(self) -> float:
        return self.dt_ms / self.service_ms

    @property
    def middleware_chain(self) -> Tuple[str, ...]:
        """Resolved pipeline: the legacy cache flag prepends the cache."""
        chain = tuple(self.middleware)
        if self.cache_enabled and "cache" not in chain:
            chain = ("cache",) + chain
        return chain


class SimState(NamedTuple):
    tick: jnp.ndarray            # () int32
    L: jnp.ndarray               # (m,) float32 queue length
    L_hat: jnp.ndarray           # (m,) float32 EWMA of observed L
    L_hat_p: jnp.ndarray         # (P, m) float32 per-proxy views (fleet)
    p50_hat: jnp.ndarray         # (m,) float32 EWMA p50 (ms)
    p99_hat: jnp.ndarray         # (m,) float32 EWMA p99 (ms)
    sketch: telemetry.LatencySketch
    policy: tuple                # policy-owned pytree (see policies.base)
    ctrl: ctl.ControlState
    mw: tuple                    # per-stage middleware pytrees, chain order
    rng: jnp.ndarray


class TickOut(NamedTuple):
    L: jnp.ndarray               # (m,) queue snapshot after tick
    arrivals: jnp.ndarray        # (m,) arrivals routed this tick
    lat_pred: jnp.ndarray        # (m,) predicted latency of a new arrival (ms)
    d: jnp.ndarray               # () int32 control knob
    delta_l: jnp.ndarray         # ()
    f_max: jnp.ndarray           # () steering-bucket cap this tick
    pressure: jnp.ndarray        # ()
    steered: jnp.ndarray         # ()
    eligible: jnp.ndarray        # ()
    cache_hits: jnp.ndarray      # () requests absorbed by the pipeline
    dV: jnp.ndarray              # () potential change from steering this tick


class SimResult(NamedTuple):
    queue_timeline: np.ndarray   # (T, m)
    arrivals: np.ndarray         # (T, m)
    lat_pred: np.ndarray         # (T, m)
    d_timeline: np.ndarray       # (T,)
    delta_l_timeline: np.ndarray
    pressure: np.ndarray         # (T,)
    steered: np.ndarray          # (T,)
    eligible: np.ndarray         # (T,)
    cache_hits: np.ndarray       # (T,)
    final_cache: Optional[object]
    config: SimConfig
    f_max_timeline: Optional[np.ndarray] = None   # (T,) bucket cap

    # ---- paper metrics -------------------------------------------------
    def mean_queue(self) -> float:
        return float(self.queue_timeline.mean())

    def max_queue(self) -> float:
        return float(self.queue_timeline.max())

    def worst_case_queue(self, q: float = 99.9) -> float:
        return float(np.percentile(self.queue_timeline, q))

    def dispersion(self) -> float:
        """CV of per-server time-averaged queue length (paper §VI-C)."""
        per_server = self.queue_timeline.mean(axis=0)
        mu = per_server.mean()
        if mu < 1e-9:
            return 0.0
        return float(per_server.std() / mu)

    def dispersion_t(self) -> float:
        """Time-average of instantaneous CV across servers."""
        mu = self.queue_timeline.mean(axis=1)
        sd = self.queue_timeline.std(axis=1)
        ok = mu > 1e-9
        if not ok.any():
            return 0.0
        return float((sd[ok] / mu[ok]).mean())

    def latency_quantiles(self, qs=(50, 99)) -> Tuple[float, ...]:
        """Arrival-weighted request latency quantiles (ms)."""
        lat = self.lat_pred.reshape(-1)
        w = self.arrivals.reshape(-1)
        if w.sum() <= 0:
            return tuple(0.0 for _ in qs)
        order = np.argsort(lat)
        lat, w = lat[order], w[order]
        cum = np.cumsum(w) / w.sum()
        # fp rounding can leave cum[-1] < 1.0, pushing searchsorted past the
        # last index — clip.
        last = lat.size - 1
        return tuple(
            float(lat[min(int(np.searchsorted(cum, q / 100.0)), last)])
            for q in qs)


def _middlewares(cfg: SimConfig) -> Tuple[mw_lib.Middleware, ...]:
    return tuple(mw_lib.get(name) for name in cfg.middleware_chain)


def _knob_view(cfg: SimConfig, ctrl: ctl.ControlState) -> ControlKnobs:
    """Control knobs as policies see them, with stability-mechanism
    ablations (benchmarks/ablations.py) applied uniformly."""
    delta_l = (jnp.zeros(()) if "no_margin" in cfg.ablate else ctrl.delta_l)
    delta_t = (jnp.zeros(()) - 1e9 if "no_margin" in cfg.ablate
               else ctrl.delta_t)
    f_max = (jnp.ones(()) if "no_bucket" in cfg.ablate else ctrl.f_max)
    pin_ms = 0.0 if "no_pin" in cfg.ablate else ctl.PIN_C_MS
    return ControlKnobs(d=ctrl.d, delta_l=delta_l, delta_t=delta_t,
                        f_max=f_max, pin_ms=pin_ms)


def _tick(cfg: SimConfig, ring: hashring.Ring, policy: policy_lib.Policy,
          mws: Tuple[mw_lib.Middleware, ...], state: SimState,
          inputs) -> Tuple[SimState, TickOut]:
    keys, mask, is_write = inputs
    now_ms = state.tick.astype(jnp.float32) * cfg.dt_ms
    rng, r_mw, r_route = jax.random.split(state.rng, 3)
    state = state._replace(rng=rng)

    # --- middleware pipeline: stages may absorb requests at the proxy -----
    absorbed = jnp.zeros((), jnp.float32)
    mw_states = list(state.mw)
    for i, mw in enumerate(mws):
        batch = mw_lib.BatchView(keys=keys, mask=mask, is_write=is_write,
                                 now_ms=now_ms,
                                 rng=jax.random.fold_in(r_mw, i))
        mw_states[i], mask, took = mw.on_batch(mw_states[i], batch, cfg)
        absorbed = absorbed + took
    state = state._replace(mw=tuple(mw_states))

    # --- route in waves ---------------------------------------------------
    # Legacy: n_groups sequential waves, later waves seeing earlier waves'
    # own assignments (a proxy knows what it already sent).  Fleet: one
    # wave per proxy — wave g holds slots r ≡ g (mod P), served by proxy
    # (g + tick) % P to match fleet.proxy_assign — each routing from its
    # OWN staggered telemetry view with no within-tick sharing:
    # independent proxies cannot see each other's sends until telemetry
    # reports them.
    R = keys.shape[0]
    if cfg.fleet_routing:
        G = cfg.P
        pad = (-R) % G
        keysg = jnp.pad(keys, (0, pad)).reshape(-1, G).T
        maskg = jnp.pad(mask, (0, pad)).reshape(-1, G).T
    else:
        G = cfg.n_groups
        pad = (-R) % G
        keysg = jnp.pad(keys, (0, pad)).reshape(G, -1)
        maskg = jnp.pad(mask, (0, pad)).reshape(G, -1)

    knobs = _knob_view(cfg, state.ctrl)
    ps = state.policy
    L_self = jnp.zeros((cfg.m,), jnp.float32)   # own sends this tick
    arrivals = jnp.zeros((cfg.m,), jnp.float32)
    steered = jnp.zeros((), jnp.float32)
    eligible = jnp.zeros((), jnp.float32)
    dV = jnp.zeros((), jnp.float32)
    for g in range(G):
        # fleet: wave g holds slots r ≡ g (mod P), which fleet_cache
        # serves as proxy (g + tick) % P — rotate to that proxy's view
        if cfg.fleet_routing:
            L_view = state.L_hat_p[(g + state.tick) % G]
        else:
            L_view = state.L_hat + L_self
        ctx = RouteContext(
            keys=keysg[g], mask=maskg[g],
            feas=hashring.feasible_set(ring, keysg[g], cfg.d_max),
            L_view=L_view, p50_view=state.p50_hat,
            knobs=knobs, now_ms=now_ms,
            rng=jax.random.fold_in(r_route, g),
            m=cfg.m, fixed_d=cfg.fixed_d)
        ps, assign, stats = policy.route(ps, ctx)
        counts = jnp.zeros((cfg.m,), jnp.float32).at[
            jnp.where(maskg[g], assign, 0)].add(
            jnp.where(maskg[g], 1.0, 0.0))
        L_self = L_self + counts
        arrivals = arrivals + counts
        steered = steered + stats.steered
        eligible = eligible + stats.eligible
        dV = dV + stats.dV
    state = state._replace(policy=ps)

    # --- queue dynamics: constant-rate servers, work-conserving ----------
    L = state.L + arrivals
    served = jnp.minimum(L, cfg.serve_per_tick)
    L = L - served
    lat_pred = (state.L + arrivals) * cfg.service_ms  # wait of a new arrival

    state = state._replace(L=L, tick=state.tick + 1)

    # --- telemetry ingest + fast control (every T_fast) ------------------
    is_fast = (state.tick % cfg.t_fast_ticks) == 0
    sketch = telemetry.sketch_add(state.sketch, lat_pred)
    p50_o, p99_o = telemetry.sketch_quantiles(sketch)

    if cfg.fleet_routing:
        # per-proxy views: each proxy polls on its own staggered phase, so
        # the P views carry genuinely different staleness at any instant
        state = state._replace(L_hat_p=telemetry.ewma_staggered(
            state.L_hat_p, state.L, state.tick, cfg.t_fast_ticks,
            ctl.ALPHA_FAST))

    def ingest(s: SimState) -> SimState:
        if cfg.fleet_routing:
            # one control loop fed by the fleet's consensus view
            L_hat = ctl.consensus_view(s.L_hat_p)
        else:
            L_hat = telemetry.ewma(s.L_hat, s.L, ctl.ALPHA_FAST)
        p50 = telemetry.ewma(s.p50_hat, p50_o, ctl.ALPHA_FAST)
        p99 = telemetry.ewma(s.p99_hat, p99_o, ctl.ALPHA_FAST)
        B = telemetry.imbalance(L_hat)
        jit = jax.random.uniform(jax.random.fold_in(s.rng, 3), (),
                                 minval=-1.0, maxval=1.0)
        ctrl = ctl.fast_update(s.ctrl, B, jnp.max(p99), cfg.rtt_ms, jit)
        return s._replace(L_hat=L_hat, p50_hat=p50, p99_hat=p99, ctrl=ctrl)

    state = state._replace(sketch=sketch)
    state = jax.lax.cond(is_fast, ingest, lambda s: s, state)

    if mws:
        is_slow = (state.tick % cfg.t_slow_ticks) == 0

        def slow(s: SimState) -> SimState:
            return s._replace(mw=tuple(
                mw.on_slow(ms, cfg) for mw, ms in zip(mws, s.mw)))

        state = jax.lax.cond(is_slow, slow, lambda s: s, state)

    out = TickOut(L=L, arrivals=arrivals, lat_pred=lat_pred,
                  d=state.ctrl.d, delta_l=state.ctrl.delta_l,
                  f_max=state.ctrl.f_max,
                  pressure=state.ctrl.pressure, steered=steered,
                  eligible=eligible, cache_hits=absorbed, dV=dV)
    return state, out


def init_state(cfg: SimConfig, b_tgt: float = 0.15,
               p99_tgt: float = 500.0) -> SimState:
    policy = policy_lib.get(cfg.policy)     # raises with available() names
    ring = hashring.make_ring(cfg.m, cfg.V)
    return SimState(
        tick=jnp.zeros((), jnp.int32),
        L=jnp.zeros((cfg.m,), jnp.float32),
        L_hat=jnp.zeros((cfg.m,), jnp.float32),
        L_hat_p=jnp.zeros((cfg.P, cfg.m), jnp.float32),
        p50_hat=jnp.zeros((cfg.m,), jnp.float32),
        p99_hat=jnp.zeros((cfg.m,), jnp.float32),
        sketch=telemetry.make_sketch(cfg.m),
        policy=policy.init(cfg, ring),
        ctrl=ctl.init_control(cfg.rtt_ms, b_tgt, p99_tgt),
        mw=tuple(mw.init(cfg) for mw in _middlewares(cfg)),
        rng=jax.random.PRNGKey(cfg.seed))


@functools.partial(jax.jit, static_argnums=(0,))
def _run_scan(cfg: SimConfig, state: SimState, keys, mask, is_write):
    ring = hashring.make_ring(cfg.m, cfg.V)
    step = functools.partial(_tick, cfg, ring, policy_lib.get(cfg.policy),
                             _middlewares(cfg))
    return jax.lax.scan(step, state, (keys, mask, is_write))


# Trace counter for _run_scan_sweep: increments only when the sweep scan is
# (re)compiled, letting tests assert "one compile per policy, any #seeds".
_SWEEP_TRACES = [0]


@functools.partial(jax.jit, static_argnums=(0,))
def _run_scan_sweep(cfg: SimConfig, states: SimState, keys, mask, is_write):
    """Batched scan: ``states`` and the workload grids both carry a leading
    batch axis (seed × workload combos flattened)."""
    _SWEEP_TRACES[0] += 1
    ring = hashring.make_ring(cfg.m, cfg.V)
    step = functools.partial(_tick, cfg, ring, policy_lib.get(cfg.policy),
                             _middlewares(cfg))
    return jax.vmap(
        lambda st, k, mk, w: jax.lax.scan(step, st, (k, mk, w)))(
        states, keys, mask, is_write)


def warmup(cfg: SimConfig, T: int = 1200, seed: int = 99
           ) -> Tuple[float, float]:
    """§III-B: run at ≤30% utilization with no middleware, derive targets."""
    from repro.core.workloads import make_workload
    wl = make_workload("light", T=T, m=cfg.m, seed=seed, dt_ms=cfg.dt_ms,
                       service_ms=cfg.service_ms, N=cfg.N)
    warm_cfg = dataclasses.replace(cfg, policy="hash", cache_enabled=False,
                                   middleware=())
    st = init_state(warm_cfg)
    _, outs = _run_scan(warm_cfg, st, wl.keys, wl.mask, wl.is_write)
    L = np.asarray(outs.L)
    # EWMA'd imbalance series, same smoothing as the controller
    L_hat = np.zeros_like(L)
    acc = np.zeros(L.shape[1])
    for t in range(L.shape[0]):
        acc = (1 - ctl.ALPHA_FAST) * acc + ctl.ALPHA_FAST * L[t]
        L_hat[t] = acc
    B = L_hat.std(axis=1) / (L_hat.mean(axis=1) + ctl.EPS)
    lat = np.asarray(outs.lat_pred)
    w = np.asarray(outs.arrivals)
    flat, fw = lat.reshape(-1), w.reshape(-1)
    if fw.sum() > 0:
        order = np.argsort(flat)
        cum = np.cumsum(fw[order]) / fw.sum()
        idx = min(int(np.searchsorted(cum, 0.99)), flat.size - 1)  # fp clip
        p99_warm = float(flat[order][idx])
    else:
        p99_warm = cfg.service_ms
    b_tgt = float(np.median(B) + 0.05)
    p99_tgt = float(max(1.25 * p99_warm, cfg.rtt_ms + 2.0))
    return b_tgt, p99_tgt


def _final_cache(cfg: SimConfig, final: SimState):
    """Final cache pytree: the shared-table CacheState for "cache", the
    FleetState (converged table + per-proxy counters) for "fleet_cache"."""
    chain = cfg.middleware_chain
    for name in ("cache", "fleet_cache"):
        if name in chain:
            return jax.device_get(final.mw[chain.index(name)])
    return None


def _to_result(cfg: SimConfig, outs: TickOut, final_cache) -> SimResult:
    return SimResult(
        queue_timeline=np.asarray(outs.L),
        arrivals=np.asarray(outs.arrivals),
        lat_pred=np.asarray(outs.lat_pred),
        d_timeline=np.asarray(outs.d),
        delta_l_timeline=np.asarray(outs.delta_l),
        pressure=np.asarray(outs.pressure),
        steered=np.asarray(outs.steered),
        eligible=np.asarray(outs.eligible),
        cache_hits=np.asarray(outs.cache_hits),
        final_cache=final_cache,
        config=cfg,
        f_max_timeline=np.asarray(outs.f_max))


def _targets(cfg: SimConfig, do_warmup: bool) -> Tuple[float, float]:
    if do_warmup and policy_lib.get_class(cfg.policy).adaptive:
        return warmup(cfg)
    return 0.15, 5.0 * cfg.service_ms


def simulate(cfg: SimConfig, wl: Workload,
             do_warmup: bool = True) -> SimResult:
    b_tgt, p99_tgt = _targets(cfg, do_warmup)
    state = init_state(cfg, b_tgt, p99_tgt)
    final, outs = _run_scan(cfg, state, wl.keys, wl.mask, wl.is_write)
    return _to_result(cfg, outs, _final_cache(cfg, final))


# per-seed rows for one (policy, workload) combo
SweepRows = Tuple[SimResult, ...]


def simulate_sweep(cfg: SimConfig, wl: Union[Workload, Sequence[Workload]],
                   policies: Optional[Tuple[str, ...]] = None,
                   seeds: Tuple[int, ...] = (0,),
                   do_warmup: bool = True,
                   ) -> Union[Dict[str, SweepRows],
                              Dict[str, Dict[str, SweepRows]]]:
    """Batched simulation: fan-out over ``policies × workloads × seeds``.

    ``wl`` is a single :class:`Workload` or a sequence of them (same grid
    shape, e.g. built under one set of ``make_workload`` params).  For each
    policy the scan is traced and compiled exactly once: seeds *and*
    workload grids are batched onto a leading ``vmap`` axis — the grids
    ride along as scan inputs, so sweeping the whole scenario registry
    costs one compile per policy (per-seed/per-workload ``simulate`` calls
    would each retrace, since ``cfg.seed`` is static).

    Returns ``{policy: (SimResult per seed, ...)}`` for a single workload
    (the legacy shape) and ``{policy: {workload_name: (SimResult per seed,
    ...)}}`` for a sequence; per-combo results match individual
    ``simulate`` runs.
    """
    single = isinstance(wl, Workload)
    wls: Tuple[Workload, ...] = (wl,) if single else tuple(wl)
    if not wls:
        raise ValueError("simulate_sweep needs at least one workload")
    shapes = {w.keys.shape for w in wls}
    if len(shapes) > 1:
        raise ValueError(f"simulate_sweep workloads must share one grid "
                         f"shape; got {sorted(shapes)}")
    wl_names = [w.name for w in wls]
    if len(set(wl_names)) != len(wl_names):
        raise ValueError(f"simulate_sweep workload names must be unique; "
                         f"got {wl_names}")
    names = tuple(policies) if policies is not None else (cfg.policy,)
    seeds = tuple(seeds)
    if not seeds:
        raise ValueError("simulate_sweep needs at least one seed")
    S, W = len(seeds), len(wls)
    # grids batched workload-major: combo b = i_wl * S + i_seed
    keys = jnp.repeat(jnp.stack([w.keys for w in wls]), S, axis=0)
    mask = jnp.repeat(jnp.stack([w.mask for w in wls]), S, axis=0)
    is_write = jnp.repeat(jnp.stack([w.is_write for w in wls]), S, axis=0)
    results: Dict[str, dict] = {}
    for name in names:
        pcfg = dataclasses.replace(cfg, policy=name)
        b_tgt, p99_tgt = _targets(pcfg, do_warmup)
        per_seed = [init_state(dataclasses.replace(pcfg, seed=s),
                               b_tgt, p99_tgt) for s in seeds]
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                         *per_seed)
        states = jax.tree_util.tree_map(
            lambda x: jnp.tile(x, (W,) + (1,) * (x.ndim - 1)), stacked)
        final, outs = _run_scan_sweep(pcfg, states, keys, mask, is_write)
        per_wl: Dict[str, Tuple[SimResult, ...]] = {}
        for j, w in enumerate(wls):
            rows = []
            for i, s in enumerate(seeds):
                b = j * S + i
                outs_b = jax.tree_util.tree_map(lambda x: x[b], outs)
                final_b = jax.tree_util.tree_map(lambda x: x[b], final)
                rows.append(_to_result(dataclasses.replace(pcfg, seed=s),
                                       outs_b, _final_cache(pcfg, final_b)))
            per_wl[w.name] = tuple(rows)
        results[name] = per_wl[wls[0].name] if single else per_wl
    return results
