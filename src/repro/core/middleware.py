"""Middleware pipeline: composable batch stages in front of routing.

The paper frames MIDAS as *middleware* — stages that sit between incoming
metadata requests and the routing decision.  Each stage sees the tick's
request batch, may absorb requests (serve them at the proxy) by clearing
their mask bits, and carries its own state through the scan.  Stages also
get a slow-loop hook on the paper's T_slow cadence.

``SimConfig.middleware`` is a tuple of registered stage names applied in
order; the cooperative cache is the first (and reference) stage.  Writing
a new stage — admission control, QoS throttling (PADLL-style), in-network
caching (Fletch-style) — means subclassing :class:`Middleware`,
registering it, and naming it in the config; the simulator core never
changes.

Scan contract (DESIGN.md §9): ``on_batch``/``on_slow`` execute inside the
engine's jitted tick scan, so a stage's state must keep a stable pytree
structure (same leaves, shapes, dtypes) across calls, and per-tick Python
side effects will only run at trace time.  The stage loop itself is
Python-unrolled — pipelines are short and heterogeneous — but each
stage's body is traced once per compile, independent of the horizon.

    from repro.core import middleware

    @middleware.register("drop_writes")
    class DropWrites(middleware.Middleware):
        def on_batch(self, state, batch, cfg):
            keep = batch.mask & ~batch.is_write
            absorbed = jnp.sum(batch.mask & batch.is_write)
            return state, keep, absorbed.astype(jnp.float32)

    SimConfig(middleware=("drop_writes", "cache"))
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple, Type

import jax.numpy as jnp

from repro.core import cache as cache_lib
from repro.core import fleet as fleet_lib
from repro.core import registry as registry_lib
from repro.core.controllers.base import T_SLOW_MS, Knobs


class BatchView(NamedTuple):
    """One tick's request batch, as seen by a middleware stage."""

    keys: jnp.ndarray      # (R,) int32 namespace keys
    mask: jnp.ndarray      # (R,) bool validity (may be narrowed upstream)
    is_write: jnp.ndarray  # (R,) bool metadata-mutating ops
    now_ms: jnp.ndarray    # () float32 tick clock
    rng: jnp.ndarray       # per-stage PRNG key
    # fault context (faults.FaultTickInfo) or None when the run carries
    # no fault schedule — stages read availability / partition state
    # from here; None keeps the zero-fault path untouched
    faults: Any = None


class Middleware:
    """Base class for registered pipeline stages.

    ``init(cfg) -> state`` builds the stage's carried pytree.
    ``on_batch(state, batch, cfg) -> (state, mask, absorbed)`` processes
    one tick: the returned mask replaces ``batch.mask`` for downstream
    stages and routing; ``absorbed`` is the () float32 count of requests
    served at the proxy.  ``on_slow(state, cfg, knobs) -> state`` runs
    on the T_slow cadence; ``knobs`` is the configured controller's
    emitted :class:`repro.core.controllers.Knobs` bundle (the cache
    stages consume ``knobs.ttl_scale``).
    """

    name: str = "?"

    def init(self, cfg) -> Any:
        return ()

    def on_batch(
        self, state: Any, batch: BatchView, cfg
    ) -> Tuple[Any, jnp.ndarray, jnp.ndarray]:
        return state, batch.mask, jnp.zeros((), jnp.float32)

    def on_slow(self, state: Any, cfg, knobs: Knobs) -> Any:
        return state

    def on_fault(self, state: Any, info, cfg) -> Any:
        """React to this tick's fault context (``info`` is a
        ``faults.FaultTickInfo``), called BEFORE ``on_batch`` so remap
        invalidation lands before any request of the new epoch is
        served.  Runs inside the jitted scan only when the config
        carries a membership-changing fault schedule; default: no-op.
        """
        return state


REGISTRY = registry_lib.Registry("middleware")


def register(name: str):
    """Class decorator registering a Middleware stage under ``name``."""
    return REGISTRY.register(name)


def unregister(name: str) -> None:
    REGISTRY.unregister(name)


def available() -> Tuple[str, ...]:
    return REGISTRY.available()


def get_class(name: str) -> Type[Middleware]:
    return REGISTRY.get_class(name)


def get(name: str) -> Middleware:
    return REGISTRY.get(name)


@register("cache")
class CooperativeCache(Middleware):
    """The paper's cooperative metadata cache as a pipeline stage.

    Read hits within the validity horizon are absorbed at the proxy;
    writes always pass through (bumping versions / invalidating leases).
    The slow hook retunes the aggregate TTL from the invalidation-hazard
    estimator.  Coherence semantics live unchanged in
    :mod:`repro.core.cache`.
    """

    def init(self, cfg) -> cache_lib.CacheState:
        return cache_lib.init_cache(cfg.N)

    def on_batch(self, state: cache_lib.CacheState, batch: BatchView, cfg):
        fi = batch.faults
        state, hit = cache_lib.lookup_batch(
            state,
            batch.keys,
            batch.mask,
            batch.is_write,
            batch.now_ms,
            mode=cfg.cache_mode,
            lease_ms=cfg.lease_ms,
            rtt_ms=cfg.rtt_ms,
            p_star=cfg.p_star,
            avail=None if fi is None else fi.avail,
        )
        # hits never reach the servers
        return state, batch.mask & ~hit, jnp.sum(hit).astype(jnp.float32)

    def on_fault(self, state: cache_lib.CacheState, info, cfg):
        if info.inval is None:
            return state
        return cache_lib.remap_invalidate(state, info.inval)

    def on_slow(self, state: cache_lib.CacheState, cfg, knobs: Knobs):
        lease = cfg.lease_ms if cfg.cache_mode == "lease" else jnp.inf
        return cache_lib.slow_update(
            state,
            T_SLOW_MS,
            cfg.rtt_ms,
            lease,
            cfg.p_star,
            ttl_scale=knobs.ttl_scale,
        )


@register("fleet_cache")
class FleetCache(Middleware):
    """The cooperative cache as ``cfg.P`` real proxies with gossip.

    Requests are sharded across the fleet per tick (slot r → proxy
    (r+tick)%P); each proxy decides hits against its own gossip-delayed
    view (``cfg.gossip_ms`` propagation, see :mod:`repro.core.fleet`),
    while effects land on the converged table.  At ``gossip_ms=0`` this
    stage reproduces ``"cache"`` bit-for-bit — the Δ=0 equivalence
    contract.
    """

    def init(self, cfg) -> fleet_lib.FleetState:
        D = fleet_lib.delay_ticks(cfg.gossip_ms, cfg.dt_ms)
        return fleet_lib.init_fleet(cfg.N, cfg.P, D)

    def on_batch(self, state: fleet_lib.FleetState, batch: BatchView, cfg):
        R = batch.keys.shape[0]
        proxy = fleet_lib.proxy_assign(R, cfg.P, state.tick)
        fi = batch.faults
        state, hit = fleet_lib.lookup_fleet(
            state,
            batch.keys,
            batch.mask,
            batch.is_write,
            proxy,
            batch.now_ms,
            mode=cfg.cache_mode,
            lease_ms=cfg.lease_ms,
            rtt_ms=cfg.rtt_ms,
            p_star=cfg.p_star,
            gossip_ms=cfg.gossip_ms,
            partitioned=None if fi is None else fi.partition,
            avail=None if fi is None else fi.avail,
        )
        # hits are served by their proxy and never reach the servers
        return state, batch.mask & ~hit, jnp.sum(hit).astype(jnp.float32)

    def on_fault(self, state: fleet_lib.FleetState, info, cfg):
        if info.inval is None:
            return state
        return fleet_lib.remap_invalidate(state, info.inval)

    def on_slow(self, state: fleet_lib.FleetState, cfg, knobs: Knobs):
        lease = cfg.lease_ms if cfg.cache_mode == "lease" else jnp.inf
        return fleet_lib.slow_fleet(
            state,
            T_SLOW_MS,
            cfg.rtt_ms,
            lease,
            cfg.p_star,
            ttl_scale=knobs.ttl_scale,
        )
