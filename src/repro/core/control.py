"""The MIDAS self-stabilizing control plane (paper §IV-E, Algorithm 1).

Fast loop (every T_fast=250 ms): ingest telemetry, smooth with EWMA α=0.2,
compute imbalance B and pressure
    P = w1·[B − B_tgt]₊ + w2·[p̃99 − P99_tgt]₊,
and under hysteresis (H↓=0.02 < H↑=0.10, K↑=3, K↓=8) move knobs in single
bounded steps:  d ∈ {1..4},  Δ_L ∈ [Δ_L^min=2, Δ_L^max=8].

Slow loop (every T_slow=30 s): retune per-class cache TTLs from the
invalidation-hazard estimate (see cache.py).

Targets come from a low-utilization warmup (§III-B):
    B_tgt   = median_t B(t) + 0.05
    P99_tgt = max(1.25 · p99_warm, RTT + 2 ms)
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

# Paper defaults (Algorithm 1 lines 1–20)
T_FAST_MS = 250.0
T_SLOW_MS = 30_000.0
D_INIT, D_MIN, D_MAX = 2, 1, 4
DELTA_L_INIT, DELTA_L_MIN, DELTA_L_MAX = 4.0, 2.0, 8.0
H_DOWN, H_UP = 0.02, 0.10
K_UP, K_DOWN = 3, 8
F_CAP = 0.10
F_MAX_HIGH = 1.0
W_WINDOW_MS = 1000.0
PIN_C_MS = 300.0
W1, W2 = 1.0, 1.0
EPS = 1e-6
ALPHA_FAST = 0.2
BETA_SLOW = 0.1


class ControlState(NamedTuple):
    d: jnp.ndarray            # () int32 in {1..4}
    delta_l: jnp.ndarray      # () float32 in [2, 8]
    delta_t: jnp.ndarray      # () float32 ms latency margin
    f_max: jnp.ndarray        # () float32 steering cap
    above_cnt: jnp.ndarray    # () int32 consecutive P > H_up
    below_cnt: jnp.ndarray    # () int32 consecutive P < H_down
    b_tgt: jnp.ndarray        # () float32
    p99_tgt: jnp.ndarray      # () float32 ms
    pressure: jnp.ndarray     # () float32 (last computed, for logging)


def init_control(rtt_ms: float, b_tgt: float = 0.15,
                 p99_tgt: float = 500.0) -> ControlState:
    return ControlState(
        d=jnp.asarray(D_INIT, jnp.int32),
        delta_l=jnp.asarray(DELTA_L_INIT, jnp.float32),
        delta_t=jnp.asarray(rtt_ms, jnp.float32),
        f_max=jnp.asarray(F_CAP, jnp.float32),
        above_cnt=jnp.zeros((), jnp.int32),
        below_cnt=jnp.zeros((), jnp.int32),
        b_tgt=jnp.asarray(b_tgt, jnp.float32),
        p99_tgt=jnp.asarray(p99_tgt, jnp.float32),
        pressure=jnp.zeros((), jnp.float32),
    )


def consensus_view(views_p: jnp.ndarray) -> jnp.ndarray:
    """Collapse (P, m) per-proxy telemetry views into the single view the
    one control loop consumes (fleet mode).  The paper runs one logical
    controller over P proxies' reports; the mean is its consensus — each
    proxy's staleness phase shifts the aggregate, it does not fork the
    loop."""
    return jnp.mean(views_p, axis=0)


def warmup_targets(B_series: jnp.ndarray, p99_warm: jnp.ndarray,
                   rtt_ms: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """§III-B target selection from the warmup window."""
    b_tgt = jnp.median(B_series) + 0.05
    p99_tgt = jnp.maximum(p99_warm * 1.25, rtt_ms + 2.0)
    return b_tgt, p99_tgt


def pressure_score(B: jnp.ndarray, p99: jnp.ndarray,
                   ctrl: ControlState) -> jnp.ndarray:
    relu = lambda z: jnp.maximum(z, 0.0)
    # p99 pressure normalized by target so both terms are O(1)
    return (W1 * relu(B - ctrl.b_tgt)
            + W2 * relu((p99 - ctrl.p99_tgt) / jnp.maximum(ctrl.p99_tgt, EPS)))


def fast_update(ctrl: ControlState, B: jnp.ndarray, p99: jnp.ndarray,
                rtt_ms: float, jitter: jnp.ndarray) -> ControlState:
    """One fast-loop knob update (Alg. 1 lines 26–35).

    ``jitter`` is uniform in [-1, 1]; applied as ±0.1·RTT on Δ_t to avoid
    lockstep moves across proxies.

    The steering bucket cap ``f_max`` moves with the same hysteresis as
    d/Δ_L: a bounded multiplicative step (×2 up, ×½ down) inside
    [F_CAP, F_MAX_HIGH].  A fixed cap deadlocks under write-hot storms —
    writes are uncacheable, so when mutations dominate, the only relief
    valve is steering, and pinning 90% of hot-key traffic to its primary
    (f_max = 0.10 forever) is exactly the E8 rename_storm collapse.  Under
    calm load K_DOWN shrinks the cap back, restoring the paper's 10%
    churn bound.
    """
    P = pressure_score(B, p99, ctrl)
    above = jnp.where(P > H_UP, ctrl.above_cnt + 1, 0)
    below = jnp.where(P < H_DOWN, ctrl.below_cnt + 1, 0)

    go_up = above >= K_UP
    go_down = below >= K_DOWN

    d = jnp.where(go_up, jnp.minimum(ctrl.d + 1, D_MAX),
                  jnp.where(go_down, jnp.maximum(ctrl.d - 1, D_MIN), ctrl.d))
    delta_l = jnp.where(
        go_up, jnp.maximum(ctrl.delta_l - 1.0, DELTA_L_MIN),
        jnp.where(go_down, jnp.minimum(ctrl.delta_l + 1.0, DELTA_L_MAX),
                  ctrl.delta_l))
    f_max = jnp.where(
        go_up, jnp.minimum(ctrl.f_max * 2.0, F_MAX_HIGH),
        jnp.where(go_down, jnp.maximum(ctrl.f_max * 0.5, F_CAP),
                  ctrl.f_max))
    # reset the counter that fired
    above = jnp.where(go_up, 0, above)
    below = jnp.where(go_down, 0, below)

    delta_t = jnp.asarray(rtt_ms, jnp.float32) + 0.1 * rtt_ms * jitter

    return ctrl._replace(d=d, delta_l=delta_l, delta_t=delta_t, f_max=f_max,
                         above_cnt=above, below_cnt=below, pressure=P)


def lyapunov_delta_v(L: jnp.ndarray, p: jnp.ndarray,
                     j: jnp.ndarray) -> jnp.ndarray:
    """ΔV for moving one request p→j:  2(L̂_j − L̂_p) + 2  (paper eq. 2)."""
    return 2.0 * (L[j] - L[p]) + 2.0


def lyapunov_potential(L: jnp.ndarray) -> jnp.ndarray:
    """V(L̂) = Σ_i (L̂_i − L̄)²."""
    return jnp.sum((L - jnp.mean(L)) ** 2)
