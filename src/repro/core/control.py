"""Migration shim — the control plane now lives in ``repro.core.controllers``.

The §IV-E fast/slow control loop used to be this module: a monolithic
hysteresis update with module-level constants and an ad-hoc
``ControlState`` that sim.py, the policies, and the cache all reached
into.  PR 5 refactored it into the controller registry
(``repro.core.controllers``): a ``Controller`` protocol with a typed
``Knobs``/``KnobSpec`` schema and ``Signals`` telemetry bundle, the
paper's hysteresis law migrated verbatim as the reference
implementation (``controllers/hysteresis.py``), and ``aimd`` /
``deadband_pid`` / ``static`` registered alongside it.

Everything historical is re-exported here unchanged — constants, the
legacy flat ``ControlState``, ``init_control`` / ``fast_update`` (thin
adapters over the registered hysteresis controller), the pressure /
warmup / consensus / Lyapunov helpers — so pre-PR5 call sites keep
working bit-for-bit.  New code should import from
``repro.core.controllers`` directly.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.controllers import base as _base
from repro.core.controllers import hysteresis as _hyst
from repro.core.controllers.base import (  # noqa: F401
    ALPHA_FAST,
    BETA_SLOW,
    D_INIT,
    D_MAX,
    D_MIN,
    DELTA_L_INIT,
    DELTA_L_MAX,
    DELTA_L_MIN,
    EPS,
    F_CAP,
    F_MAX_HIGH,
    PIN_C_MS,
    T_FAST_MS,
    T_SLOW_MS,
    W_WINDOW_MS,
    W1,
    W2,
    lyapunov_delta_v,
    lyapunov_potential,
    warmup_targets,
)
from repro.core.controllers.hysteresis import (  # noqa: F401
    H_DOWN,
    H_UP,
    K_DOWN,
    K_UP,
)


class ControlState(NamedTuple):
    """Legacy flat control state (pre-registry layout)."""

    d: jnp.ndarray  # () int32 in {1..4}
    delta_l: jnp.ndarray  # () float32 in [2, 8]
    delta_t: jnp.ndarray  # () float32 ms latency margin
    f_max: jnp.ndarray  # () float32 steering cap
    above_cnt: jnp.ndarray  # () int32 consecutive P > H_up
    below_cnt: jnp.ndarray  # () int32 consecutive P < H_down
    b_tgt: jnp.ndarray  # () float32
    p99_tgt: jnp.ndarray  # () float32 ms
    pressure: jnp.ndarray  # () float32 (last computed, for logging)


def _to_registry(ctrl: ControlState) -> _base.ControlState:
    """Legacy flat layout -> registry ControlState (hysteresis inner)."""
    knobs = _base.init_knobs(0.0)._replace(
        d=ctrl.d,
        delta_l=ctrl.delta_l,
        delta_t=ctrl.delta_t,
        f_max=ctrl.f_max,
    )
    return _base.ControlState(
        knobs=knobs,
        b_tgt=ctrl.b_tgt,
        p99_tgt=ctrl.p99_tgt,
        pressure=ctrl.pressure,
        inner=_hyst.HysteresisInner(
            above_cnt=ctrl.above_cnt, below_cnt=ctrl.below_cnt
        ),
    )


def _from_registry(st: _base.ControlState) -> ControlState:
    k = st.knobs
    return ControlState(
        d=k.d,
        delta_l=k.delta_l,
        delta_t=k.delta_t,
        f_max=k.f_max,
        above_cnt=st.inner.above_cnt,
        below_cnt=st.inner.below_cnt,
        b_tgt=st.b_tgt,
        p99_tgt=st.p99_tgt,
        pressure=st.pressure,
    )


def init_control(
    rtt_ms: float, b_tgt: float = 0.15, p99_tgt: float = 500.0
) -> ControlState:
    return ControlState(
        d=jnp.asarray(D_INIT, jnp.int32),
        delta_l=jnp.asarray(DELTA_L_INIT, jnp.float32),
        delta_t=jnp.asarray(rtt_ms, jnp.float32),
        f_max=jnp.asarray(F_CAP, jnp.float32),
        above_cnt=jnp.zeros((), jnp.int32),
        below_cnt=jnp.zeros((), jnp.int32),
        b_tgt=jnp.asarray(b_tgt, jnp.float32),
        p99_tgt=jnp.asarray(p99_tgt, jnp.float32),
        pressure=jnp.zeros((), jnp.float32),
    )


def consensus_view(
    views_p: jnp.ndarray, reducer: str = "mean"
) -> jnp.ndarray:
    """See :func:`repro.core.controllers.consensus_view` (now reducer-
    configurable via ``SimConfig.consensus``)."""
    return _base.consensus_view(views_p, reducer)


def pressure_score(
    B: jnp.ndarray, p99: jnp.ndarray, ctrl: ControlState
) -> jnp.ndarray:
    return _base.pressure_score(B, p99, ctrl.b_tgt, ctrl.p99_tgt)


def fast_update(
    ctrl: ControlState,
    B: jnp.ndarray,
    p99: jnp.ndarray,
    rtt_ms: float,
    jitter: jnp.ndarray,
) -> ControlState:
    """One fast-loop knob update (Alg. 1 lines 26-35) — delegates to the
    registered ``hysteresis`` controller on the legacy flat state."""
    sig = _base.make_signals(B=B, p99=p99, jitter=jitter, rtt_ms=rtt_ms)
    st, _ = _hyst.Hysteresis().fast(_to_registry(ctrl), sig)
    return _from_registry(st)
