# The paper's primary contribution: adaptive proxy middleware for metadata
# hotspot mitigation — namespace-aware power-of-d routing over consistent
# hashing, cooperative caching with leases/adaptive TTLs, and a
# self-stabilizing control loop.  All components are pure-JAX and reused by
# the framework layers (MoE dispatch, checkpoint writers, serving router).
#
# Routing policies live in the pluggable registry (repro.core.policies);
# batch stages such as the cooperative cache compose via the middleware
# pipeline (repro.core.middleware); control-plane implementations live
# in the controller registry (repro.core.controllers — control.py is the
# pre-PR5 migration shim); fault events compile into scan-borne
# schedules via the fault registry (repro.core.faults).  See DESIGN.md.
from repro.core import (cache, control, controllers,  # noqa: F401
                        faults, fleet, hashring, middleware, policies,
                        registry, routing, sim, sweep, telemetry, theory,
                        workloads)
from repro.core.faults import FaultEvent  # noqa: F401
from repro.core.sim import (SimConfig, SimResult,  # noqa: F401
                            SummaryResult, simulate, simulate_sweep,
                            summarize)
from repro.core.sweep import (SweepResult, SweepSpec,  # noqa: F401
                              run_sweep)
from repro.core.workloads import WORKLOADS, make_workload  # noqa: F401
