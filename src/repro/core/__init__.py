# The paper's primary contribution: adaptive proxy middleware for metadata
# hotspot mitigation — namespace-aware power-of-d routing over consistent
# hashing, cooperative caching with leases/adaptive TTLs, and a
# self-stabilizing control loop.  All components are pure-JAX and reused by
# the framework layers (MoE dispatch, checkpoint writers, serving router).
from repro.core import cache, control, hashring, routing, sim, telemetry, theory, workloads  # noqa: F401
from repro.core.sim import SimConfig, SimResult, simulate  # noqa: F401
from repro.core.workloads import WORKLOADS, make_workload  # noqa: F401
