"""Declarative sweep engine: ``SweepSpec`` -> :func:`run_sweep` ->
``SweepResult``, with optional multi-device sharding.

``simulate_sweep`` accreted positional grids and a legacy
single-workload return shape; this module is its redesign.  A sweep is
now DATA — one frozen :class:`SweepSpec` naming the (policy ×
controller × workload × seed) grid, the metrics mode, the fault
schedule, and the device mesh — validated eagerly at construction with
the same list-alternatives errors as ``SimConfig`` (shared
``repro.core.registry`` helpers).  :func:`run_sweep` executes it and
returns a :class:`SweepResult` addressable by grid coordinates instead
of nested dicts.  The old ``simulate_sweep`` survives as a deprecation
shim on top of this module.

Sharding (DESIGN.md §12).  ``SweepSpec(devices=n)`` partitions the SEED
axis of each (policy, controller) batch over an n-device mesh with
``shard_map`` (the ``jax.experimental.shard_map`` compat split mirrors
``repro.models.moe``): workload grids are replicated (``P()`` — they are
seed-independent, and the per-workload feasible-set gather stays one
batched call *per device*, never O(cells)), while every leaf of the
stacked ``SimState`` is split on its leading seed axis.  Each device
runs the IDENTICAL nested-vmap body as the single-device path
(``sim._sweep_vmapped``), so sharded results are bit-for-bit the
single-device vmap results — tested under
``--xla_force_host_platform_device_count=8`` for both metrics modes.
Seeds that don't divide ``devices`` are padded with repeats of the last
state and the padded rows dropped on host.

Memory stays flat in the namespace size R (paper scale: R ≈ 10⁶ keys,
P in the hundreds of proxies): nothing materializes O(R·P) — the ring
is O(m·V), the gather output O(T·R_slots·d_max), and per-key state
(pins, cache tables) O(R) per cell at 4–8 bytes/key.  E11
(``benchmarks/shard_sweep.py``) measures both claims.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Iterator, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import controllers as ctrl_lib
from repro.core import policies as policy_lib
from repro.core import registry as registry_lib
from repro.core import sim
from repro.core.workloads import Workload
from repro.obs import trace as obs_trace

# one realized row of the grid: full timelines or the streaming summary
Row = Union[sim.SimResult, sim.SummaryResult]
# grid coordinates: (policy, controller, workload name, seed)
Coord = Tuple[str, str, str, int]


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """One declarative sweep: the full grid, validated at construction.

    ``workloads`` accepts a single :class:`Workload` or a sequence
    (coerced to a tuple; grids must share one shape and names must be
    unique).  ``policies`` / ``controllers`` default to the config's
    single policy / controller.  ``faults`` overrides ``config.faults``
    when not ``None`` (pass ``()`` to force the zero-fault engine).
    ``devices=1`` is the plain nested-vmap engine; ``devices=n`` shards
    the seed axis over n devices (see module docstring).  ``targets``
    pins the §III-B control targets, skipping the per-policy warmup.
    """

    config: sim.SimConfig
    workloads: Tuple[Workload, ...]
    policies: Optional[Tuple[str, ...]] = None
    controllers: Optional[Tuple[str, ...]] = None
    seeds: Tuple[int, ...] = (0,)
    metrics: str = "full"
    devices: int = 1
    faults: Optional[Tuple] = None
    do_warmup: bool = True
    targets: Optional[Tuple[float, float]] = None

    def __post_init__(self):
        # -- workload grid ------------------------------------------------
        wls = (
            (self.workloads,)
            if isinstance(self.workloads, Workload)
            else tuple(self.workloads)
        )
        object.__setattr__(self, "workloads", wls)
        if not wls:
            raise ValueError("SweepSpec needs at least one workload")
        shapes = {w.keys.shape for w in wls}
        if len(shapes) > 1:
            raise ValueError(
                f"SweepSpec workloads must share one grid "
                f"shape; got {sorted(shapes)}"
            )
        names = [w.name for w in wls]
        if len(set(names)) != len(names):
            raise ValueError(
                f"SweepSpec workload names must be unique; got {names}"
            )
        # -- policy / controller axes (registry-validated) ----------------
        pols = (
            (self.config.policy,)
            if self.policies is None
            else tuple(self.policies)
        )
        for p in pols:
            policy_lib.get_class(p)  # raises with alternatives
        object.__setattr__(self, "policies", pols)
        ctrls = (
            (self.config.controller,)
            if self.controllers is None
            else tuple(self.controllers)
        )
        for c in ctrls:
            ctrl_lib.get_class(c)
        object.__setattr__(self, "controllers", ctrls)
        # -- seeds / metrics / mesh ---------------------------------------
        seeds = tuple(int(s) for s in self.seeds)
        object.__setattr__(self, "seeds", seeds)
        if not seeds:
            raise ValueError("SweepSpec needs at least one seed")
        registry_lib.validate_choice(
            self.metrics, "metrics mode", sim.METRICS_MODES
        )
        d = self.devices
        if not isinstance(d, int) or isinstance(d, bool) or d <= 0:
            raise ValueError(
                f"SweepSpec.devices must be a positive int, got {d!r}"
            )
        # -- fault override: folded into the config (and validated by
        #    SimConfig.__post_init__, which canonicalizes the events)
        if self.faults is not None:
            object.__setattr__(
                self,
                "config",
                dataclasses.replace(self.config, faults=self.faults),
            )
        if self.targets is not None:
            b_tgt, p99_tgt = self.targets
            object.__setattr__(self, "targets", (float(b_tgt), float(p99_tgt)))

    # -- grid views -------------------------------------------------------
    @property
    def workload_names(self) -> Tuple[str, ...]:
        return tuple(w.name for w in self.workloads)

    @property
    def n_cells(self) -> int:
        return (
            len(self.policies)
            * len(self.controllers)
            * len(self.workloads)
            * len(self.seeds)
        )

    def coords(self) -> Iterator[Coord]:
        """Grid coordinates in execution order."""
        for p in self.policies:
            for c in self.controllers:
                for w in self.workload_names:
                    for s in self.seeds:
                        yield (p, c, w, s)


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Realized grid: one :class:`Row` per (policy, controller,
    workload, seed) coordinate of the spec."""

    spec: SweepSpec
    cells: Dict[Coord, Row]

    def _pick(self, kind: str, value, options) -> str:
        if value is not None:
            return registry_lib.validate_choice(value, kind, options)
        if len(options) == 1:
            return options[0]
        raise ValueError(
            f"ambiguous {kind}: the sweep has {len(options)} "
            f"({', '.join(str(o) for o in options)}); name one"
        )

    def rows(
        self,
        policy: Optional[str] = None,
        controller: Optional[str] = None,
        workload: Optional[str] = None,
    ) -> Tuple[Row, ...]:
        """Per-seed rows of one grid cell.  Axes with a single value in
        the spec may be omitted; multi-valued axes must be named."""
        p = self._pick("policy", policy, self.spec.policies)
        c = self._pick("controller", controller, self.spec.controllers)
        w = self._pick("workload", workload, self.spec.workload_names)
        return tuple(self.cells[(p, c, w, s)] for s in self.spec.seeds)

    def row(
        self,
        policy: Optional[str] = None,
        controller: Optional[str] = None,
        workload: Optional[str] = None,
        seed: Optional[int] = None,
    ) -> Row:
        """One realized run (seed defaulted when the spec has one)."""
        p = self._pick("policy", policy, self.spec.policies)
        c = self._pick("controller", controller, self.spec.controllers)
        w = self._pick("workload", workload, self.spec.workload_names)
        s = self._pick("seed", seed, self.spec.seeds)
        return self.cells[(p, c, w, s)]

    def items(self):
        """((policy, controller, workload, seed), row) pairs."""
        return self.cells.items()

    def to_legacy(self, single: bool):
        """The pre-SweepSpec ``simulate_sweep`` return shapes:
        ``{policy: rows}`` for a single workload, ``{policy:
        {workload: rows}}`` otherwise.  Requires a single-controller
        spec (the legacy API had no controller axis)."""
        if len(self.spec.controllers) != 1:
            raise ValueError(
                "legacy sweep shape has no controller axis; the spec "
                f"names {len(self.spec.controllers)} controllers"
            )
        (ctrl,) = self.spec.controllers
        out: Dict[str, dict] = {}
        for p in self.spec.policies:
            per_wl = {
                w: self.rows(policy=p, controller=ctrl, workload=w)
                for w in self.spec.workload_names
            }
            out[p] = per_wl[self.spec.workload_names[0]] if single else per_wl
        return out


# ---------------------------------------------------------------------------
# Sharded runner (devices > 1)
# ---------------------------------------------------------------------------


def _shard_map(fn, mesh, in_specs, out_specs):
    """PR 3 compat split: ``jax.shard_map`` (>= 0.5, check_vma) vs
    ``jax.experimental.shard_map`` (pre-rename, check_rep) — same idiom
    as ``repro.models.moe``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(
        fn,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=False,
    )


# Trace counter mirroring sim._SWEEP_TRACES: one (re)compile per
# (config, metrics, devices), regardless of #seeds/#workloads.
_SHARD_TRACES = [0]


@functools.partial(jax.jit, static_argnums=(0, 5, 6))
def _run_scan_sweep_sharded(
    cfg: sim.SimConfig,
    states: sim.SimState,
    keys,
    mask,
    is_write,
    metrics: str,
    n_dev: int,
):
    """``sim._run_scan_sweep`` with the seed axis split over ``n_dev``
    devices.  The body each device runs is ``sim._sweep_vmapped`` —
    shared with the single-device jit, which is what makes the parity
    contract bit-for-bit.  Workload grids ride in replicated (they are
    seed-independent); every output leaf is (W, S, ...), so a single
    ``P(None, "dev")`` prefix reassembles the seed axis.
    """
    _SHARD_TRACES[0] += 1
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P

    mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("dev",))

    def body(sts, k, mk, w):
        return sim._sweep_vmapped(cfg, sts, k, mk, w, metrics)

    fn = _shard_map(
        body,
        mesh,
        in_specs=(P("dev"), P(), P(), P()),
        out_specs=P(None, "dev"),
    )
    return fn(states, keys, mask, is_write)


def _check_devices(n_dev: int) -> None:
    have = len(jax.devices())
    if n_dev > have:
        raise ValueError(
            f"SweepSpec.devices={n_dev} but only {have} JAX device(s) "
            f"are visible; on CPU, launch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_dev} "
            f"set BEFORE jax initializes"
        )


def _pad_seed_axis(states, n_seeds: int, n_dev: int):
    """Pad the leading seed axis to a multiple of n_dev by repeating the
    last state; padded rows compute throwaway cells dropped on host."""
    pad = (-n_seeds) % n_dev
    if pad == 0:
        return states, 0
    states = jax.tree_util.tree_map(
        lambda x: jnp.concatenate(
            [x, jnp.repeat(x[-1:], pad, axis=0)], axis=0
        ),
        states,
    )
    return states, pad


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------


def run_sweep(spec: SweepSpec) -> SweepResult:
    """Execute a :class:`SweepSpec`.

    One compiled scan per (policy, controller) — seeds and workloads
    ride vmap axes (sharded over the mesh when ``devices > 1``), and the
    §III-B warmup (when enabled and the policy is adaptive) runs once
    per policy, shared across controllers.  One device transfer per
    (policy, controller) batch, sliced on host into :class:`Row` cells.
    """
    cfg = spec.config
    if spec.devices > 1:
        _check_devices(spec.devices)
    wls = spec.workloads
    # (W, T, R) grids — shared across the seed axis, never duplicated
    keys = jnp.stack([w.keys for w in wls])
    mask = jnp.stack([w.mask for w in wls])
    is_write = jnp.stack([w.is_write for w in wls])
    targets_by_policy: Dict[str, Tuple[float, float]] = {}
    cells: Dict[Coord, Row] = {}
    for pname in spec.policies:
        for cname in spec.controllers:
            pcfg = dataclasses.replace(cfg, policy=pname, controller=cname)
            if spec.targets is not None:
                b_tgt, p99_tgt = spec.targets
            else:
                # warmup is policy- and controller-independent (it runs
                # the bare "hash" policy): one pass per policy, shared
                # across the controller axis
                if pname not in targets_by_policy:
                    with obs_trace.span(
                        "sweep/warmup", cat="warmup", policy=pname
                    ):
                        targets_by_policy[pname] = sim._targets(
                            pcfg, spec.do_warmup
                        )
                b_tgt, p99_tgt = targets_by_policy[pname]
            with obs_trace.span(
                "sweep/init_states",
                cat="host",
                policy=pname,
                controller=cname,
                seeds=len(spec.seeds),
            ):
                per_seed = [
                    sim.init_state(
                        dataclasses.replace(pcfg, seed=s), b_tgt, p99_tgt
                    )
                    for s in spec.seeds
                ]
                states = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *per_seed
                )
            traces0 = sim._SWEEP_TRACES[0] + _SHARD_TRACES[0]
            with obs_trace.span(
                "sweep/execute",
                cat="execute",
                policy=pname,
                controller=cname,
                metrics=spec.metrics,
                devices=spec.devices,
                workloads=len(wls),
                seeds=len(spec.seeds),
            ) as sp:
                if spec.devices > 1:
                    states, pad = _pad_seed_axis(
                        states, len(spec.seeds), spec.devices
                    )
                    final, outs = _run_scan_sweep_sharded(
                        pcfg,
                        states,
                        keys,
                        mask,
                        is_write,
                        spec.metrics,
                        spec.devices,
                    )
                else:
                    pad = 0
                    final, outs = sim._run_scan_sweep(
                        pcfg, states, keys, mask, is_write, spec.metrics
                    )
                # one transfer for the whole batch, sliced on host
                outs = jax.device_get(outs)
                if spec.metrics == "full":
                    final = jax.device_get(final)
                sp["compiled"] = (
                    sim._SWEEP_TRACES[0] + _SHARD_TRACES[0] > traces0
                )
            del pad  # padded rows simply never get sliced below
            with obs_trace.span(
                "sweep/host_slice",
                cat="host",
                policy=pname,
                controller=cname,
                cells=len(wls) * len(spec.seeds),
            ):
                for j, w in enumerate(wls):
                    for i, s in enumerate(spec.seeds):
                        scfg = dataclasses.replace(pcfg, seed=s)
                        row = jax.tree_util.tree_map(lambda x: x[j, i], outs)
                        if spec.metrics == "summary":
                            # row is the (SummaryAcc, KnobTrace) pair
                            cells[(pname, cname, w.name, s)] = sim._to_summary(
                                scfg, *row
                            )
                        else:
                            final_b = jax.tree_util.tree_map(
                                lambda x: x[j, i], final
                            )
                            cells[(pname, cname, w.name, s)] = (
                                sim._to_result(
                                    scfg,
                                    row,
                                    sim._final_cache(pcfg, final_b),
                                )
                            )
    return SweepResult(spec=spec, cells=cells)
