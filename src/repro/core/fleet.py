"""Proxy fleet: P real proxies with gossip-delayed cache coherence.

The shared-table model in :mod:`repro.core.cache` is the Δ=0 gossip limit
of the paper's cooperative cache — every entry announcement and write
invalidation is instantly visible to all P proxies, so one converged
table suffices.  This module drops that assumption: requests are sharded
across ``P`` proxies per tick, and each proxy serves from *its own view*
of the table, where remote events (installs and invalidations gossiped by
other proxies, §IV-C) only become visible ``gossip_ms`` after they
happen.

Representation.  Rather than materializing P physical tables (O(P·N)
state whose Δ=0 merge would have to reproduce the shared scatter order
exactly), the fleet keeps

  * ``shared``      — the converged table, updated every tick by exactly
                      the shared model's
                      :func:`repro.core.cache.apply_batch` (so the
                      eventual state *is* the shared model's state);
  * ``last_event_ms`` / ``last_origin``
                    — per-key gossip log: when the most recent install
                      or invalidation happened, and which proxy
                      originated it;
  * ``lag_expiry`` / ``lag_version``
                    — a (D, N) ring buffer of converged-table snapshots,
                      D = ceil(gossip_ms / dt_ms) ticks deep.

Proxy p's view of key k is the *fresh* converged entry iff p originated
the last event on k or that event is at least ``gossip_ms`` old;
otherwise p sees the *lagged* snapshot from D ticks ago — i.e. the table
as it was before any not-yet-propagated event.  With
D = ceil(gossip_ms/dt_ms) the two visibility tests agree exactly: an
event from tick t − j is time-visible (age j·dt ≥ gossip_ms) iff j ≥ D,
which is precisely when it is contained in the snapshot.  Multiple
events on one key inside the gossip window collapse to last-event-wins —
a documented approximation (a proxy can lose sight of its own install if
another proxy re-announced the key meanwhile); interleavings finer than
dt are not modeled.

Equivalence contract (tested property): at ``gossip_ms=0`` every event
is immediately visible, each view equals the converged table, and the
fleet reproduces the shared-table model bit-for-bit — same
hit/miss/stale/bypass counters and same table trajectory — for any P,
across all coherence modes.  Staleness is accounted omnisciently against
the authoritative ``global_version`` (the server's), which gossip never
lags: with Δ>0 a proxy can serve an entry another proxy's write already
invalidated, and that is exactly the stale-serve rate E9 measures.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple, Union

import jax.numpy as jnp

from repro.core import cache as cache_lib


class FleetState(NamedTuple):
    """Carried scan state of the proxy fleet (one pytree)."""

    shared: cache_lib.CacheState  # converged table + aggregate counters
    tick: jnp.ndarray             # () int32 fleet-local tick counter
    last_event_ms: jnp.ndarray    # (N,) float32 time of last gossip event
    last_origin: jnp.ndarray      # (N,) int32 proxy that originated it
    lag_expiry: jnp.ndarray       # (D, N) float32 snapshot ring buffer
    lag_version: jnp.ndarray      # (D, N) int32 snapshot ring buffer
    hits_p: jnp.ndarray           # (P,) int32 per-proxy hits
    misses_p: jnp.ndarray         # (P,) int32 per-proxy misses
    stale_p: jnp.ndarray          # (P,) int32 per-proxy stale serves
    bypasses_p: jnp.ndarray       # (P,) int32 per-proxy guard bypasses

    # Aggregate counters mirror the shared-table model bit-for-bit; the
    # per-proxy vectors expose the divergence the shared model hides.
    @property
    def hits(self) -> jnp.ndarray:
        return self.shared.hits

    @property
    def misses(self) -> jnp.ndarray:
        return self.shared.misses

    @property
    def stale_serves(self) -> jnp.ndarray:
        return self.shared.stale_serves

    @property
    def bypasses(self) -> jnp.ndarray:
        return self.shared.bypasses


def delay_ticks(gossip_ms: float, dt_ms: float) -> int:
    """Gossip delay in whole ticks; the ring buffer depth (static, >=1)."""
    if gossip_ms < 0:
        raise ValueError(f"gossip_ms must be >= 0, got {gossip_ms}")
    return max(int(math.ceil(gossip_ms / dt_ms)), 1)


def proxy_assign(
    R: int, P: int, tick: Union[jnp.ndarray, int] = 0
) -> jnp.ndarray:
    """Shard request slots across proxies: slot r → proxy (r + tick) % P.

    Workload grids fill slots as a masked prefix, so the modulo spreads
    each tick's live requests across the fleet, and the tick rotation
    decorrelates slot rank from proxy over time — the paper's
    client-pinned proxies with no key affinity.  At Δ=0 the assignment
    is immaterial to cache results (every proxy shares one view), so the
    equivalence contract does not depend on this choice.
    """
    tick = jnp.asarray(tick, jnp.int32)
    return ((jnp.arange(R, dtype=jnp.int32) + tick) % P).astype(jnp.int32)


def wave_views(L_hat_p: jnp.ndarray, tick: jnp.ndarray) -> jnp.ndarray:
    """(P, m) telemetry views reordered so row g is the view of the
    proxy serving routing wave g this tick — proxy (g + tick) % P, the
    same rotation as :func:`proxy_assign`.  One gather up front lets the
    engine feed per-wave views to its wave scan instead of issuing P
    dynamic row reads (bit-for-bit the same rows)."""
    P = L_hat_p.shape[0]
    idx = (jnp.arange(P, dtype=jnp.int32) + jnp.asarray(tick, jnp.int32)) % P
    return L_hat_p[idx]


def init_fleet(
    N: int, P: int, D: int, ttl_init_ms: float = 100.0
) -> FleetState:
    if P <= 0:
        raise ValueError(f"fleet needs P >= 1 proxies, got {P}")
    if D <= 0:
        raise ValueError(f"fleet needs D >= 1 ring-buffer slots, got {D}")
    zp = jnp.zeros((P,), jnp.int32)
    return FleetState(
        shared=cache_lib.init_cache(N, ttl_init_ms),
        tick=jnp.zeros((), jnp.int32),
        # -inf-like sentinel: "no event yet" is always propagation-old
        last_event_ms=jnp.full((N,), -1e30, jnp.float32),
        last_origin=jnp.full((N,), -1, jnp.int32),
        # empty-cache snapshots: expiry 0 / version -1 == never live
        lag_expiry=jnp.zeros((D, N), jnp.float32),
        lag_version=jnp.full((D, N), -1, jnp.int32),
        hits_p=zp,
        misses_p=zp,
        stale_p=zp,
        bypasses_p=zp,
    )


def lookup_fleet(
    state: FleetState,
    keys: jnp.ndarray,
    mask: jnp.ndarray,
    is_write: jnp.ndarray,
    proxy: jnp.ndarray,
    now_ms: jnp.ndarray,
    *,
    mode: str = "lease",
    lease_ms: float = 5000.0,
    rtt_ms: float = 2.0,
    p_star: float = cache_lib.P_STAR,
    gossip_ms: float = 0.0,
    partitioned: Optional[jnp.ndarray] = None,
    avail: Optional[jnp.ndarray] = None,
) -> Tuple[FleetState, jnp.ndarray]:
    """Process one tick of requests, each served by its assigned proxy.

    ``proxy`` maps every request slot to the proxy serving it (see
    :func:`proxy_assign`).  Hits are decided against the serving proxy's
    gossip view; effects land on the converged table via the shared
    model's ``apply_batch``, then this tick's install/invalidation
    events enter the gossip log and the snapshot ring buffer.

    ``partitioned`` (optional (P,) bool from the fault layer) cuts a
    proxy off from gossip: remote events never become time-visible to
    it while partitioned — it keeps serving from the lagged snapshot
    (plus its own events), which is exactly the staleness spike a
    gossip partition causes.  ``avail`` feeds the availability install
    guard (see :func:`repro.core.cache.apply_batch`).  Returns
    ``(new_state, served_locally: (R,) bool)``.
    """
    sh = state.shared
    P = state.hits_p.shape[0]
    D = state.lag_expiry.shape[0]

    # --- per-request view: fresh for own/propagated events, else lagged --
    slot = state.tick % D  # ring slot holding the snapshot from D ticks ago
    lag_exp = state.lag_expiry[slot]
    lag_ver = state.lag_version[slot]
    own = state.last_origin[keys] == proxy
    propagated = now_ms - state.last_event_ms[keys] >= gossip_ms
    if partitioned is not None:
        propagated = propagated & ~partitioned[proxy]
    fresh = own | propagated
    exp_view = jnp.where(fresh, sh.expiry_ms[keys], lag_exp[keys])
    ver_view = jnp.where(fresh, sh.cached_version[keys], lag_ver[keys])

    _, hit, stale = cache_lib.classify(
        exp_view, ver_view, sh.global_version[keys], mask, is_write, now_ms
    )

    # --- converged-table effects: identical to the shared model ----------
    new_sh, eff = cache_lib.apply_batch(
        sh,
        keys,
        mask,
        is_write,
        hit,
        stale,
        now_ms,
        mode=mode,
        lease_ms=lease_ms,
        rtt_ms=rtt_ms,
        p_star=p_star,
        avail=avail,
    )

    # --- gossip log: invalidations first, installs win on collision ------
    # (same intra-tick order as apply_batch's table scatters)
    lev = state.last_event_ms.at[eff.inv_keys].set(now_ms, mode="drop")
    lor = state.last_origin.at[eff.inv_keys].set(proxy, mode="drop")
    lev = lev.at[eff.ins_keys].set(now_ms, mode="drop")
    lor = lor.at[eff.ins_keys].set(proxy, mode="drop")

    # --- push the post-tick snapshot; this slot is re-read at tick+D -----
    lag_e = state.lag_expiry.at[slot].set(new_sh.expiry_ms)
    lag_v = state.lag_version.at[slot].set(new_sh.cached_version)

    # --- per-proxy counters: segment-sum flags onto the proxy axis -------
    # miss/bypassed come from apply_batch's effect vectors, so per-proxy
    # counters sum to the aggregate ones by construction.
    def seg(flags: jnp.ndarray) -> jnp.ndarray:
        sink = jnp.where(flags, proxy, P)  # OOB sentinel drops non-events
        return jnp.zeros((P,), jnp.int32).at[sink].add(1, mode="drop")

    new = state._replace(
        shared=new_sh,
        tick=state.tick + 1,
        last_event_ms=lev,
        last_origin=lor,
        lag_expiry=lag_e,
        lag_version=lag_v,
        hits_p=state.hits_p + seg(hit),
        misses_p=state.misses_p + seg(eff.miss),
        stale_p=state.stale_p + seg(stale),
        bypasses_p=state.bypasses_p + seg(eff.bypassed),
    )
    return new, hit


def remap_invalidate(
    state: FleetState, moved: jnp.ndarray
) -> FleetState:
    """Fleet-wide remap invalidation: after a membership epoch flip,
    NO proxy may serve an entry whose owner changed without
    revalidation (the tested property).  Moved entries are dropped from
    the converged table (:func:`repro.core.cache.remap_invalidate`) AND
    from every lagged snapshot in the ring buffer — whichever view a
    proxy's gossip freshness test selects, the entry is never-live."""
    return state._replace(
        shared=cache_lib.remap_invalidate(state.shared, moved),
        lag_expiry=jnp.where(moved[None, :], 0.0, state.lag_expiry),
    )


def slow_fleet(
    state: FleetState,
    window_ms: float,
    rtt_ms: float,
    lease_remaining_ms: float = jnp.inf,
    p_star: float = cache_lib.P_STAR,
    ttl_scale=1.0,
) -> FleetState:
    """T_slow retune: the hazard estimator lives on the converged table
    (server-side aggregates, which gossip does not lag).  ``ttl_scale``
    is the controller-emitted TTL multiplier (``Knobs.ttl_scale``)."""
    shared = cache_lib.slow_update(
        state.shared,
        window_ms,
        rtt_ms,
        lease_remaining_ms,
        p_star,
        ttl_scale=ttl_scale,
    )
    return state._replace(shared=shared)
