"""Traffic generators reproducing the paper's Fig. 2 workload patterns.

Each generator emits a (T, R) grid of request keys with a validity mask:
tick t carries ``counts[t] <= R`` real requests.  Keys index a namespace of
``N`` objects (directories/inodes); the key→server map comes from the
consistent-hash ring, so key skew creates server hotspots exactly as in the
paper's motivation (job start-ups / checkpoint storms hammer few dirs).

Rates are expressed as a fraction of aggregate service capacity
``cap = m * dt_ms / service_ms`` requests per tick.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

WORKLOADS = ("light", "uniform_heavy", "bursty", "periodic", "diurnal",
             "skewed", "storm")


class Workload(NamedTuple):
    keys: jnp.ndarray     # (T, R) int32 in [0, N)
    mask: jnp.ndarray     # (T, R) bool
    is_write: jnp.ndarray  # (T, R) bool (metadata-mutating ops)
    name: str
    N: int


def _zipf_cdf(N: int, alpha: float) -> jnp.ndarray:
    ranks = jnp.arange(1, N + 1, dtype=jnp.float32)
    w = ranks ** (-alpha)
    return jnp.cumsum(w) / jnp.sum(w)


def _sample_keys(key, shape, N: int, alpha: float, perm_salt: int = 3):
    """Zipf(alpha) keys (alpha=0 → uniform), rank→id decorrelated by hashing."""
    if alpha <= 0.0:
        return jax.random.randint(key, shape, 0, N, dtype=jnp.int32)
    cdf = _zipf_cdf(N, alpha)
    u = jax.random.uniform(key, shape)
    ranks = jnp.searchsorted(cdf, u).astype(jnp.int32)
    # permute ranks over the namespace so hot keys land on "random" servers
    from repro.core.hashring import hash2
    return (hash2(ranks.astype(jnp.uint32), jnp.uint32(perm_salt))
            % jnp.uint32(N)).astype(jnp.int32)


def _hot_subset_keys(key, shape, epoch_idx: jnp.ndarray, N: int, *,
                     subset: int, alpha: float, salt: int) -> jnp.ndarray:
    """Zipf(alpha) keys over a small hot subset that rotates per epoch
    (each burst/storm is a different job hitting different directories)."""
    from repro.core.hashring import hash2
    cdf = _zipf_cdf(subset, alpha)
    u = jax.random.uniform(key, shape)
    ranks = jnp.searchsorted(cdf, u).astype(jnp.int32)
    mixed = hash2(ranks.astype(jnp.uint32)
                  + jnp.uint32(subset) * epoch_idx[:, None].astype(jnp.uint32),
                  jnp.uint32(salt))
    return (mixed % jnp.uint32(N)).astype(jnp.int32)


def _assemble(key, rate_per_tick: jnp.ndarray, R: int, N: int,
              alpha: float, write_frac: float, name: str,
              hot_subset: int = 0) -> Workload:
    """Poisson arrivals at rate_per_tick; keys zipf(alpha) (optionally over a
    hot subset of the namespace, modeling one hot directory)."""
    T = rate_per_tick.shape[0]
    k1, k2, k3 = jax.random.split(key, 3)
    counts = jax.random.poisson(k1, rate_per_tick).astype(jnp.int32)
    counts = jnp.minimum(counts, R)
    mask = jnp.arange(R)[None, :] < counts[:, None]
    keys = _sample_keys(k2, (T, R), hot_subset or N, alpha)
    is_write = jax.random.uniform(k3, (T, R)) < write_frac
    return Workload(keys=keys, mask=mask, is_write=is_write & mask,
                    name=name, N=N)


def make_workload(name: str, *, T: int, m: int, seed: int = 0,
                  dt_ms: float = 50.0, service_ms: float = 100.0,
                  N: int = 4096, R: int = 0,
                  write_frac: float = 0.05) -> Workload:
    cap = m * dt_ms / service_ms          # requests/tick the cluster can serve
    R = R or int(4 * cap) + 8
    key = jax.random.PRNGKey(seed)
    t = jnp.arange(T, dtype=jnp.float32)
    sec = t * dt_ms / 1000.0

    if name == "light":
        rate = jnp.full((T,), 0.40 * cap)
        return _assemble(key, rate, R, N, 0.0, write_frac, name)

    if name == "uniform_heavy":
        rate = jnp.full((T,), 0.85 * cap)
        return _assemble(key, rate, R, N, 0.0, write_frac, name)

    if name == "bursty":
        # background 30% + job-startup bursts: every ~20 s, 2 s at 3x
        # capacity, keys concentrated on a small hot directory set.  Each
        # burst is a *different* job => different hot directories.
        k1, k2, k3 = jax.random.split(key, 3)
        base = jnp.full((T,), 0.30 * cap)
        period_s, dur_s = 20.0, 2.0
        phase = jax.random.uniform(k3, ()) * period_s
        in_burst = ((sec + phase) % period_s) < dur_s
        burst_idx = ((sec + phase) // period_s).astype(jnp.int32)
        rate = base + jnp.where(in_burst, 3.0 * cap, 0.0)
        wl = _assemble(k1, rate, R, N, 0.0, write_frac, name)
        hot = _hot_subset_keys(k2, wl.keys.shape, burst_idx, N,
                               subset=32, alpha=1.1, salt=11)
        keys = jnp.where(in_burst[:, None], hot, wl.keys)
        return wl._replace(keys=keys)

    if name == "periodic":
        # sinusoid peaking slightly above capacity (checkpoint cadence)
        rate = cap * jnp.clip(0.55 + 0.55 * jnp.sin(2 * jnp.pi * sec / 30.0),
                              0.0, None)
        return _assemble(key, rate, R, N, 0.6, write_frac, name)

    if name == "diurnal":
        horizon = jnp.maximum(sec[-1], 1.0)
        rate = cap * jnp.clip(
            0.5 + 0.45 * jnp.sin(2 * jnp.pi * sec / horizon)
            + 0.08 * jnp.sin(2 * jnp.pi * sec / 13.0), 0.0, None)
        return _assemble(key, rate, R, N, 0.5, write_frac, name)

    if name == "skewed":
        rate = jnp.full((T,), 0.70 * cap)
        return _assemble(key, rate, R, N, 0.9, write_frac, name)

    if name == "storm":
        # checkpoint storm: near-idle then all ranks write at once (5 s);
        # each storm targets that job's checkpoint directories.
        k1, k2 = jax.random.split(key)
        storm = (sec % 60.0) < 5.0
        storm_idx = (sec // 60.0).astype(jnp.int32)
        rate = jnp.where(storm, 4.0 * cap, 0.05 * cap)
        wl = _assemble(k1, rate, R, N, 0.0, 0.5, name)
        hot = _hot_subset_keys(k2, wl.keys.shape, storm_idx, N,
                               subset=16, alpha=1.0, salt=17)
        keys = jnp.where(storm[:, None], hot, wl.keys)
        return wl._replace(keys=keys)

    raise ValueError(f"unknown workload {name!r}; known: {WORKLOADS}")
