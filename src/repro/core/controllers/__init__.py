"""Pluggable control-plane registry for the MIDAS middleware pipeline.

The simulator resolves ``SimConfig.controller`` through this registry —
there is no controller-name branching in ``sim.py`` — so third-party
control laws plug in without touching the engine.  A complete
registration looks like this (~15 lines):

    import jax.numpy as jnp
    from repro.core import controllers

    @controllers.register("bang_bang")
    class BangBang(controllers.Controller):
        '''Max aggressiveness whenever pressure is positive, else min.'''

        def fast(self, state, sig):
            P = controllers.pressure_score(
                sig.B, sig.p99, state.b_tgt, state.p99_tgt)
            hot = P > 0.0
            knobs = state.knobs._replace(
                d=jnp.where(hot, controllers.D_MAX,
                            controllers.D_MIN).astype(jnp.int32),
                f_max=jnp.where(hot, controllers.F_MAX_HIGH,
                                controllers.F_CAP))
            state = state._replace(knobs=knobs, pressure=P)
            return state, self.view(state)

    # SimConfig(controller="bang_bang") now works everywhere:
    # simulate(), simulate_sweep(), the E4 stability matrix, examples.

Stateful controllers override ``init_inner(cfg)`` and thread their
pytree through ``fast``/``slow`` (see ``hysteresis.py``); ablation
decorators (``wrap_ablations``) mask the emitted knob view without
touching dynamics.  ``available()`` lists everything registered;
unknown names raise a ``ValueError`` naming the alternatives.  Every
registered controller must keep its knobs inside their ``KnobSpec``
bounds and must not sustain a limit cycle under constant load — both
are enforced registry-wide by hypothesis properties in
``tests/test_core_controllers.py``.
"""

from repro.core.controllers.base import (
    ABLATIONS,
    ALPHA_FAST,
    BETA_SLOW,
    D_INIT,
    D_MAX,
    D_MIN,
    DELTA_L_INIT,
    DELTA_L_MAX,
    DELTA_L_MIN,
    EPS,
    F_CAP,
    F_MAX_HIGH,
    KNOB_SPECS,
    PIN_C_MS,
    T_FAST_MS,
    T_SLOW_MS,
    TTL_SCALE_MAX,
    TTL_SCALE_MIN,
    W_WINDOW_MS,
    W1,
    W2,
    Ablated,
    ControlState,
    Controller,
    KnobSpec,
    Knobs,
    Signals,
    available,
    clip_knobs,
    consensus_view,
    get,
    get_class,
    init_knobs,
    lyapunov_delta_v,
    lyapunov_potential,
    make_signals,
    parse_ablations,
    pressure_score,
    register,
    spec,
    trajectory_stats,
    unregister,
    warmup_targets,
    wrap_ablations,
)
from repro.core.controllers.guard import (
    HOLD_WINDOWS,
    TRIP_FLIPS,
    Guarded,
    GuardInner,
    wrap_guard,
)

# Built-in controllers self-register on import.
from repro.core.controllers import (  # noqa: F401, E402
    aimd,
    deadband_pid,
    hysteresis,
    static,
)

__all__ = [
    "ABLATIONS",
    "ALPHA_FAST",
    "BETA_SLOW",
    "Ablated",
    "ControlState",
    "Controller",
    "D_INIT",
    "D_MAX",
    "D_MIN",
    "DELTA_L_INIT",
    "DELTA_L_MAX",
    "DELTA_L_MIN",
    "EPS",
    "F_CAP",
    "F_MAX_HIGH",
    "Guarded",
    "GuardInner",
    "HOLD_WINDOWS",
    "KNOB_SPECS",
    "KnobSpec",
    "Knobs",
    "PIN_C_MS",
    "Signals",
    "T_FAST_MS",
    "T_SLOW_MS",
    "TRIP_FLIPS",
    "TTL_SCALE_MAX",
    "TTL_SCALE_MIN",
    "W_WINDOW_MS",
    "W1",
    "W2",
    "available",
    "clip_knobs",
    "consensus_view",
    "get",
    "get_class",
    "init_knobs",
    "lyapunov_delta_v",
    "lyapunov_potential",
    "make_signals",
    "parse_ablations",
    "pressure_score",
    "register",
    "spec",
    "trajectory_stats",
    "unregister",
    "warmup_targets",
    "wrap_ablations",
    "wrap_guard",
]
