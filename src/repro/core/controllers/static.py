"""Static controller: knobs pinned at their spec inits forever.

The ablation baseline for the whole control plane — running MIDAS
routing with ``controller="static"`` measures what the adaptive loop
itself buys, the §IV-E counterpart of disabling a single stability
mechanism.  The pressure score is still computed (it surfaces in
``TickOut.pressure`` and the E4 matrix), but no knob ever moves, so the
trajectory is trivially oscillation-free.
"""

from __future__ import annotations

from typing import Tuple

from repro.core.controllers import base
from repro.core.controllers.base import (
    ControlState,
    Controller,
    Knobs,
    Signals,
    register,
)


@register("static")
class Static(Controller):
    """Fixed-knob baseline: d=2, Δ_L=4, f_max=0.10, TTL scale 1."""

    def fast(
        self, state: ControlState, sig: Signals
    ) -> Tuple[ControlState, Knobs]:
        P = base.pressure_score(sig.B, sig.p99, state.b_tgt, state.p99_tgt)
        state = state._replace(pressure=P)
        return state, self.view(state)
