"""Deadband integral controller on the pressure score.

Instead of hysteresis counters, an anti-windup integrator ``I ∈ [0, 1]``
accumulates how far pressure sits OUTSIDE the deadband (the same
[H↓, H↑] band the hysteresis controller uses, for comparability):

    I ← clip(I + KI·[P − H↑]₊ − KR·[H↓ − P]₊, 0, 1)

Inside the deadband the integrator — and therefore every knob — is
exactly frozen; above it knobs ramp smoothly instead of stepping, and
release (KR < KI) is deliberately slower than attack, mirroring
K↓ > K↑.  Knobs derive from ``I`` with the same declarative affine map
as the AIMD controller, so bounds hold by construction and constant
load drives ``I`` to a fixed point (a clamp or the frozen band) — no
limit cycle.

The slow hook retunes ``ttl_scale`` from the write-mix signal: under
mutation-dominated traffic TTL-mode cache entries die before reuse, so
the controller halves the TTL multiplier (floor TTL_SCALE_MIN) and
doubles it back toward 1 when reads dominate — the controller-side
complement of the cache's own hazard estimator.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core.controllers import base
from repro.core.controllers.aimd import _knobs_from_axis
from repro.core.controllers.base import (
    ControlState,
    Controller,
    Knobs,
    Signals,
    register,
)
from repro.core.controllers.hysteresis import H_DOWN, H_UP

KI = 0.10  # integral attack gain (per fast tick above the band)
KR = 0.02  # integral release gain (per fast tick below the band)
W_SHRINK = 0.3  # write-mix threshold for the slow TTL retune


@register("deadband_pid")
class DeadbandPid(Controller):
    """Anti-windup integral control with a frozen deadband."""

    def init_inner(self, cfg) -> jnp.ndarray:
        return jnp.zeros((), jnp.float32)  # the integrator I

    def fast(
        self, state: ControlState, sig: Signals
    ) -> Tuple[ControlState, Knobs]:
        P = base.pressure_score(sig.B, sig.p99, state.b_tgt, state.p99_tgt)
        relu = lambda z: jnp.maximum(z, 0.0)
        i = jnp.clip(
            state.inner + KI * relu(P - H_UP) - KR * relu(H_DOWN - P),
            0.0,
            1.0,
        )
        state = state._replace(
            knobs=base.clip_knobs(
                _knobs_from_axis(state.knobs, i, sig.rtt_ms)
            ),
            pressure=P,
            inner=i,
        )
        return state, self.view(state)

    def slow(
        self, state: ControlState, sig: Signals
    ) -> Tuple[ControlState, Knobs]:
        k = state.knobs
        scale = jnp.where(
            sig.write_mix > W_SHRINK,
            k.ttl_scale * 0.5,
            jnp.minimum(k.ttl_scale * 2.0, 1.0),
        )
        scale = jnp.clip(scale, base.TTL_SCALE_MIN, base.TTL_SCALE_MAX)
        state = state._replace(knobs=k._replace(ttl_scale=scale))
        return state, self.view(state)
