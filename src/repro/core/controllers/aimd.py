"""AIMD controller: additive-increase / multiplicative-decrease on one
aggressiveness axis.

The congestion-control classic mapped onto the MIDAS knob schema: the
controller carries a single scalar ``a ∈ [0, 1]`` ("routing
aggressiveness").  While the pressure score is positive, ``a`` ramps
*additively* (+AI per fast tick); the moment pressure clears, ``a``
collapses *multiplicatively* (×MD) — probe gently, back off hard, the
inverse of hysteresis' fast-escalate / slow-release asymmetry.  Knobs
derive declaratively from ``a`` along each spec's range:

    d       = round(D_MIN     + a·(D_MAX − D_MIN))
    Δ_L     = Δ_L_MAX         − a·(Δ_L_MAX − Δ_L_MIN)
    f_max   = F_CAP           + a·(F_MAX_HIGH − F_CAP)

so bounds hold by construction, and under constant load ``a`` converges
(to the clamp under sustained pressure, geometrically to 0 when calm) —
no sustained limit cycle.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core.controllers import base
from repro.core.controllers.base import (
    ControlState,
    Controller,
    Knobs,
    Signals,
    register,
)

AI = 0.05  # additive aggressiveness step per pressured fast tick
MD = 0.5  # multiplicative back-off once pressure clears


def _knobs_from_axis(k: Knobs, a: jnp.ndarray, rtt_ms: float) -> Knobs:
    """Affine map from the aggressiveness axis to every routing knob."""
    d = jnp.round(
        base.D_MIN + a * (base.D_MAX - base.D_MIN)
    ).astype(jnp.int32)
    delta_l = base.DELTA_L_MAX - a * (base.DELTA_L_MAX - base.DELTA_L_MIN)
    f_max = base.F_CAP + a * (base.F_MAX_HIGH - base.F_CAP)
    return k._replace(
        d=d,
        delta_l=delta_l.astype(jnp.float32),
        delta_t=jnp.asarray(rtt_ms, jnp.float32),
        f_max=f_max.astype(jnp.float32),
    )


@register("aimd")
class Aimd(Controller):
    """Probe additively under pressure, back off multiplicatively."""

    def init_inner(self, cfg) -> jnp.ndarray:
        return jnp.zeros((), jnp.float32)  # the aggressiveness axis a

    def fast(
        self, state: ControlState, sig: Signals
    ) -> Tuple[ControlState, Knobs]:
        P = base.pressure_score(sig.B, sig.p99, state.b_tgt, state.p99_tgt)
        a = jnp.where(P > 0.0, state.inner + AI, state.inner * MD)
        a = jnp.clip(a, 0.0, 1.0)
        state = state._replace(
            knobs=base.clip_knobs(
                _knobs_from_axis(state.knobs, a, sig.rtt_ms)
            ),
            pressure=P,
            inner=a,
        )
        return state, self.view(state)
