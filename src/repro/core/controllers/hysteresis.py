"""The paper's hysteresis controller (§IV-E, Algorithm 1 lines 26-35).

Reference implementation of the controller protocol, migrated verbatim
from the pre-registry ``control.py`` monolith: pressure
    P = w1·[B − B_tgt]₊ + w2·[(p̃99 − tgt)/tgt]₊
is compared against a hysteresis band (H↓ = 0.02 < H↑ = 0.10); only
after K↑ = 3 consecutive ticks above (K↓ = 8 below) do the knobs move,
in single bounded steps — d ± 1, Δ_L ∓ 1, f_max ×2/×½ — and the counter
that fired resets.  The asymmetric counters are what prevent limit
cycles: escalation is fast, de-escalation deliberately sluggish.

Availability reaction (fault layer): while detected membership is
degraded (``Signals.avail < AVAIL_FULL``) the counter gates are
overridden — escalate immediately, never de-escalate — because a
shrunken ring concentrates remapped keys on the survivors and waiting
K↑ ticks is exactly the hotspot window E12 measures.  With full
availability the comparison is constant-false and the controller is
value-identical to the pre-fault engine (the golden contract).  The
``no_fault_signal`` ablation removes this reaction.

``SimConfig(controller="hysteresis")`` is the engine default and is
bit-for-bit identical to the pre-refactor engine on CPU
(tests/test_core_controllers.py golden contract).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

from repro.core.controllers import base
from repro.core.controllers.base import (
    ControlState,
    Controller,
    Knobs,
    Signals,
    register,
)
from repro.core.faults.base import AVAIL_FULL

# Hysteresis thresholds and counters (paper defaults).
H_DOWN, H_UP = 0.02, 0.10
K_UP, K_DOWN = 3, 8


class HysteresisInner(NamedTuple):
    above_cnt: jnp.ndarray  # () int32 consecutive P > H_up
    below_cnt: jnp.ndarray  # () int32 consecutive P < H_down


@register("hysteresis")
class Hysteresis(Controller):
    """Counter-gated single-step knob moves inside a pressure deadband."""

    def init_inner(self, cfg) -> HysteresisInner:
        return HysteresisInner(
            above_cnt=jnp.zeros((), jnp.int32),
            below_cnt=jnp.zeros((), jnp.int32),
        )

    def fast(
        self, state: ControlState, sig: Signals
    ) -> Tuple[ControlState, Knobs]:
        k = state.knobs
        P = base.pressure_score(sig.B, sig.p99, state.b_tgt, state.p99_tgt)
        above = jnp.where(P > H_UP, state.inner.above_cnt + 1, 0)
        below = jnp.where(P < H_DOWN, state.inner.below_cnt + 1, 0)

        degraded = jnp.asarray(sig.avail, jnp.float32) < AVAIL_FULL
        go_up = (above >= K_UP) | degraded
        go_down = (below >= K_DOWN) & ~degraded

        d = jnp.where(
            go_up,
            jnp.minimum(k.d + 1, base.D_MAX),
            jnp.where(go_down, jnp.maximum(k.d - 1, base.D_MIN), k.d),
        )
        delta_l = jnp.where(
            go_up,
            jnp.maximum(k.delta_l - 1.0, base.DELTA_L_MIN),
            jnp.where(
                go_down,
                jnp.minimum(k.delta_l + 1.0, base.DELTA_L_MAX),
                k.delta_l,
            ),
        )
        f_max = jnp.where(
            go_up,
            jnp.minimum(k.f_max * 2.0, base.F_MAX_HIGH),
            jnp.where(
                go_down, jnp.maximum(k.f_max * 0.5, base.F_CAP), k.f_max
            ),
        )
        # reset the counter that fired
        above = jnp.where(go_up, 0, above)
        below = jnp.where(go_down, 0, below)

        delta_t = (
            jnp.asarray(sig.rtt_ms, jnp.float32)
            + 0.1 * sig.rtt_ms * sig.jitter
        )

        state = state._replace(
            knobs=k._replace(
                d=d, delta_l=delta_l, delta_t=delta_t, f_max=f_max
            ),
            pressure=P,
            inner=HysteresisInner(above_cnt=above, below_cnt=below),
        )
        return state, self.view(state)
