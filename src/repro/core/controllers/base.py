"""Controller protocol, knob/signal schema, and the controller registry.

A *controller* is the control-plane stage of the MIDAS middleware: on the
paper's fast cadence (T_fast = 250 ms) it ingests a :class:`Signals`
bundle — the smoothed telemetry every proxy already maintains — and emits
a :class:`Knobs` bundle, the single typed contract every knob consumer
reads: the engine threads it through the scan carry, ``RouteContext``
exposes it to routing policies, the cooperative cache's slow-loop TTL
retune consumes ``ttl_scale``, and the fleet's consensus path feeds the
per-proxy views it is computed from.  Controllers register by name and
are selected with ``SimConfig(controller="name")``; the simulator never
branches on controller names.

Protocol
--------
``Controller.init(cfg, targets) -> ControlState`` builds the carried
pytree: the knob bundle at its spec inits, the §III-B targets, and an
``inner`` pytree the controller owns (hysteresis counters, integrators —
``init_inner`` is the hook).  ``fast(state, signals) -> (state, Knobs)``
runs one fast-loop update; ``slow(state, signals) -> (state, Knobs)``
runs on the T_slow cadence (default: no-op).  ``view(state) -> Knobs``
is the bundle consumers actually see each tick — ablation decorators
(:func:`wrap_ablations`) override it to mask out a stability mechanism
while leaving the controller's dynamics untouched, which is exactly what
the §IV-E ablation study measures.

Scan contract (DESIGN.md §9): all three hooks execute inside the jitted
tick scan, so ``ControlState`` must keep a stable pytree structure, and
``fast``/``slow`` must be pure.  Knob values must stay inside their
:class:`KnobSpec` bounds — a registry-wide hypothesis property enforces
this, along with freedom from sustained limit cycles under constant load
(tests/test_core_controllers.py).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple, Type

import jax.numpy as jnp
import numpy as np

from repro.core import registry as registry_lib
from repro.core import telemetry

# Paper cadences and shared control constants (Algorithm 1 lines 1-20).
T_FAST_MS = 250.0
T_SLOW_MS = 30_000.0
W_WINDOW_MS = 1000.0
PIN_C_MS = 300.0
W1, W2 = 1.0, 1.0
EPS = 1e-6
ALPHA_FAST = 0.2
BETA_SLOW = 0.1

# Knob bound constants (paper §IV-E); the declarative source of truth is
# KNOB_SPECS below — these names survive for formula-level readability.
D_INIT, D_MIN, D_MAX = 2, 1, 4
DELTA_L_INIT, DELTA_L_MIN, DELTA_L_MAX = 4.0, 2.0, 8.0
F_CAP = 0.10
F_MAX_HIGH = 1.0
TTL_SCALE_MIN, TTL_SCALE_MAX = 0.25, 4.0


class KnobSpec(NamedTuple):
    """Declarative schema of one control knob: bounds, init, step rule."""

    name: str
    lo: float
    hi: float
    init: Optional[float]  # None: derived from config (delta_t <- rtt_ms)
    step: str  # human-readable step rule (docs, E4 tables)
    dtype: Any = jnp.float32


class Knobs(NamedTuple):
    """The typed knob bundle — one field per :class:`KnobSpec`, same
    order.  Every consumer of control output reads this contract."""

    d: jnp.ndarray  # () int32 sample width in {1..4}
    delta_l: jnp.ndarray  # () float32 queue margin
    delta_t: jnp.ndarray  # () float32 latency margin (ms)
    f_max: jnp.ndarray  # () float32 steering-bucket cap
    pin_ms: jnp.ndarray  # () float32 pin duration C (ms)
    ttl_scale: jnp.ndarray  # () float32 slow-loop TTL multiplier


KNOB_SPECS: Tuple[KnobSpec, ...] = (
    KnobSpec("d", D_MIN, D_MAX, D_INIT,
             "single +1/-1 steps under hysteresis", jnp.int32),
    KnobSpec("delta_l", DELTA_L_MIN, DELTA_L_MAX, DELTA_L_INIT,
             "single -1.0/+1.0 steps, opposite d"),
    KnobSpec("delta_t", 0.0, float(np.inf), None,
             "rtt·(1 ± 0.1·jitter) to avoid lockstep proxies"),
    KnobSpec("f_max", F_CAP, F_MAX_HIGH, F_CAP,
             "×2 up / ×0.5 down (bounded multiplicative)"),
    KnobSpec("pin_ms", 0.0, float(np.inf), PIN_C_MS, "static"),
    KnobSpec("ttl_scale", TTL_SCALE_MIN, TTL_SCALE_MAX, 1.0,
             "controller slow-loop hook"),
)

assert tuple(s.name for s in KNOB_SPECS) == Knobs._fields


def spec(name: str) -> KnobSpec:
    """The :class:`KnobSpec` registered under ``name``."""
    for s in KNOB_SPECS:
        if s.name == name:
            return s
    raise ValueError(
        f"unknown knob {name!r}; available: "
        f"{', '.join(s.name for s in KNOB_SPECS)}"
    )


def init_knobs(rtt_ms: float) -> Knobs:
    """Every knob at its spec init (delta_t derives from the RTT)."""
    vals = {
        s.name: jnp.asarray(
            rtt_ms if s.init is None else s.init, s.dtype
        )
        for s in KNOB_SPECS
    }
    return Knobs(**vals)


def clip_knobs(knobs: Knobs) -> Knobs:
    """Clip every knob to its spec bounds (d stays int32)."""
    return Knobs(**{
        s.name: jnp.clip(v, s.lo, s.hi).astype(s.dtype)
        for s, v in zip(KNOB_SPECS, knobs)
    })


class Signals(NamedTuple):
    """Telemetry bundle handed to controllers on each control ingest.

    Everything is the *smoothed, stale* view a real proxy would hold —
    never instantaneous server state (§IV-E assumption 1).  Controllers
    read what they need; XLA dead-code-eliminates the rest.
    """

    B: jnp.ndarray  # () float32 smoothed imbalance of the consensus view
    p99: jnp.ndarray  # () float32 worst smoothed p99 across servers (ms)
    L_hat: jnp.ndarray  # (m,) float32 consensus queue view
    views_p: jnp.ndarray  # (P, m) float32 per-proxy views (fleet)
    write_mix: jnp.ndarray  # () float32 write fraction of the current
    #   T_slow window (windowed, resets each slow tick — never a
    #   single-tick sample)
    jitter: jnp.ndarray  # () float32 uniform in [-1, 1]
    rtt_ms: float  # static transport RTT (ms)
    # fault-layer telemetry (repro.core.faults): detected live fraction
    # and per-server detected-membership mask.  Defaults are the
    # all-healthy constants, so fault-unaware call sites (and fault-free
    # runs) are value-identical to the pre-fault engine; controllers
    # that ignore them cost nothing (XLA DCE).
    avail: Any = 1.0  # () float32 detected live fraction in (0, 1]
    member: Any = 1.0  # (m,) float32 detected membership (1=live)


def make_signals(
    B=0.0,
    p99=0.0,
    L_hat=None,
    views_p=None,
    write_mix=0.0,
    jitter=0.0,
    rtt_ms: float = 2.0,
    avail=1.0,
    member=None,
) -> Signals:
    """Signals bundle with neutral fillers — unit tests and the legacy
    ``control.fast_update`` shim drive controllers without an engine."""
    L = jnp.zeros((1,), jnp.float32) if L_hat is None else L_hat
    return Signals(
        B=jnp.asarray(B, jnp.float32),
        p99=jnp.asarray(p99, jnp.float32),
        L_hat=L,
        views_p=L[None, :] if views_p is None else views_p,
        write_mix=jnp.asarray(write_mix, jnp.float32),
        jitter=jnp.asarray(jitter, jnp.float32),
        rtt_ms=rtt_ms,
        avail=jnp.asarray(avail, jnp.float32),
        member=jnp.ones_like(L) if member is None else member,
    )


class ControlState(NamedTuple):
    """Carried control-plane pytree: knobs + targets + controller-owned
    ``inner`` state (counters, integrators, ...)."""

    knobs: Knobs
    b_tgt: jnp.ndarray  # () float32 imbalance target (§III-B)
    p99_tgt: jnp.ndarray  # () float32 latency target (ms)
    pressure: jnp.ndarray  # () float32 last computed (logging/TickOut)
    inner: Any


def pressure_score(
    B: jnp.ndarray,
    p99: jnp.ndarray,
    b_tgt: jnp.ndarray,
    p99_tgt: jnp.ndarray,
) -> jnp.ndarray:
    """P = w1·[B − B_tgt]₊ + w2·[(p̃99 − tgt)/tgt]₊ — the shared pressure
    score every registered controller regulates on."""
    relu = lambda z: jnp.maximum(z, 0.0)
    return W1 * relu(B - b_tgt) + W2 * relu(
        (p99 - p99_tgt) / jnp.maximum(p99_tgt, EPS)
    )


def warmup_targets(
    B_series: jnp.ndarray, p99_warm: jnp.ndarray, rtt_ms: float
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """§III-B target selection from the low-utilization warmup window."""
    b_tgt = jnp.median(B_series) + 0.05
    p99_tgt = jnp.maximum(p99_warm * 1.25, rtt_ms + 2.0)
    return b_tgt, p99_tgt


def consensus_view(
    views_p: jnp.ndarray, reducer: str = "mean"
) -> jnp.ndarray:
    """Collapse (P, m) per-proxy telemetry views into the single view the
    one control loop consumes (fleet mode).  The paper runs one logical
    controller over P proxies' reports; the reducer is its consensus —
    ``median`` is the robust choice when one proxy's staggered view lags
    badly, ``max`` the conservative one."""
    return telemetry.reduce_views(views_p, reducer)


# ---------------------------------------------------------------------------
# Lyapunov stability helpers (paper §IV-E, eq. 2)
# ---------------------------------------------------------------------------


def lyapunov_delta_v(
    L: jnp.ndarray, p: jnp.ndarray, j: jnp.ndarray
) -> jnp.ndarray:
    """ΔV for moving one request p→j:  2(L̂_j − L̂_p) + 2  (paper eq. 2)."""
    return 2.0 * (L[j] - L[p]) + 2.0


def lyapunov_potential(L: jnp.ndarray) -> jnp.ndarray:
    """V(L̂) = Σ_i (L̂_i − L̄)²."""
    return jnp.sum((L - jnp.mean(L)) ** 2)


# ---------------------------------------------------------------------------
# Controller base class + registry
# ---------------------------------------------------------------------------


class Controller:
    """Base class for registered control-plane implementations.

    Subclasses override :meth:`fast` (and :meth:`init_inner` /
    :meth:`slow` when they carry state or retune slow-path knobs).
    """

    name: str = "?"

    def init_inner(self, cfg) -> Any:
        """Controller-owned pytree (default: stateless)."""
        return ()

    def init(self, cfg, targets: Tuple[float, float]) -> ControlState:
        b_tgt, p99_tgt = targets
        return ControlState(
            knobs=init_knobs(cfg.rtt_ms),
            b_tgt=jnp.asarray(b_tgt, jnp.float32),
            p99_tgt=jnp.asarray(p99_tgt, jnp.float32),
            pressure=jnp.zeros((), jnp.float32),
            inner=self.init_inner(cfg),
        )

    def fast(
        self, state: ControlState, sig: Signals
    ) -> Tuple[ControlState, Knobs]:
        raise NotImplementedError

    def slow(
        self, state: ControlState, sig: Signals
    ) -> Tuple[ControlState, Knobs]:
        return state, self.view(state)

    def view(self, state: ControlState) -> Knobs:
        """Knobs as consumers see them (decorators mask them here)."""
        return state.knobs


REGISTRY = registry_lib.Registry("controller")


def register(name: str):
    """Class decorator: ``@controllers.register("my_ctrl")`` adds a
    Controller subclass under ``name`` (``SimConfig(controller=name)``)."""
    return REGISTRY.register(name)


def unregister(name: str) -> None:
    """Remove a registered controller (intended for tests/plugins)."""
    REGISTRY.unregister(name)


def available() -> Tuple[str, ...]:
    """Sorted names of every registered controller."""
    return REGISTRY.available()


def get_class(name: str) -> Type[Controller]:
    return REGISTRY.get_class(name)


def get(name: str) -> Controller:
    """Instantiate the controller registered under ``name``."""
    return REGISTRY.get(name)


# ---------------------------------------------------------------------------
# Ablation decorators (§IV-E stability mechanisms)
# ---------------------------------------------------------------------------

ABLATIONS = ("no_margin", "no_pin", "no_bucket", "no_fault_signal")


def parse_ablations(flags: str) -> Tuple[str, ...]:
    """Split an ``ablate`` spec ("no_margin,no_pin") into known tokens;
    unknown tokens raise with the alternatives listed."""
    toks = tuple(t for t in (s.strip() for s in flags.split(",")) if t)
    for t in toks:
        if t not in ABLATIONS:
            raise ValueError(
                f"unknown ablation {t!r}; available: "
                f"{', '.join(ABLATIONS)}"
            )
    return toks


class Ablated(Controller):
    """Decorator removing §IV-E stability mechanisms from the *emitted*
    knob view while leaving the wrapped controller's dynamics untouched
    — the ablation study measures what breaks without a guard, not a
    differently-tuned controller.

      no_margin — steer on any lighter candidate (Δ_L = 0, Δ_t = −∞)
      no_pin    — re-evaluate every request (C = 0)
      no_bucket — uncapped steering (f_max = 1)
      no_fault_signal — controller never sees availability degradation
                  (Signals.avail/member forced healthy; the fault still
                  happens, the control plane just flies blind — what
                  E12 isolates as the value of availability telemetry)
    """

    def __init__(self, inner: Controller, flags: str):
        self.inner = inner
        self.flags = parse_ablations(flags)
        self.name = f"{inner.name}[{','.join(self.flags)}]"

    def init_inner(self, cfg) -> Any:
        return self.inner.init_inner(cfg)

    def init(self, cfg, targets: Tuple[float, float]) -> ControlState:
        return self.inner.init(cfg, targets)

    def _mask_signals(self, sig: Signals) -> Signals:
        if "no_fault_signal" in self.flags:
            member = jnp.ones_like(
                jnp.asarray(sig.member, jnp.float32)
            )
            sig = sig._replace(
                avail=jnp.ones((), jnp.float32), member=member
            )
        return sig

    def fast(self, state, sig):
        state, _ = self.inner.fast(state, self._mask_signals(sig))
        return state, self.view(state)

    def slow(self, state, sig):
        state, _ = self.inner.slow(state, self._mask_signals(sig))
        return state, self.view(state)

    def view(self, state: ControlState) -> Knobs:
        k = self.inner.view(state)
        if "no_margin" in self.flags:
            k = k._replace(
                delta_l=jnp.zeros(()), delta_t=jnp.zeros(()) - 1e9
            )
        if "no_pin" in self.flags:
            k = k._replace(pin_ms=jnp.zeros((), jnp.float32))
        if "no_bucket" in self.flags:
            k = k._replace(f_max=jnp.ones(()))
        return k


def wrap_ablations(ctrl: Controller, flags: str) -> Controller:
    """``ctrl`` unchanged for an empty spec, else the :class:`Ablated`
    decorator applying every named mechanism removal."""
    return Ablated(ctrl, flags) if parse_ablations(flags) else ctrl


# ---------------------------------------------------------------------------
# Host-side trajectory stability metrics (E4 + tests)
# ---------------------------------------------------------------------------


def trajectory_stats(
    d: np.ndarray,
    delta_l: np.ndarray,
    f_max: np.ndarray,
    pressure: np.ndarray,
    dt_ms: float,
) -> Dict[str, float]:
    """Stability metrics of one run's knob trajectories (host-side).

    * ``oscillation_per_min`` — d-knob flips per minute (the paper's
      oscillation measure);
    * ``settle_ms`` — time from the LAST pressure onset (final rising
      edge of P, i.e. the last burst the controller had to absorb) to
      the last knob change at or after it; 0.0 if pressure never rose
      or knobs never moved after that onset.  Anchoring on the final
      onset keeps the metric informative for workloads with recurring
      bursts, where measuring from the FIRST onset saturates at the
      horizon (knobs legitimately respond to every new burst);
    * ``knob_churn`` — mean per-tick |Δknob| normalized by each knob's
      spec range, summed over (d, delta_l, f_max);
    * ``settled`` — 1.0 when the final 10% of the horizon is change-free.
    """
    d = np.asarray(d, np.float64)
    dl = np.asarray(delta_l, np.float64)
    fm = np.asarray(f_max, np.float64)
    pr = np.asarray(pressure, np.float64)
    T = d.shape[0]
    if T < 2:
        return {"oscillation_per_min": 0.0, "settle_ms": 0.0,
                "knob_churn": 0.0, "settled": 1.0}
    minutes = T * dt_ms / 60_000.0
    flips = int(np.sum(np.diff(d) != 0))
    change = (
        (np.diff(d) != 0) | (np.diff(dl) != 0) | (np.diff(fm) != 0)
    )
    rising = np.flatnonzero((pr[1:] > 0.0) & (pr[:-1] <= 0.0)) + 1
    if pr[0] > 0.0:
        rising = np.concatenate([[0], rising])
    if rising.size == 0 or not change.any():
        settle_ms = 0.0
    else:
        onset = int(rising[-1])
        chg = np.flatnonzero(change) + 1  # tick indices of knob changes
        after = chg[chg >= onset]
        settle_ms = float(after[-1] - onset) * dt_ms if after.size else 0.0
    churn = 0.0
    for series, name in ((d, "d"), (dl, "delta_l"), (fm, "f_max")):
        s = spec(name)
        rng = (s.hi - s.lo) if np.isfinite(s.hi) else 1.0
        churn += float(np.mean(np.abs(np.diff(series))) / max(rng, EPS))
    tail = change[-max(T // 10, 1):]
    return {
        "oscillation_per_min": flips / minutes,
        "settle_ms": settle_ms,
        "knob_churn": churn,
        "settled": float(not tail.any()),
    }
