"""Oscillation guard: a registry-level limit-cycle circuit breaker.

Adversarial traffic tuned to the controllers' own cadences (the
``adversarial`` workload family) can drive any reactive control law
into a sustained limit cycle — d flapping between bounds in lockstep
with the attacker's burst period.  The guard is the defense: a
decorator in the same shape as :class:`~.base.Ablated` /
:func:`~.base.wrap_ablations` that *watches the emitted d knob* and
trips a freeze when it flips too often, trading routing aggressiveness
for stability instead of thrashing.

State machine (measured as E13's guarded rows):

* **watch** — every fast tick the guard counts flips of the stored
  ``d`` knob since the last slow tick.  The wrapped controller's
  dynamics run untouched.
* **trip** — at each slow tick (T_slow cadence, the loop the paper says
  must not oscillate) a window with ``>= TRIP_FLIPS`` flips trips the
  breaker: the guard records the current ``d`` / ``f_max`` as holds and
  freezes for ``HOLD_WINDOWS`` slow windows.
* **frozen** — while frozen, the *stored* knobs are overridden each
  control tick: ``d`` pinned at the hold, the hysteresis band widened
  to the top of the ``delta_l`` spec (steer only on large imbalance),
  ``f_max`` pinned.  Overriding stored knobs — not just the emitted
  view — matters twice: consumers, ``TickOut``, and the E4 oscillation
  metric all read them, and the wrapped controller's next step departs
  from the held point, so the freeze really interrupts the cycle.
* **release** — the freeze counts down one per slow window; a calm
  window (flip count under the trip) lets it expire, a hostile one
  re-trips it.

``wrap_guard(ctrl, False)`` returns ``ctrl`` unchanged — the default
``SimConfig(guard=False)`` path is the identically-untouched engine
(golden contract).  Composition order in the engine is
``wrap_guard(wrap_ablations(ctrl, ablate), guard)``: the guard sees the
same masked signals the ablated controller does.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax.numpy as jnp

from repro.core.controllers import base
from repro.core.controllers.base import (
    ControlState,
    Controller,
    Knobs,
    Signals,
)

# d flips within one T_slow window that trip the breaker.  The paper's
# hysteresis counters allow at most one escalation per K_UP and one
# release per K_DOWN fast ticks; a well-behaved run flips a handful of
# times per window, a resonant limit cycle tens of times.
TRIP_FLIPS = 8
# slow windows one trip freezes (re-tripped while the attack persists)
HOLD_WINDOWS = 2


class GuardInner(NamedTuple):
    """Guard-owned carry wrapped around the inner controller's pytree."""

    wrapped: Any             # the decorated controller's own inner
    flips: jnp.ndarray       # () int32 d flips since the last slow tick
    last_d: jnp.ndarray      # () int32 stored d at the last control tick
    frozen: jnp.ndarray      # () int32 freeze windows remaining
    hold_d: jnp.ndarray      # () int32 d pinned while frozen
    hold_f: jnp.ndarray      # () float32 f_max pinned while frozen


class Guarded(Controller):
    """Decorator freezing d / widening the band on detected thrash."""

    def __init__(self, inner: Controller):
        self.inner = inner
        self.name = f"{inner.name}+guard"

    def init_inner(self, cfg) -> GuardInner:
        return GuardInner(
            wrapped=self.inner.init_inner(cfg),
            flips=jnp.zeros((), jnp.int32),
            last_d=jnp.asarray(base.D_INIT, jnp.int32),
            frozen=jnp.zeros((), jnp.int32),
            hold_d=jnp.asarray(base.D_INIT, jnp.int32),
            hold_f=jnp.asarray(base.F_CAP, jnp.float32),
        )

    def init(self, cfg, targets: Tuple[float, float]) -> ControlState:
        state = self.inner.init(cfg, targets)
        return state._replace(
            inner=self.init_inner(cfg)._replace(wrapped=state.inner)
        )

    def _freeze(self, knobs: Knobs, gi: GuardInner) -> Knobs:
        frz = gi.frozen > 0
        return knobs._replace(
            d=jnp.where(frz, gi.hold_d, knobs.d).astype(jnp.int32),
            delta_l=jnp.where(
                frz,
                jnp.asarray(base.DELTA_L_MAX, jnp.float32),
                knobs.delta_l,
            ),
            f_max=jnp.where(frz, gi.hold_f, knobs.f_max),
        )

    def fast(self, state: ControlState, sig: Signals):
        gi = state.inner
        istate, _ = self.inner.fast(state._replace(inner=gi.wrapped), sig)
        knobs = self._freeze(istate.knobs, gi)
        flips = gi.flips + (knobs.d != gi.last_d).astype(jnp.int32)
        state = istate._replace(
            knobs=knobs,
            inner=gi._replace(
                wrapped=istate.inner, flips=flips, last_d=knobs.d
            ),
        )
        return state, self.view(state)

    def slow(self, state: ControlState, sig: Signals):
        gi = state.inner
        istate, _ = self.inner.slow(state._replace(inner=gi.wrapped), sig)
        trip = gi.flips >= TRIP_FLIPS
        newly = trip & (gi.frozen <= 0)
        gi = gi._replace(
            wrapped=istate.inner,
            flips=jnp.zeros((), jnp.int32),
            frozen=jnp.where(
                trip,
                jnp.asarray(HOLD_WINDOWS, jnp.int32),
                jnp.maximum(gi.frozen - 1, 0),
            ).astype(jnp.int32),
            hold_d=jnp.where(
                newly, istate.knobs.d, gi.hold_d
            ).astype(jnp.int32),
            hold_f=jnp.where(
                newly, istate.knobs.f_max, gi.hold_f
            ).astype(jnp.float32),
        )
        knobs = self._freeze(istate.knobs, gi)
        state = istate._replace(knobs=knobs, inner=gi._replace(last_d=knobs.d))
        return state, self.view(state)

    def view(self, state: ControlState) -> Knobs:
        # stored knobs already carry the freeze; delegate so ablation
        # masks compose (the guard wraps the Ablated decorator)
        return self.inner.view(state._replace(inner=state.inner.wrapped))


def wrap_guard(ctrl: Controller, enabled: bool) -> Controller:
    """``ctrl`` unchanged when disabled (the golden default), else the
    :class:`Guarded` oscillation breaker around it."""
    return Guarded(ctrl) if enabled else ctrl
