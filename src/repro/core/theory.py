"""Closed-form results the paper leans on (§V) + fast simulators to verify.

* Balls-into-bins: uniform placement has expected max load
  ~ m/n·(1 + ln M/ln ln M)-style gap; power-of-d gives ln ln M / ln d + O(1)
  above the mean (Azar et al.; Mitzenmacher).
* M/M/1: E[T] = 1/(μ − λ) for λ < μ.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp


def uniform_maxload_gap_theory(m: int) -> float:
    """Expected max-above-mean for n=m balls, uniform: ≈ ln m / ln ln m."""
    lm = math.log(m)
    return lm / math.log(lm) if lm > 1 else 1.0


def power_of_d_maxload_gap_theory(m: int, d: int) -> float:
    """≈ ln ln m / ln d + O(1)."""
    lm = math.log(max(m, 3))
    return math.log(max(lm, math.e)) / math.log(d)


def mm1_latency(lam: float, mu: float) -> float:
    """E[T] = 1/(μ−λ), λ<μ (paper §V-B)."""
    if lam >= mu:
        return float("inf")
    return 1.0 / (mu - lam)


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def balls_into_bins(key: jnp.ndarray, n_balls: int, m: int,
                    d: int) -> jnp.ndarray:
    """Sequential balls-into-bins with d choices; returns final loads (m,)."""
    def place(loads, k):
        cand = jax.random.randint(k, (d,), 0, m)
        tie = jax.random.uniform(jax.random.fold_in(k, 1), (d,)) * 1e-3
        j = cand[jnp.argmin(loads[cand] + tie)]
        return loads.at[j].add(1.0), None

    keys = jax.random.split(key, n_balls)
    loads, _ = jax.lax.scan(place, jnp.zeros((m,), jnp.float32), keys)
    return loads


def maxload_gap_empirical(n_balls: int, m: int, d: int, trials: int = 20,
                          seed: int = 0) -> Tuple[float, float]:
    """(mean gap above average load, std) across trials."""
    keys = jax.random.split(jax.random.PRNGKey(seed), trials)
    loads = jax.vmap(lambda k: balls_into_bins(k, n_balls, m, d))(keys)
    gaps = jnp.max(loads, axis=1) - n_balls / m
    return float(jnp.mean(gaps)), float(jnp.std(gaps))
