"""Compatibility shim — routing policies now live in ``repro.core.policies``.

Each policy is a self-contained registered module (see
``repro/core/policies/__init__.py`` and DESIGN.md §2).  The functional
kernels (``route_*``) are re-exported here unchanged; the state containers
were split per policy and renamed — the old monolithic ``RouterState`` /
``init_router`` are gone, replaced by ``MidasState`` / ``init_midas`` (pin
+ leaky-bucket state) and ``RRState`` / ``init_rr`` (per-proxy counters).
New code should import from the policy modules directly.
"""

from __future__ import annotations

from repro.core.policies.base import (  # noqa: F401
    RouteStats,
    sample_candidates,
    steering_dv,
)
from repro.core.policies.bounded_load import route_bounded_load  # noqa: F401
from repro.core.policies.jsq import route_jsq  # noqa: F401
from repro.core.policies.midas import (  # noqa: F401
    MidasState,
    MidasTickStats,
    init_midas,
    route_midas,
)
from repro.core.policies.power_of_d import route_power_of_d  # noqa: F401
from repro.core.policies.round_robin import (  # noqa: F401
    RRState,
    init_rr,
    route_round_robin,
    route_rr_per_request,
)
from repro.core.policies.static_hash import route_hash  # noqa: F401
from repro.core.policies.uniform import route_uniform  # noqa: F401
