"""Routing policies: round-robin (Lustre baseline), uniform, static
consistent-hash, power-of-d (paper's headline), and full MIDAS.

Faithfulness notes:
  * Proxies act on *stale* telemetry — the EWMA view from the last fast-loop
    ingest (≤ one fast interval of delay, paper assumption 1) — never on
    instantaneous queue state.
  * MIDAS steering needs BOTH margins:  L̂_j ≤ L̂_p − Δ_L  and
    p̃50_j ≤ p̃50_p − Δ_t;  winner is argmin L̂ with random tie-break.
  * Steered keys are pinned to their chosen server for C ms.
  * A sliding-window leaky bucket caps steered/eligible ≤ f_max exactly.
  * Round-robin is run by P independent proxies with random phases, which is
    how RR actually behaves at scale (aggregate ≈ random placement).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import hashring


class RouterState(NamedTuple):
    rr_count: jnp.ndarray     # (P,) int32 per-proxy RR counters
    rr_phase: jnp.ndarray     # (P,) int32 per-proxy RR phases
    pin_server: jnp.ndarray   # (N,) int32 pinned server per key (-1 none)
    pin_expiry: jnp.ndarray   # (N,) float32 absolute pin expiry (ms)
    steer_hist: jnp.ndarray   # (W,) float32 per-tick steered counts
    elig_hist: jnp.ndarray    # (W,) float32 per-tick eligible counts
    hist_idx: jnp.ndarray     # () int32


def init_router(P: int, N: int, W_ticks: int, seed: int = 0) -> RouterState:
    phases = jax.random.randint(jax.random.PRNGKey(seed ^ 0xA5A5), (P,),
                                0, 1_000_000, dtype=jnp.int32)
    return RouterState(
        rr_count=jnp.zeros((P,), jnp.int32),
        rr_phase=phases,
        pin_server=jnp.full((N,), -1, jnp.int32),
        pin_expiry=jnp.zeros((N,), jnp.float32),
        steer_hist=jnp.zeros((W_ticks,), jnp.float32),
        elig_hist=jnp.zeros((W_ticks,), jnp.float32),
        hist_idx=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------


def route_round_robin(keys: jnp.ndarray, mask: jnp.ndarray,
                      m: int) -> jnp.ndarray:
    """Lustre (Round-Robin) baseline: namespace objects are assigned to
    metadata targets *sequentially at creation time* (DNE round-robin
    striping), and every request follows its object's placement.  Object
    ids are creation-ordered, so placement is ``key mod m``.  Under skewed
    or bursty namespace access this is what produces the paper's hotspots:
    the placement never reacts to load."""
    return jnp.where(mask, (keys % m).astype(jnp.int32), -1)


def route_rr_per_request(rs: RouterState, proxy: jnp.ndarray,
                         mask: jnp.ndarray, m: int
                         ) -> Tuple[RouterState, jnp.ndarray]:
    """Ablation: P independent per-proxy per-request round-robin streams
    (ignores namespace placement entirely; not a valid metadata policy —
    requests must reach their object's server — but useful as a fairness
    upper bound on *counts*)."""
    P = rs.rr_count.shape[0]
    oh = (proxy[:, None] == jnp.arange(P)[None, :]) & mask[:, None]  # (R,P)
    prior = jnp.cumsum(oh, axis=0) - oh            # same-proxy requests before r
    rank = jnp.sum(prior * oh, axis=1)             # (R,)
    base = rs.rr_phase[proxy] + rs.rr_count[proxy]
    assign = ((base + rank) % m).astype(jnp.int32)
    new_count = rs.rr_count + jnp.sum(oh, axis=0).astype(jnp.int32)
    return rs._replace(rr_count=new_count), jnp.where(mask, assign, -1)


def route_uniform(rng: jnp.ndarray, mask: jnp.ndarray, m: int) -> jnp.ndarray:
    a = jax.random.randint(rng, mask.shape, 0, m, dtype=jnp.int32)
    return jnp.where(mask, a, -1)


def route_hash(ring: hashring.Ring, keys: jnp.ndarray,
               mask: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(mask, hashring.primary(ring, keys), -1)


# ---------------------------------------------------------------------------
# Power-of-d / MIDAS
# ---------------------------------------------------------------------------


def _sample_candidates(rng: jnp.ndarray, feas: jnp.ndarray,
                       d: jnp.ndarray) -> jnp.ndarray:
    """Mark which of the d_max feasible slots are sampled (size-d subset).

    Slot 0 (the primary) is always in S; the remaining d-1 picks are a
    uniform subset of slots 1..d_max-1 via random ranking.
    """
    R, d_max = feas.shape
    scores = jax.random.uniform(rng, (R, d_max))
    scores = scores.at[:, 0].set(-1.0)             # primary always sampled
    order = jnp.argsort(scores, axis=1)
    rank = jnp.argsort(order, axis=1)              # rank of each slot
    return rank < d                                 # (R, d_max) bool


def route_power_of_d(rng: jnp.ndarray, feas: jnp.ndarray, L_view: jnp.ndarray,
                     mask: jnp.ndarray, d) -> jnp.ndarray:
    """Pure JSQ(d) within the feasible set (paper §VI eval policy)."""
    sampled = _sample_candidates(rng, feas, d)
    load = jnp.where(sampled, L_view[feas], jnp.inf)
    # random tie-break
    tie = jax.random.uniform(jax.random.fold_in(rng, 1), feas.shape) * 1e-3
    best = jnp.argmin(load + tie, axis=1)
    assign = jnp.take_along_axis(feas, best[:, None], axis=1)[:, 0]
    return jnp.where(mask, assign, -1)


class MidasTickStats(NamedTuple):
    eligible: jnp.ndarray   # () number of steer-eligible requests
    steered: jnp.ndarray    # () number actually steered


def route_midas(rs: RouterState, rng: jnp.ndarray, keys: jnp.ndarray,
                feas: jnp.ndarray, L_view: jnp.ndarray, p50_view: jnp.ndarray,
                mask: jnp.ndarray, d, delta_l, delta_t, f_max,
                now_ms, pin_c_ms: float, w_ticks: int,
                ) -> Tuple[RouterState, jnp.ndarray, MidasTickStats]:
    """Full MIDAS routing for one request batch (Alg. 1 lines 36–47)."""
    primary = feas[:, 0]
    sampled = _sample_candidates(rng, feas, d)
    sampled = sampled.at[:, 0].set(False)          # candidates exclude primary

    Lp = L_view[primary][:, None]
    p50p = p50_view[primary][:, None]
    ok = (sampled
          & (L_view[feas] <= Lp - delta_l)
          & (p50_view[feas] <= p50p - delta_t))    # eligibility per candidate
    load = jnp.where(ok, L_view[feas], jnp.inf)
    tie = jax.random.uniform(jax.random.fold_in(rng, 2), feas.shape) * 1e-3
    best_slot = jnp.argmin(load + tie, axis=1)
    best = jnp.take_along_axis(feas, best_slot[:, None], axis=1)[:, 0]
    has_candidate = jnp.any(ok, axis=1) & mask

    # honor active pins: pinned keys go to their pinned server, no steering
    pinned = (rs.pin_expiry[keys] > now_ms) & (rs.pin_server[keys] >= 0) & mask
    # leaky bucket (exact sliding window): allow at most
    #   f_max * (eligible in window incl. now) - (steered in window)
    i = rs.hist_idx % w_ticks                     # slot about to be evicted
    elig_now = jnp.sum(has_candidate & ~pinned)
    elig_win = jnp.sum(rs.elig_hist) - rs.elig_hist[i] + elig_now
    steer_win = jnp.sum(rs.steer_hist) - rs.steer_hist[i]
    budget = jnp.floor(f_max * elig_win) - steer_win
    want = has_candidate & ~pinned
    order_rank = jnp.cumsum(want.astype(jnp.int32)) - 1
    allowed = want & (order_rank < budget)

    assign = jnp.where(pinned, rs.pin_server[keys],
                       jnp.where(allowed, best, primary))
    assign = jnp.where(mask, assign, -1)

    # pin steered keys for C ms (sentinel N is out-of-bounds => dropped)
    N = rs.pin_server.shape[0]
    steer_keys = jnp.where(allowed, keys, N)
    pin_server = rs.pin_server.at[steer_keys].set(best, mode="drop")
    pin_expiry = rs.pin_expiry.at[steer_keys].set(
        now_ms + pin_c_ms, mode="drop")

    # window histories
    steer_hist = rs.steer_hist.at[i].set(jnp.sum(allowed).astype(jnp.float32))
    elig_hist = rs.elig_hist.at[i].set(elig_now.astype(jnp.float32))

    new = rs._replace(pin_server=pin_server, pin_expiry=pin_expiry,
                      steer_hist=steer_hist, elig_hist=elig_hist,
                      hist_idx=rs.hist_idx + 1)
    stats = MidasTickStats(eligible=elig_now.astype(jnp.float32),
                           steered=jnp.sum(allowed).astype(jnp.float32))
    return new, assign, stats
