"""Shared name -> implementation registry.

Every pluggable axis of the reproduction (policies, workloads,
controllers, middleware stages, fault kinds) used to carry its own
copy-pasted ``_REGISTRY`` dict plus the same two ``ValueError`` messages.
This module is that pattern, written once: a :class:`Registry` instance
per axis, with the uniform list-alternatives error text the tests match
against::

    unknown <kind> '<name>'; available: a, b, c
    <kind> '<name>' already registered (module.Qualname)

The per-axis modules keep their public ``register / unregister /
available / get_class / get`` functions as thin delegates, so existing
imports (and third-party registrations) are untouched.

:func:`validate_choice` applies the same "unknown X; available: ..."
contract to closed enums that are not registries (consensus reducers,
cache modes, metrics modes) — ``SimConfig.__post_init__`` and
``SweepSpec`` validation both route through it.
"""

from __future__ import annotations

from typing import Callable, Dict, Sequence, Tuple, TypeVar

T = TypeVar("T")


class Registry:
    """One pluggable axis: a name -> class mapping with uniform errors.

    ``kind`` is the singular noun used in error text ("policy",
    "workload", ...).  ``name_attr`` is the class attribute stamped with
    the registered name ("name" everywhere but faults, which use
    "kind"); ``None`` skips stamping.
    """

    def __init__(self, kind: str, *, name_attr: str = "name"):
        self.kind = kind
        self.name_attr = name_attr
        self._entries: Dict[str, type] = {}

    # -- registration -----------------------------------------------------
    def register(self, name: str) -> Callable[[T], T]:
        """Class decorator: ``@REG.register("name")``.  Registering a
        DIFFERENT class under a taken name is an error (catches
        copy-paste and name collisions); re-registering the same class
        is a no-op (module re-import).  :meth:`unregister` first to
        replace deliberately."""

        def deco(cls: T) -> T:
            prev = self._entries.get(name)
            if prev is not None and prev is not cls:
                raise ValueError(
                    f"{self.kind} {name!r} already registered "
                    f"({prev.__module__}.{prev.__qualname__})"
                )
            if self.name_attr:
                setattr(cls, self.name_attr, name)
            self._entries[name] = cls
            return cls

        return deco

    def unregister(self, name: str) -> None:
        """Remove a registration (tests / deliberate replacement)."""
        self._entries.pop(name, None)

    # -- lookup -----------------------------------------------------------
    def available(self) -> Tuple[str, ...]:
        return tuple(sorted(self._entries))

    def get_class(self, name: str):
        try:
            return self._entries[name]
        except KeyError:
            raise ValueError(
                f"unknown {self.kind} {name!r}; available: "
                f"{', '.join(self.available())}"
            ) from None

    def get(self, name: str):
        return self.get_class(name)()

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __len__(self) -> int:
        return len(self._entries)


def validate_choice(
    value: str, kind: str, alternatives: Sequence[str]
) -> str:
    """Raise the uniform "unknown <kind> ...; available: ..." ValueError
    when ``value`` is not one of ``alternatives``; return it otherwise."""
    if value not in alternatives:
        raise ValueError(
            f"unknown {kind} {value!r}; available: "
            f"{', '.join(alternatives)}"
        )
    return value
