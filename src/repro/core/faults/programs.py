"""Compound fault programs: overlap, sequence, and cascade triggers.

PR 6's fault registry injects *single* events; real outages compound —
a proxy crashes DURING a checkpoint storm, brownouts roll across the
server fleet one disk at a time, a partition follows a crash because
the gossip fabric reacts to the membership flap.  This module composes
:class:`~repro.core.faults.base.FaultEvent` values into *programs* that
compile into the exact same host-side :class:`Schedule` / ``FaultXs``
machinery, so compound failures ride the scan xs with zero new engine
surface and the zero-cost-when-off golden contract intact.

Three composition forms:

* :func:`overlap` — events whose windows all intersect (the compound
  stress is the *simultaneity*); validated eagerly so a typo'd window
  fails at construction, not silently as two disjoint single faults.
* :func:`sequence` — events re-timed to fire back-to-back with a
  ``stagger`` (rolling per-server brownouts); a zero-length sequence is
  ``()``, the identically-untouched zero-fault engine.
* :class:`CascadeEvent` — event B fires at event A's *detection* time
  plus an offset.  Detection time depends on ``dt_ms`` (the heartbeat
  timeout is a wall-clock constant), so cascades resolve inside the
  fault compiler (``base._compile_cached``), where the horizon and the
  config are both known — :func:`resolve` is the host-side expansion.

Because every registered spec's ``apply`` writes monotonically into the
shared schedule (membership only clears, service scales multiply,
partitions only set, storm intensity maxes), a program's compiled
schedule equals the element-wise composition of its single-event
schedules — the property ``tests/test_core_faults.py`` pins.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.faults.base import (
    FaultEvent,
    Schedule,
    detect_available,
    detect_ticks,
    get,
)


@dataclasses.dataclass(frozen=True)
class CascadeEvent:
    """Event ``effect`` fires at ``trigger``'s detection time + offset.

    Hashable (rides ``SimConfig.faults`` next to plain events).  The
    ``effect``'s own ``t0`` is a placeholder — :func:`resolve` replaces
    it with the trigger's detection tick plus ``offset`` (in ticks).
    The trigger itself is applied too: a cascade is "A happens, and B
    follows once the system *notices* A".
    """

    trigger: FaultEvent
    effect: FaultEvent
    offset: int = 0


def _nominal_window(ev: FaultEvent) -> Tuple[int, float]:
    """[t0, t1) before horizon clipping; open-ended when duration<=0."""
    t0 = max(int(ev.t0), 0)
    t1 = float("inf") if ev.duration <= 0 else t0 + int(ev.duration)
    return t0, t1


def overlap(*events: FaultEvent) -> Tuple[FaultEvent, ...]:
    """Events that must be simultaneously active at some tick.

    Validates that every pair of windows intersects — the point of an
    overlap program is the compound stress, and two disjoint windows
    silently degenerating into independent single faults is the bug
    this check exists to catch.
    """
    evs = tuple(events)
    for i, a in enumerate(evs):
        for b in evs[i + 1 :]:
            a0, a1 = _nominal_window(a)
            b0, b1 = _nominal_window(b)
            if max(a0, b0) >= min(a1, b1):
                raise ValueError(
                    f"overlap: windows of {a!r} and {b!r} do not "
                    f"intersect; use sequence() for disjoint events"
                )
    return evs


def sequence(
    *events: FaultEvent, t0: int = None, stagger: int = None
) -> Tuple[FaultEvent, ...]:
    """Events re-timed to roll one after another.

    With ``t0``/``stagger`` given, event ``i`` starts at
    ``t0 + i * stagger`` (its duration is kept); otherwise the events'
    own timings are preserved.  ``sequence()`` is ``()`` — a zero-length
    program is the zero-fault engine (golden parity, tested).
    """
    evs = tuple(events)
    if not evs:
        return ()
    if stagger is not None and stagger < 0:
        raise ValueError(f"sequence: stagger must be >= 0, got {stagger}")
    if t0 is None and stagger is None:
        return evs
    start = evs[0].t0 if t0 is None else int(t0)
    step = stagger if stagger is not None else 0
    return tuple(
        dataclasses.replace(ev, t0=start + i * step)
        for i, ev in enumerate(evs)
    )


def rolling(
    kind: str,
    *,
    targets: Tuple[int, ...],
    t0: int,
    duration: int,
    stagger: int,
    magnitude: float = 0.5,
) -> Tuple[FaultEvent, ...]:
    """Convenience: the same fault rolling across ``targets`` — e.g.
    per-server brownouts marching down the fleet one disk at a time."""
    return sequence(
        *(
            FaultEvent(
                kind, t0=0, duration=duration, target=t, magnitude=magnitude
            )
            for t in targets
        ),
        t0=t0,
        stagger=stagger,
    )


def detection_tick(
    ev: FaultEvent, *, dt_ms: float, T: int, m: int, P: int
) -> int:
    """First tick the fault layer *notices* ``ev`` (host-side).

    Compiles the event alone and finds the first tick where detected
    membership drops (membership faults surface only after the
    heartbeat timeout — for a crash at ``t0`` that is
    ``t0 + detect_ticks(dt_ms)``).  Faults that never change detected
    membership (brownouts, partitions, storms) are "detected" at their
    first active tick; an event that never fires inside the horizon
    returns ``T``.
    """
    sched = Schedule(T, m, P)
    get(ev.kind).apply(ev, sched)
    detected = detect_available(sched.member, detect_ticks(dt_ms))
    lost = np.flatnonzero((~detected).any(axis=1))
    if lost.size:
        return int(lost[0])
    active = np.flatnonzero(sched.active)
    return int(active[0]) if active.size else T


def resolve(
    events, *, dt_ms: float, T: int, m: int, P: int
) -> Tuple[FaultEvent, ...]:
    """Expand cascade entries into plain events (fault-compiler hook).

    Each :class:`CascadeEvent` becomes its trigger plus its effect
    re-timed to ``detection_tick(trigger) + offset``; plain events pass
    through untouched.  The resolved effect's window is clipped by the
    horizon like any other event's (a trigger never detected inside the
    horizon pushes the effect past ``T``, so it never fires).
    """
    out = []
    for ev in events:
        if isinstance(ev, CascadeEvent):
            t_fire = (
                detection_tick(ev.trigger, dt_ms=dt_ms, T=T, m=m, P=P)
                + int(ev.offset)
            )
            out.append(ev.trigger)
            out.append(dataclasses.replace(ev.effect, t0=t_fire))
        else:
            out.append(ev)
    return tuple(out)
