"""Fault-event schema, registry, and the host-side schedule compiler.

A *fault* is a typed, registered event — ``proxy_crash``,
``server_brownout``, ``gossip_partition``, ... — injected into a run via
``SimConfig(faults=(...,))``.  The design constraint is the scan contract
(DESIGN.md §9): the engine's tick is jitted and sweep-vmapped, so fault
dynamics cannot branch on Python state at run time.  Instead the whole
fault program is compiled HERE, host-side, into dense time-indexed numpy
schedules (ground-truth membership, service-rate scale, gossip
partitions, storm intensity), which ride the tick scan's ``xs`` exactly
like the tick clock does — unbatched under sweep vmaps, constant-folded
where inert.

Two-plane semantics.  ``member`` is ground truth: a crashed server
serves zero requests immediately.  ``detected`` is what the *proxies*
believe: a server is presumed alive until it has been silent for
``DETECT_TIMEOUT_MS`` (the same windowed-heartbeat rule as
:class:`repro.ft.failures.FailureDetector`, property-tested against it).
Routing, feasible sets, remap invalidation, and the controller's
availability signal all follow ``detected`` — the detection latency is
precisely the hotspot window the resilience benchmark (E12) measures.

Membership epochs.  Consecutive runs of identical ``detected`` rows form
*epochs*; per-epoch subrings are built once (numpy) and the per-key
primary owner per epoch (``owner_by_epoch``) is the compile-time table
the engine diffs on an epoch flip to derive the remap-invalidation mask:
exactly the keys whose owner changed get dropped from every cache view
(consistent-hashing minimal disruption, tested as a property).

Zero-cost-when-off.  ``compile_faults`` returns ``None`` for an absent
or empty schedule, and every behavioural hook in the engine is gated on
the concrete ``has_*`` flags computed from the numpy schedules — a
benign (never-firing) schedule takes value-identical paths, so the
PR 5 golden engine is reproduced bit-for-bit (tested).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple, Type

import jax.numpy as jnp
import numpy as np

from repro.core import hashring
from repro.core import registry as registry_lib

# Detection timeout: a member silent for longer is presumed FAILED (the
# host-side reference is repro.ft.failures.FailureDetector).
DETECT_TIMEOUT_MS = 500.0
# Signals.avail below this means "detected membership degraded" — the
# cache install guard and availability-aware controllers key off it.
AVAIL_FULL = 1.0 - 1e-6
# Writer lanes a fleet-scale checkpoint storm hammers (the hot-key lane
# pattern of benchmarks/ckpt_storm.py, promoted to a registered fault).
STORM_LANES = 16


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault occurrence (hashable: rides ``SimConfig``).

    ``t0``/``duration`` are in ticks; ``duration <= 0`` means "until the
    end of the horizon".  ``target`` selects a server (or proxy, for
    ``gossip_partition``); ``-1`` picks each kind's documented default.
    ``magnitude`` is the kind-specific intensity in (0, 1].
    """

    kind: str
    t0: int = 100
    duration: int = 200
    target: int = -1
    magnitude: float = 0.5


class Schedule:
    """Mutable host-side schedule the registered specs write into."""

    def __init__(self, T: int, m: int, P: int):
        self.T, self.m, self.P = T, m, P
        self.member = np.ones((T, m), bool)
        self.service_scale = np.ones((T, m), np.float32)
        self.partition = np.zeros((T, P), bool)
        self.storm = np.zeros((T,), np.float32)
        self.active = np.zeros((T,), bool)

    def window(self, ev: FaultEvent) -> Tuple[int, int]:
        """[t0, t1) clipped to the horizon; open-ended when duration<=0."""
        t0 = max(int(ev.t0), 0)
        t1 = self.T if ev.duration <= 0 else min(t0 + int(ev.duration),
                                                 self.T)
        return min(t0, self.T), max(min(t0, self.T), t1)


class FaultSpec:
    """Base class for registered fault kinds.

    ``validate(ev, m, P)`` raises ``ValueError`` on a bad event at
    ``SimConfig`` construction time; ``apply(ev, sched)`` writes the
    event's effect into the host-side :class:`Schedule`.
    """

    kind: str = "?"

    def validate(self, ev: FaultEvent, m: int, P: int) -> None:
        pass

    def apply(self, ev: FaultEvent, sched: Schedule) -> None:
        raise NotImplementedError


REGISTRY = registry_lib.Registry("fault", name_attr="kind")


def register(kind: str):
    """Class decorator: ``@faults.register("my_fault")`` adds a
    FaultSpec subclass under ``kind`` (``SimConfig(faults=(kind,))``)."""
    return REGISTRY.register(kind)


def unregister(kind: str) -> None:
    """Remove a registered fault kind (intended for tests/plugins)."""
    REGISTRY.unregister(kind)


def available() -> Tuple[str, ...]:
    """Sorted names of every registered fault kind."""
    return REGISTRY.available()


def get_class(kind: str) -> Type[FaultSpec]:
    return REGISTRY.get_class(kind)


def get(kind: str) -> FaultSpec:
    """Instantiate the spec registered under ``kind``."""
    return REGISTRY.get(kind)


def normalize(faults) -> Tuple[FaultEvent, ...]:
    """Canonical event tuple: names become default-parameter events.

    Cascade entries (:class:`programs.CascadeEvent`) pass through — they
    stay unresolved until the compiler knows ``dt_ms`` and the horizon
    (detection time is a wall-clock property, not a config one).
    """
    if not faults:
        return ()
    from repro.core.faults import programs  # lazy: programs imports base

    out = []
    for f in faults:
        if isinstance(f, str):
            f = FaultEvent(kind=f)
        elif not isinstance(f, (FaultEvent, programs.CascadeEvent)):
            raise ValueError(
                f"SimConfig.faults entries must be fault names, "
                f"FaultEvent, or CascadeEvent, got {f!r}"
            )
        out.append(f)
    return tuple(out)


def _validate_one(ev: FaultEvent, m: int, P: int) -> None:
    get_class(ev.kind)  # raises with alternatives on unknown kind
    if ev.t0 < 0:
        raise ValueError(f"fault t0 must be >= 0, got {ev!r}")
    get(ev.kind).validate(ev, m, P)


def validate_events(faults, m: int, P: int) -> None:
    """Eager list-alternatives validation (SimConfig.__post_init__)."""
    from repro.core.faults import programs  # lazy: programs imports base

    for ev in normalize(faults):
        if isinstance(ev, programs.CascadeEvent):
            if ev.offset < 0:
                raise ValueError(f"cascade offset must be >= 0, got {ev!r}")
            _validate_one(ev.trigger, m, P)
            # the effect's t0 is a placeholder resolve() overwrites, so
            # only its kind-specific parameters are checked here
            get_class(ev.effect.kind)
            get(ev.effect.kind).validate(ev.effect, m, P)
        else:
            _validate_one(ev, m, P)


def parse_fault(spec: str) -> FaultEvent:
    """Parse ``"kind"`` or ``"kind:t0=200,duration=300,..."`` (CLI)."""
    spec = spec.strip()
    kind, _, rest = spec.partition(":")
    if kind not in REGISTRY:
        raise ValueError(
            f"unknown fault {kind!r}; available: {', '.join(available())}"
        )
    kw: Dict[str, Any] = {}
    fields = {f.name: f.type for f in dataclasses.fields(FaultEvent)}
    for tok in filter(None, (t.strip() for t in rest.split(","))):
        k, sep, v = tok.partition("=")
        if not sep or k not in fields or k == "kind":
            raise ValueError(
                f"bad fault parameter {tok!r} in {spec!r}; expected "
                f"key=value with key in t0, duration, target, magnitude"
            )
        kw[k] = float(v) if k == "magnitude" else int(v)
    return FaultEvent(kind=kind, **kw)


# ---------------------------------------------------------------------------
# Detection, epochs, and the compiled schedule
# ---------------------------------------------------------------------------


def detect_ticks(dt_ms: float) -> int:
    """Detection timeout in whole ticks (>= 1)."""
    return max(int(math.ceil(DETECT_TIMEOUT_MS / dt_ms)), 1)


def detect_available(member: np.ndarray, timeout_ticks: int) -> np.ndarray:
    """(T, m) detected-alive mask from ground-truth membership.

    A member is detected alive at tick t iff it heartbeat within the
    last ``timeout_ticks`` ticks (inclusive window [t-K, t]), with every
    member presumed alive before t=0 — the same rule as
    ``FailureDetector.failed`` with injected clocks (property-tested).
    """
    member = np.asarray(member, bool)
    T, m = member.shape
    ext = np.concatenate([np.ones((timeout_ticks, m), bool), member])
    det = np.zeros((T, m), bool)
    for j in range(timeout_ticks + 1):
        det |= ext[j:j + T]
    return det


def _epochs(detected: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Collapse (T, m) detected rows into (epoch_masks, epoch_index)."""
    T = detected.shape[0]
    masks = [detected[0]]
    idx = np.zeros((T,), np.int32)
    for t in range(1, T):
        if not np.array_equal(detected[t], masks[-1]):
            masks.append(detected[t])
        idx[t] = len(masks) - 1
    return np.stack(masks), idx


def _scan_width(m: int, V: int, masks: np.ndarray) -> int:
    """Feasible-set window wide enough to find d_max live owners: the
    default 16 slots, stretched by the worst epoch's dead fraction."""
    min_live = max(min(int(mk.sum()) for mk in masks), 1)
    return int(min(max(16, math.ceil(16 * m / min_live)), m * V))


class CompiledFaults(NamedTuple):
    """Host-compiled fault program for one (config, horizon) pair.

    All arrays are concrete numpy — they enter jitted code as constants
    (via :func:`make_xs`) or compile-time tables (``owner_by_epoch``).
    The ``has_*`` flags are Python bools: the engine's fault hooks are
    gated on them at trace time, so inert schedules cost nothing.
    """

    member: np.ndarray          # (T, m) bool ground-truth membership
    service_scale: np.ndarray   # (T, m) f32 service-rate multiplier
    partition: np.ndarray       # (T, P) bool gossip-partitioned proxies
    storm: np.ndarray           # (T,) f32 storm intensity in [0, 1]
    detected: np.ndarray        # (T, m) bool detected membership
    avail: np.ndarray           # (T,) f32 detected live fraction
    epoch: np.ndarray           # (T,) i32 membership epoch index
    epoch_prev: np.ndarray      # (T,) i32 previous tick's epoch
    epoch_masks: np.ndarray     # (E, m) bool detected mask per epoch
    owner_by_epoch: Optional[np.ndarray]  # (E, N) i32 primary per epoch
    active: np.ndarray          # (T,) bool any event window active
    timeout_ticks: int          # detection window K
    scan_width: int             # member-aware feasible-set window
    has_downtime: bool          # any ground-truth dead tick
    has_remap: bool             # >1 detected-membership epoch
    has_brownout: bool          # any service_scale != 1
    has_partition: bool         # any partitioned (proxy, tick)
    has_storm: bool             # any storm intensity > 0


class FaultXs(NamedTuple):
    """Per-tick fault rows riding the scan ``xs`` (leading T axis)."""

    member: jnp.ndarray      # (T, m) bool
    scale: jnp.ndarray       # (T, m) f32
    detected: jnp.ndarray    # (T, m) bool
    avail: jnp.ndarray       # (T,) f32
    partition: jnp.ndarray   # (T, P) bool
    epoch: jnp.ndarray       # (T,) i32
    epoch_prev: jnp.ndarray  # (T,) i32


class FaultTickInfo(NamedTuple):
    """One tick's fault context, handed to middleware via BatchView."""

    member: jnp.ndarray     # (m,) bool ground truth
    detected: jnp.ndarray   # (m,) bool detected membership
    partition: jnp.ndarray  # (P,) bool partitioned proxies
    avail: jnp.ndarray      # () f32 detected live fraction
    inval: Optional[jnp.ndarray]  # (N,) bool owner-changed keys


@functools.lru_cache(maxsize=None)
def _compile_cached(cfg, T: int) -> CompiledFaults:
    from repro.core.faults import programs  # lazy: programs imports base

    # cascade entries resolve HERE: detection time needs dt_ms + horizon
    events = programs.resolve(
        normalize(cfg.faults), dt_ms=cfg.dt_ms, T=T, m=cfg.m, P=cfg.P
    )
    sched = Schedule(T, cfg.m, cfg.P)
    for ev in events:
        get(ev.kind).apply(ev, sched)
    K = detect_ticks(cfg.dt_ms)
    detected = detect_available(sched.member, K)
    masks, epoch = _epochs(detected)
    for mk in masks:
        if not mk.any():
            raise ValueError(
                "fault schedule leaves no detected-live server in some "
                "epoch; keep at least one member alive"
            )
    epoch_prev = np.concatenate([epoch[:1], epoch[:-1]])
    has_remap = masks.shape[0] > 1
    owner_by_epoch = None
    if has_remap:
        keys = np.arange(cfg.N)
        owner_by_epoch = np.stack([
            hashring.np_member_primary(cfg.m, cfg.V, mk, keys)
            for mk in masks
        ]).astype(np.int32)
    return CompiledFaults(
        member=sched.member,
        service_scale=sched.service_scale,
        partition=sched.partition,
        storm=sched.storm,
        detected=detected,
        avail=detected.mean(axis=1).astype(np.float32),
        epoch=epoch,
        epoch_prev=epoch_prev.astype(np.int32),
        epoch_masks=masks,
        owner_by_epoch=owner_by_epoch,
        active=sched.active,
        timeout_ticks=K,
        scan_width=_scan_width(cfg.m, cfg.V, masks),
        has_downtime=bool((~sched.member).any()),
        has_remap=has_remap,
        has_brownout=bool((sched.service_scale != 1.0).any()),
        has_partition=bool(sched.partition.any()),
        has_storm=bool((sched.storm > 0.0).any()),
    )


def compile_faults(cfg, T: int) -> Optional[CompiledFaults]:
    """The compiled fault program for ``cfg`` over a T-tick horizon, or
    ``None`` when the config carries no fault events (``faults=None``
    and ``faults=()`` are both the identically-untouched engine)."""
    if not normalize(cfg.faults):
        return None
    return _compile_cached(cfg, int(T))


def make_xs(fc: CompiledFaults) -> FaultXs:
    """Device-side per-tick rows appended to the scan's xs tuple."""
    return FaultXs(
        member=jnp.asarray(fc.member),
        scale=jnp.asarray(fc.service_scale),
        detected=jnp.asarray(fc.detected),
        avail=jnp.asarray(fc.avail, jnp.float32),
        partition=jnp.asarray(fc.partition),
        epoch=jnp.asarray(fc.epoch, jnp.int32),
        epoch_prev=jnp.asarray(fc.epoch_prev, jnp.int32),
    )


def tick_info(fc: CompiledFaults, fx: FaultXs) -> FaultTickInfo:
    """One tick's fault context (``fx`` holds this tick's slices).

    The remap-invalidation mask diffs the per-epoch owner tables at the
    current vs. previous epoch — all-False except on a flip tick, where
    it marks exactly the keys whose detected-ring owner changed.
    """
    inval = None
    if fc.has_remap:
        owners = jnp.asarray(fc.owner_by_epoch)
        inval = owners[fx.epoch] != owners[fx.epoch_prev]
    return FaultTickInfo(
        member=fx.member,
        detected=fx.detected,
        partition=fx.partition,
        avail=fx.avail,
        inval=inval,
    )


def feasible_by_epoch(
    ring: hashring.Ring, keysg: jnp.ndarray, d_max: int, fc: CompiledFaults
) -> jnp.ndarray:
    """Membership-aware feasible sets for a whole (T, ...) key grid.

    One batched member-aware gather per epoch (E is tiny — one per
    membership change), then a per-tick row gather selects each tick's
    epoch — the scan engine's hoisted-feasible contract, now membership-
    aware, still O(1) trace size in T.
    """
    if not fc.has_remap:
        return hashring.feasible_set(ring, keysg, d_max)
    stacks = [
        hashring.feasible_set(
            ring, keysg, d_max,
            scan_width=fc.scan_width, member=jnp.asarray(mk),
        )
        for mk in fc.epoch_masks
    ]
    T = keysg.shape[0]
    return jnp.stack(stacks)[jnp.asarray(fc.epoch), jnp.arange(T)]


def apply_traffic(
    fc: CompiledFaults,
    keys: jnp.ndarray,
    mask: jnp.ndarray,
    is_write: jnp.ndarray,
):
    """Overlay storm traffic on a (T, R) workload grid.

    A storm of intensity s activates the trailing s-fraction of each
    tick's inactive request slots as WRITES against the hot writer-lane
    keys (r mod STORM_LANES) — the ckpt_storm lane pattern at fleet
    scale.  Inactive-tail slots keep the base workload untouched.
    """
    if not fc.has_storm:
        return keys, mask, is_write
    s = jnp.asarray(fc.storm)[:, None]
    R = keys.shape[-1]
    r = jnp.arange(R, dtype=jnp.int32)
    tail_frac = (R - r.astype(jnp.float32) - 0.5) / R
    extra = (~mask) & (tail_frac[None, :] < s)
    lane_keys = (r % STORM_LANES).astype(keys.dtype)
    keys = jnp.where(extra, lane_keys[None, :], keys)
    return keys, mask | extra, is_write | extra
