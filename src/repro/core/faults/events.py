"""Registered fault events (the built-in fault vocabulary).

Each spec documents its schedule effect; detection / epochs / remap
invalidation are shared machinery in :mod:`repro.core.faults.base`.
"""

from __future__ import annotations

import numpy as np

from repro.core.faults.base import (
    FaultEvent,
    FaultSpec,
    Schedule,
    register,
)


def _server(ev: FaultEvent, m: int) -> int:
    """Resolve a server target: -1 means the last server (m-1)."""
    return m - 1 if ev.target < 0 else ev.target


def _check_server(ev: FaultEvent, m: int) -> None:
    if not -1 <= ev.target < m:
        raise ValueError(
            f"fault {ev.kind!r} target must be a server in [0, {m}) "
            f"or -1, got {ev.target}"
        )


def _check_magnitude(ev: FaultEvent) -> None:
    if not 0.0 < ev.magnitude <= 1.0:
        raise ValueError(
            f"fault {ev.kind!r} magnitude must be in (0, 1], "
            f"got {ev.magnitude}"
        )


@register("proxy_crash")
class ProxyCrash(FaultSpec):
    """A metadata server vanishes for the event window: it serves zero
    requests immediately (ground truth), but proxies keep routing to it
    until the heartbeat timeout expires — then the detected ring drops
    it, its keys remap to ring successors, and remapped cache entries
    are invalidated.  Rejoin at the window's end runs the same epoch
    flip in reverse."""

    def validate(self, ev: FaultEvent, m: int, P: int) -> None:
        _check_server(ev, m)

    def apply(self, ev: FaultEvent, sched: Schedule) -> None:
        t0, t1 = sched.window(ev)
        sched.member[t0:t1, _server(ev, sched.m)] = False
        sched.active[t0:t1] = True


@register("proxy_join")
class ProxyJoin(FaultSpec):
    """A server is ABSENT from the start of the run and joins at t0 —
    the cold-start half of elastic membership.  Its keys remap onto it
    at join (heartbeats make detection immediate), forcing the caches
    to revalidate every entry the newcomer now owns.  ``duration`` is
    ignored; the fault window is [0, t0)."""

    def validate(self, ev: FaultEvent, m: int, P: int) -> None:
        _check_server(ev, m)
        if m < 2:
            raise ValueError(
                "proxy_join needs m >= 2: the ring must stay non-empty "
                "before the join"
            )

    def apply(self, ev: FaultEvent, sched: Schedule) -> None:
        t0 = min(max(int(ev.t0), 0), sched.T)
        sched.member[:t0, _server(ev, sched.m)] = False
        sched.active[:t0] = True


@register("server_brownout")
class ServerBrownout(FaultSpec):
    """Time-varying MDS degradation: the target server's service rate
    is multiplied by ``magnitude`` for the window (a slow disk, a noisy
    neighbour).  Membership never changes — the ring stays put and the
    controller only sees the brownout through queue telemetry."""

    def validate(self, ev: FaultEvent, m: int, P: int) -> None:
        _check_server(ev, m)
        _check_magnitude(ev)

    def apply(self, ev: FaultEvent, sched: Schedule) -> None:
        t0, t1 = sched.window(ev)
        sched.service_scale[t0:t1, _server(ev, sched.m)] *= ev.magnitude
        sched.active[t0:t1] = True


@register("gossip_partition")
class GossipPartition(FaultSpec):
    """Gossip stops reaching the target proxy (-1: every proxy) for the
    window: remote installs/invalidations become invisible to it until
    the partition heals, spiking its stale-serve exposure — the fleet's
    per-proxy staleness failure mode (E9's worst case, injected)."""

    def validate(self, ev: FaultEvent, m: int, P: int) -> None:
        if not -1 <= ev.target < P:
            raise ValueError(
                f"gossip_partition target must be a proxy in [0, {P}) "
                f"or -1 (all), got {ev.target}"
            )

    def apply(self, ev: FaultEvent, sched: Schedule) -> None:
        t0, t1 = sched.window(ev)
        if ev.target < 0:
            sched.partition[t0:t1, :] = True
        else:
            sched.partition[t0:t1, ev.target] = True
        sched.active[t0:t1] = True


@register("ckpt_storm_fleet")
class CkptStormFleet(FaultSpec):
    """Fleet-scale checkpoint storm: for the window, the trailing
    ``magnitude`` fraction of each tick's idle request slots fire as
    WRITES against the ``STORM_LANES`` hot writer-lane keys — the
    benchmarks/ckpt_storm.py lane pattern promoted to a registered
    fault.  Write-heavy hot keys stress the install guard and lease
    invalidation rather than the ring."""

    def validate(self, ev: FaultEvent, m: int, P: int) -> None:
        _check_magnitude(ev)

    def apply(self, ev: FaultEvent, sched: Schedule) -> None:
        t0, t1 = sched.window(ev)
        sched.storm[t0:t1] = np.maximum(sched.storm[t0:t1], ev.magnitude)
        sched.active[t0:t1] = True


def storm_from_pool(pool, t0: int = 100, duration: int = 200) -> FaultEvent:
    """A ``ckpt_storm_fleet`` event calibrated from a live
    :class:`repro.ckpt.midas_writer.WriterPool`: intensity is the worst
    lane's share of the queued backlog (1.0 = one lane holds
    everything), via the public ``backlogs()`` accessor."""
    b = [float(x) for x in pool.backlogs()]
    total = sum(b)
    mag = (max(b) / total) if total > 0 and b else 1.0 / max(len(b), 1)
    return FaultEvent(
        kind="ckpt_storm_fleet",
        t0=t0,
        duration=duration,
        magnitude=min(max(mag, 1e-3), 1.0),
    )
