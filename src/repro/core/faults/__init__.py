# The fourth registry (after policies, workloads, controllers): typed
# fault events compiled host-side into time-indexed schedules that ride
# the engine's scan xs — jittable, sweep-vmappable, and provably
# zero-cost when no event fires.  See base.py for the schema and
# events.py for the built-in vocabulary.
from repro.core.faults import events  # noqa: F401  (registration)
from repro.core.faults.base import (  # noqa: F401
    AVAIL_FULL,
    DETECT_TIMEOUT_MS,
    STORM_LANES,
    CompiledFaults,
    FaultEvent,
    FaultSpec,
    FaultTickInfo,
    FaultXs,
    Schedule,
    apply_traffic,
    available,
    compile_faults,
    detect_available,
    detect_ticks,
    feasible_by_epoch,
    get,
    get_class,
    make_xs,
    normalize,
    parse_fault,
    register,
    tick_info,
    unregister,
    validate_events,
)
from repro.core.faults.events import storm_from_pool  # noqa: F401
from repro.core.faults.programs import (  # noqa: F401
    CascadeEvent,
    detection_tick,
    overlap,
    resolve,
    rolling,
    sequence,
)
