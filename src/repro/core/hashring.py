"""Consistent hashing with virtual nodes, fully vectorized in JAX.

The middleware "consults the hash table already maintained by the MDS" —
modeled as a consistent-hash ring with V virtual nodes per server.  The ring
gives (i) a stable primary placement per key and (ii) the namespace-feasible
set F(r): the next ``d_max`` *distinct* servers clockwise of the key's
position (the standard replica-successor set, which is what keeps steering
consistent with namespace locality).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

_GOLDEN = jnp.uint32(0x9E3779B9)


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 finalizer — deterministic uint32 mixing."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def hash2(a: jnp.ndarray, b) -> jnp.ndarray:
    """Hash a pair of uint32s."""
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    return mix32(a ^ (mix32(b) + _GOLDEN + (a << 6) + (a >> 2)))


class Ring(NamedTuple):
    positions: jnp.ndarray  # (m*V,) uint32, sorted ring positions
    owners: jnp.ndarray  # (m*V,) int32, owning server per position
    m: int  # number of servers
    V: int  # virtual nodes per server


def _np_mix32(x: np.ndarray) -> np.ndarray:
    """Numpy replica of :func:`mix32` (uint32 arithmetic wraps mod 2^32)."""
    x = np.asarray(x, np.uint32).copy()
    x ^= x >> np.uint32(16)
    x *= np.uint32(0x85EBCA6B)
    x ^= x >> np.uint32(13)
    x *= np.uint32(0xC2B2AE35)
    x ^= x >> np.uint32(16)
    return x


def _np_hash2(a: np.ndarray, b) -> np.ndarray:
    a = np.asarray(a, np.uint32)
    b = np.asarray(b, np.uint32)
    return _np_mix32(
        a
        ^ (
            _np_mix32(b)
            + np.uint32(0x9E3779B9)
            + (a << np.uint32(6))
            + (a >> np.uint32(2))
        )
    )


def _ring_arrays(m: int, V: int, salt: int):
    """Pure-numpy ring builder; memoization happens in the caller."""
    servers = np.repeat(np.arange(m, dtype=np.uint32), V)
    replicas = np.tile(np.arange(V, dtype=np.uint32), m)
    pos = _np_hash2(
        servers * np.uint32(0x10001) + replicas, np.uint32(salt + 1)
    )
    order = np.argsort(pos, kind="stable")
    return pos[order], servers[order].astype(np.int32)


@functools.lru_cache(maxsize=None)
def _make_ring_cached(m: int, V: int, salt: int) -> Ring:
    """Memoized host-side: re-tracing ``_run_scan`` reuses the concrete
    positions/owners instead of rebuilding the ring."""
    pos, owners = _ring_arrays(m, V, salt)
    return Ring(
        positions=jnp.asarray(pos), owners=jnp.asarray(owners), m=m, V=V
    )


def make_ring(m: int, V: int = 64, salt: int = 0) -> Ring:
    """Memoized ring: repeat calls (and re-traces) return the same object,
    whose arrays become compile-time constants inside ``jax.jit``."""
    return _make_ring_cached(int(m), int(V), int(salt))


def key_position(keys: jnp.ndarray, salt: int = 0) -> jnp.ndarray:
    return hash2(keys.astype(jnp.uint32), jnp.uint32(salt + 7919))


def primary(ring: Ring, keys: jnp.ndarray) -> jnp.ndarray:
    """Primary server for each key (first owner clockwise)."""
    pos = key_position(keys)
    idx = jnp.searchsorted(ring.positions, pos) % ring.positions.shape[0]
    return ring.owners[idx]


def np_key_position(keys: np.ndarray, salt: int = 0) -> np.ndarray:
    """Numpy replica of :func:`key_position` (same hash, same salt)."""
    return _np_hash2(np.asarray(keys, np.uint32), np.uint32(salt + 7919))


def np_member_primary(
    m: int, V: int, member: np.ndarray, keys: np.ndarray, salt: int = 0
) -> np.ndarray:
    """Primary owner per key under live membership (numpy reference).

    Builds the full ring, drops every virtual node owned by a dead
    server, and walks the *subring* — the canonical consistent-hashing
    semantics of membership change: keys whose live owner is unchanged
    never move (minimal disruption, property-tested), and with all
    members live this reduces exactly to :func:`primary`.  This is the
    reference the member-aware :func:`feasible_set` window (and the
    fault engine's per-epoch ``owner_by_epoch`` tables, which are built
    from it) are tested against.
    """
    member = np.asarray(member, bool)
    if member.shape != (m,):
        raise ValueError(
            f"member mask must have shape ({m},), got {member.shape}"
        )
    if not member.any():
        raise ValueError("membership has no live servers")
    pos, owners = _ring_arrays(m, V, salt)
    keep = member[owners]
    pos, owners = pos[keep], owners[keep]
    kp = np_key_position(np.asarray(keys), salt)
    idx = np.searchsorted(pos, kp) % pos.size
    return owners[idx]


# ---------------------------------------------------------------------------
# Per-shard subrings (DESIGN.md §12)
# ---------------------------------------------------------------------------
#
# The sharded sweep / E11's million-key ring audit never materialize
# O(R·P): the position space [0, 2^32) is cut into ``n_shards`` equal
# arcs, and a shard resolves ONLY keys hashing into its arc, using the
# ring slots inside the arc plus a ``tail`` of wrap-around successors
# (enough for the feasible-set window).  Each subring is
# O(m·V/n_shards + tail) — ownership is identical to the global ring
# (bit-for-bit, property-tested: per-shard ownership unions/partitions
# to the global ring).


class Subring(NamedTuple):
    """The slice of a ring owning one arc of the position space."""

    positions: np.ndarray  # (n_arc + tail,) uint32: sorted arc, then
    owners: np.ndarray  # wrap-around successor slots (may re-wrap)
    n_arc: int  # slots whose position lies inside [lo, hi)
    lo: int  # arc start position (inclusive)
    hi: int  # arc end position (exclusive)
    shard: int
    n_shards: int
    m: int
    V: int


def np_key_shard(
    keys: np.ndarray, n_shards: int, salt: int = 0
) -> np.ndarray:
    """Which shard's arc each key's ring position falls in."""
    q = np_key_position(np.asarray(keys), salt).astype(np.uint64)
    return (q * np.uint64(n_shards) >> np.uint64(32)).astype(np.int32)


def np_subring(
    m: int,
    V: int,
    shard: int,
    n_shards: int,
    salt: int = 0,
    tail: int = 16,
) -> Subring:
    """Build shard ``shard`` of an ``n_shards``-way ring partition.

    ``tail`` successor slots past the arc let the shard resolve keys
    landing after its last in-arc position and give :func:`feasible_set`
    -compatible windows; it must be >= the intended ``scan_width``.
    """
    if not 0 <= shard < n_shards:
        raise ValueError(
            f"shard must be in [0, {n_shards}), got {shard}"
        )
    pos, owners = _ring_arrays(m, V, salt)
    n = pos.size
    lo = (shard * (1 << 32)) // n_shards
    hi = ((shard + 1) * (1 << 32)) // n_shards
    start = int(np.searchsorted(pos, np.uint32(lo), side="left"))
    end = (
        n
        if hi == (1 << 32)
        else int(np.searchsorted(pos, np.uint32(hi), side="left"))
    )
    idx = np.arange(start, end + tail) % n
    return Subring(
        positions=pos[idx],
        owners=owners[idx],
        n_arc=end - start,
        lo=lo,
        hi=hi,
        shard=shard,
        n_shards=n_shards,
        m=m,
        V=V,
    )


def np_subring_primary(
    sub: Subring, keys: np.ndarray, salt: int = 0
) -> np.ndarray:
    """Primary owner per key, resolved from the subring alone.

    Every key must hash into the subring's arc (route by
    :func:`np_key_shard` first); results are bit-for-bit
    :func:`primary` on the global ring.
    """
    kp = np_key_position(np.asarray(keys), salt)
    if kp.size and (
        (kp.astype(np.uint64) < sub.lo).any()
        or (kp.astype(np.uint64) >= sub.hi).any()
    ):
        raise ValueError(
            f"keys outside shard {sub.shard}/{sub.n_shards}'s arc; "
            f"route with np_key_shard first"
        )
    # the arc prefix is sorted, so a local searchsorted lands on the
    # first in-arc slot >= kp; past-the-arc keys fall through to the
    # first wrap-around successor (local index n_arc)
    li = np.searchsorted(sub.positions[: sub.n_arc], kp)
    return sub.owners[li]


def np_subring_feasible(
    sub: Subring, keys: np.ndarray, d_max: int, scan_width: int = 16,
    salt: int = 0,
) -> np.ndarray:
    """F(r) from the subring alone: first ``d_max`` distinct owners
    clockwise, scanning ``scan_width`` slots — the numpy mirror of
    :func:`feasible_set` (member-free path), valid for keys in the
    shard's arc.  Requires ``sub.tail >= scan_width`` (the default
    :func:`np_subring` tail)."""
    if sub.positions.size - sub.n_arc < scan_width:
        raise ValueError(
            f"subring tail {sub.positions.size - sub.n_arc} < "
            f"scan_width {scan_width}; rebuild with a larger tail"
        )
    kp = np_key_position(np.asarray(keys), salt)
    li = np.searchsorted(sub.positions[: sub.n_arc], kp)
    cand = sub.owners[li[..., None] + np.arange(scan_width)]  # (..., W)
    eq = cand[..., None, :] == cand[..., :, None]
    seen_before = np.any(eq & _strict_lower(scan_width), axis=-1)
    fresh = ~seen_before
    rank = np.cumsum(fresh, axis=-1) - 1
    rank = np.where(fresh, rank, scan_width)
    take = rank[..., None] == np.arange(d_max)
    out = np.max(
        np.where(take, cand[..., :, None], np.int32(-1)), axis=-2
    )
    pad = (out[..., :1] + np.arange(d_max, dtype=np.int32)) % sub.m
    return np.where(out < 0, pad, out).astype(np.int32)


@functools.lru_cache(maxsize=None)
def _strict_lower(scan_width: int) -> np.ndarray:
    """Strict lower-triangular mask, built host-side once per width so it
    enters every trace as a ready constant."""
    return np.tril(np.ones((scan_width, scan_width), bool), k=-1)


def feasible_set(
    ring: Ring,
    keys: jnp.ndarray,
    d_max: int,
    scan_width: int = 16,
    member=None,
) -> jnp.ndarray:
    """F(r): the first ``d_max`` distinct servers clockwise of each key.

    Returns (..., d_max) int32; entry 0 is the primary.  Scans
    ``scan_width`` consecutive ring slots, keeps first occurrences, and (in
    the degenerate case of fewer distinct owners than d_max within the
    window) pads deterministically with (primary + i) mod m.

    ``member`` (optional (m,) bool) restricts F(r) to LIVE servers: dead
    owners are skipped by the first-occurrence scan exactly as if their
    virtual nodes left the ring, so entry 0 becomes the subring primary
    (:func:`np_member_primary`) whenever a live owner falls inside the
    window — callers with dead members should widen ``scan_width``
    accordingly (the fault compiler does).  The fallback pad walks
    server indices (primary + i) mod m and keeps the first live ones;
    with every member live the result is bit-for-bit the member-free
    path.  ``member`` must have at least one live server.

    Every op is elementwise in ``keys``, so arbitrary leading batch axes
    are supported — the engine exploits this to gather all G routing
    waves in ONE call per tick (a (G, R/G) key matrix) instead of G
    per-wave calls, with identical per-key results.
    """
    n = ring.positions.shape[0]
    pos = key_position(keys)
    base = jnp.searchsorted(ring.positions, pos) % n
    offs = jnp.arange(scan_width, dtype=jnp.int32)
    idx = (base[..., None] + offs) % n
    cand = ring.owners[idx]  # (..., W)
    # first-occurrence mask: cand[j] not among cand[:j]
    eq = cand[..., None, :] == cand[..., :, None]  # (..., W, W)
    lower = jnp.asarray(_strict_lower(scan_width))
    seen_before = jnp.any(eq & lower, axis=-1)  # (..., W)
    fresh = ~seen_before
    if member is not None:
        # dead owners neither claim a rank nor appear in the output
        fresh = fresh & jnp.asarray(member)[cand]
    # rank among fresh entries
    rank = jnp.cumsum(fresh.astype(jnp.int32), axis=-1) - 1
    rank = jnp.where(fresh, rank, scan_width)
    # scatter fresh candidates into their rank slot
    take = jnp.where(rank[..., None] == jnp.arange(d_max), 1, 0)
    out = jnp.max(
        jnp.where(take.astype(bool), cand[..., :, None], jnp.int32(-1)),
        axis=-2,
    )
    if member is None:
        # pad any remaining -1 deterministically
        pad = (out[..., :1] + jnp.arange(d_max, dtype=jnp.int32)) % ring.m
        return jnp.where(out < 0, pad, out)
    # live-aware pad: first live servers along (raw primary + i) mod m —
    # identical to the member-free pad when every server is live
    rot = (cand[..., :1] + jnp.arange(ring.m, dtype=jnp.int32)) % ring.m
    liv = jnp.asarray(member)[rot]
    lrank = jnp.cumsum(liv.astype(jnp.int32), axis=-1) - 1
    lrank = jnp.where(liv, lrank, ring.m)
    take2 = lrank[..., None] == jnp.arange(d_max)
    fb = jnp.max(
        jnp.where(take2, rot[..., :, None], jnp.int32(-1)), axis=-2
    )
    # fewer live servers than d_max: repeat the first live fallback
    fb = jnp.where(fb < 0, fb[..., :1], fb)
    return jnp.where(out < 0, fb, out)
