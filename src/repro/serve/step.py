"""Serving step functions (prefill + decode) for pjit."""
from __future__ import annotations


import jax.numpy as jnp

from repro import models
from repro.config import ArchConfig, RunConfig


def make_prefill_step(cfg: ArchConfig, run: RunConfig,
                      cache_len: int | None = None):
    cache_dtype = jnp.dtype(run.decode_kv_dtype)

    def prefill_step(params, batch):
        return models.prefill(params, cfg, batch, cache_len=cache_len,
                              cache_dtype=cache_dtype)

    return prefill_step


def make_serve_step(cfg: ArchConfig, run: RunConfig):
    def serve_step(params, cache, tokens, pos):
        logits, new_cache = models.decode_step(params, cfg, cache, tokens,
                                               pos)
        next_token = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return next_token.astype(jnp.int32), new_cache

    return serve_step
