"""MIDAS serving-request router: the paper's full policy at the replica
level.

Sessions are consistent-hashed to replica groups (KV-cache affinity ==
namespace locality); new requests may steer within the feasible replica
set by power-of-d on queue telemetry under the Δ_L/Δ_t margins, pinned for
C ms (a migrated session implies a prefix re-prefill, so flapping is
expensive — exactly the paper's pinning rationale); a leaky bucket caps
aggregate steering; a cooperative prefix cache with lease invalidation
serves repeated prefixes at the router tier.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from repro.core.hashring import hash2


@dataclasses.dataclass
class Replica:
    queue_len: float = 0.0
    ewma_queue: float = 0.0
    p50_ms: float = 0.0
    busy_until: float = 0.0


class RouterStats(NamedTuple):
    routed: int
    steered: int
    cache_hits: int


class MidasRouter:
    def __init__(self, replicas: int, *, d: int = 2, delta_l: float = 2.0,
                 f_max: float = 0.25, pin_ms: float = 300.0,
                 alpha: float = 0.2, policy: str = "midas",
                 prefix_cache: bool = True):
        self.n = replicas
        self.replicas = [Replica() for _ in range(replicas)]
        self.d = d
        self.delta_l = delta_l
        self.f_max = f_max
        self.pin_ms = pin_ms
        self.alpha = alpha
        self.policy = policy
        self.prefix_cache_enabled = prefix_cache
        self._pins: Dict[int, Tuple[int, float]] = {}
        self._prefix_cache: Dict[int, int] = {}     # prefix hash -> version
        self._rr = 0
        self._steered = 0
        self._routed = 0
        self._hits = 0
        self._window: List[Tuple[float, bool]] = []  # (t, steered)

    # -------------------------------------------------------------- helpers
    def _feasible(self, session: int) -> List[int]:
        prim = int(hash2(np.uint32(session), np.uint32(5))) % self.n
        feas = [prim]
        i = 1
        while len(feas) < min(4, self.n):
            c = int(hash2(np.uint32(session * 131 + i), np.uint32(11))
                    ) % self.n
            if c not in feas:
                feas.append(c)
            i += 1
        return feas

    def ingest_telemetry(self) -> None:
        """Fast-loop EWMA over replica queue lengths (stale view)."""
        for r in self.replicas:
            r.ewma_queue = ((1 - self.alpha) * r.ewma_queue
                            + self.alpha * r.queue_len)

    # ---------------------------------------------------------------- route
    def route(self, session: int, now_ms: float,
              prefix_hash: Optional[int] = None) -> Tuple[int, bool, bool]:
        """Returns (replica, steered, cache_hit)."""
        self._routed += 1
        hit = False
        if self.prefix_cache_enabled and prefix_hash is not None:
            hit = prefix_hash in self._prefix_cache
            if not hit:
                self._prefix_cache[prefix_hash] = 1
            else:
                self._hits += 1

        if self.policy == "round_robin":
            self._rr += 1
            target = self._rr % self.n
            self.replicas[target].queue_len += 0 if hit else 1
            return target, False, hit

        feas = self._feasible(session)
        prim = feas[0]
        pin = self._pins.get(session)
        if pin is not None and pin[1] > now_ms:
            target = pin[0]
            self.replicas[target].queue_len += 0 if hit else 1
            return target, False, hit

        target, steered = prim, False
        if self.policy == "midas" and len(feas) > 1:
            cands = feas[1:self.d + 1 - 1] if self.d > 1 else []
            q = lambda i: self.replicas[i].ewma_queue
            ok = [c for c in cands if q(c) <= q(prim) - self.delta_l]
            # leaky bucket over the last 1 s window
            self._window = [(t, s) for (t, s) in self._window
                            if t > now_ms - 1000.0]
            steers = sum(1 for _, s in self._window if s)
            allowed = steers + 1 <= self.f_max * (len(self._window) + 1)
            if ok and allowed:
                target = min(ok, key=q)
                steered = True
                self._steered += 1
                self._pins[session] = (target, now_ms + self.pin_ms)
            self._window.append((now_ms, steered))
        self.replicas[target].queue_len += 0 if hit else 1
        return target, steered, hit

    def complete(self, replica: int, n: int = 1) -> None:
        self.replicas[replica].queue_len = max(
            0.0, self.replicas[replica].queue_len - n)

    def invalidate_prefix(self, prefix_hash: int) -> None:
        self._prefix_cache.pop(prefix_hash, None)   # lease-style coherence

    # ---------------------------------------------------------------- stats
    def stats(self) -> RouterStats:
        return RouterStats(self._routed, self._steered, self._hits)

    def queue_dispersion(self) -> float:
        q = np.asarray([r.queue_len for r in self.replicas])
        return float(q.std() / max(q.mean(), 1e-9))
