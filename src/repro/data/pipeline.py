"""Deterministic, seekable, sharded data pipeline.

Restart-exactness is the fault-tolerance contract: ``batch_at(step)`` is a
pure function of (seed, step, host), so resuming from a checkpoint at step
N replays the identical stream with zero coordination — the property that
makes 1000-node restarts cheap.  A background prefetch thread hides
generation latency; MIDAS balancing assigns heterogeneous file shards to
hosts (see balance.py).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np

from repro.config import ArchConfig


class SyntheticLM:
    """Deterministic synthetic token stream (hash-based, O(1) seek)."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, *,
                 seed: int = 0, host: int = 0, num_hosts: int = 1):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.host = host
        self.num_hosts = num_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host)
        cfg = self.cfg
        if cfg.frontend == "audio_frames":
            return {
                "frames": rng.normal(0, 0.02, (self.batch, self.seq,
                                               cfg.d_model)
                                     ).astype(np.float32),
                "labels": rng.integers(0, cfg.vocab_size,
                                       (self.batch, self.seq)
                                       ).astype(np.int32),
            }
        if cfg.frontend == "vlm_patches":
            P = cfg.frontend_tokens
            return {
                "tokens": rng.integers(0, cfg.vocab_size,
                                       (self.batch, self.seq - P)
                                       ).astype(np.int32),
                "patches": rng.normal(0, 0.02, (self.batch, P, cfg.d_model)
                                      ).astype(np.float32),
            }
        # mildly zipfian token stream so losses actually move
        z = rng.zipf(1.3, (self.batch, self.seq))
        return {"tokens": (z % cfg.vocab_size).astype(np.int32)}


class Prefetcher:
    """Background prefetch with bounded queue; restart-exact via start_step."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: int = 2):
        self._source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self._source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
