from repro.data.balance import assign_shards, host_load_cv  # noqa: F401
from repro.data.pipeline import Prefetcher, SyntheticLM  # noqa: F401
