"""MIDAS shard→host balancing for heterogeneous data shards.

File shards in real corpora are skewed (some 10x larger).  Static
round-robin assignment gives some hosts 2x the bytes => stragglers every
epoch.  We reuse the paper's policy one more time: hosts are servers,
shards are requests keyed by shard id, load = assigned bytes."""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.core.hashring import hash2


def assign_shards(shard_bytes: Sequence[int], num_hosts: int, *,
                  policy: str = "midas", d: int = 2,
                  delta_frac: float = 0.05) -> List[int]:
    """Returns host index per shard.  delta_frac: steering margin as a
    fraction of mean host load (Δ_L analogue)."""
    loads = np.zeros(num_hosts, np.float64)
    out = []
    mean_total = max(sum(shard_bytes) / num_hosts, 1.0)
    for i, nbytes in enumerate(shard_bytes):
        if policy == "round_robin":
            h = i % num_hosts
        else:
            primary = int(hash2(np.uint32(i), np.uint32(3))) % num_hosts
            h = primary
            if policy == "midas":
                cands = [int(hash2(np.uint32(i * 31 + j + 1),
                                   np.uint32(7))) % num_hosts
                         for j in range(d - 1)]
                best = min(cands, key=lambda c: loads[c])
                if loads[primary] - loads[best] >= delta_frac * mean_total:
                    h = best
        loads[h] += nbytes
        out.append(h)
    return out


def host_load_cv(shard_bytes: Sequence[int], assignment: Sequence[int],
                 num_hosts: int) -> float:
    loads = np.zeros(num_hosts, np.float64)
    for b, h in zip(shard_bytes, assignment):
        loads[h] += b
    return float(loads.std() / max(loads.mean(), 1e-9))
