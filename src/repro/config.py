"""Config system for repro: architectures, shapes, meshes, run options.

Everything is a frozen dataclass so configs are hashable (usable as jit
static args) and safely shareable.  Architectures register themselves into
``ARCH_REGISTRY`` via :func:`register_arch`; input shapes are global and
paired per-arch through ``applicable_shapes``.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    experts_per_token: int = 0          # top-k
    d_ff_expert: int = 0                # per-expert hidden dim
    router: str = "topk"                # "topk" | "midas"
    capacity_factor: float = 1.25
    # MIDAS dispatch knobs (paper Alg. 1 adapted to expert dispatch)
    midas_d: int = 2            # power-of-d sample among top-d gate
                                # candidates
    midas_delta_l: int = 2              # queue margin (Lyapunov-stable >= 2)
    midas_fmax: float = 0.25            # steering cap (fraction of tokens)
    midas_ewma_alpha: float = 0.2       # EWMA on per-expert load telemetry


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2                     # d_inner = expand * d_model
    dt_rank: int = 0                    # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // num_heads
    # attention flavor
    rope_theta: float = 10000.0
    window_size: int = 0                # 0 = global; >0 = sliding window
    alt_local_global: bool = False      # gemma2: alternate local/global layers
    logit_softcap: float = 0.0          # gemma2 attn/final softcap
    final_softcap: float = 0.0
    tie_embeddings: bool = False
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    act: str = "silu"           # silu (gated) | gelu (gated) | gelu_plain
    qkv_bias: bool = False
    # MoE / hybrid / ssm
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    attn_every: int = 1         # jamba: 1 attn layer per `attn_every`
    moe_every: int = 1          # jamba: MoE layer every `moe_every`
    # modality frontend stub
    frontend: str = "none"              # none | audio_frames | vlm_patches
    frontend_tokens: int = 0    # extra prepended embedding tokens (vlm)
    # which shapes apply (long_500k only for sub-quadratic archs)
    applicable_shapes: Tuple[str, ...] = (
        "train_4k", "prefill_32k", "decode_32k")
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def n_params(self) -> int:
        """Total parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        attn = (d * self.num_heads * hd  # q
                + 2 * d * self.num_kv_heads * hd  # k,v
                + self.num_heads * hd * d)  # o
        total = emb + head
        for i in range(L):
            kind, is_moe = self.layer_kind(i)
            total += 2 * d  # norms
            if kind == "attn":
                total += attn
            else:  # mamba
                m = self.mamba
                d_in = m.expand * d
                dt_rank = m.dt_rank or -(-d // 16)
                total += (d * 2 * d_in        # in_proj
                          + d_in * m.d_conv   # conv
                          + d_in * (dt_rank + 2 * m.d_state)  # x_proj
                          + dt_rank * d_in + d_in             # dt_proj
                          + d_in * m.d_state  # A
                          + d_in              # D
                          + d_in * d)         # out_proj
            if kind == "attn" or self.family != "ssm":
                if is_moe:
                    mo = self.moe
                    total += (d * mo.num_experts                      # router
                              + mo.num_experts * 3 * d * mo.d_ff_expert)
                elif kind != "mamba":
                    mult = 3 if self.act in ("silu", "gelu") else 2
                    total += mult * d * self.d_ff
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top-k experts)."""
        if self.moe is None:
            return self.n_params()
        total = self.n_params()
        mo = self.moe
        n_moe_layers = sum(1 for i in range(self.num_layers)
                           if self.layer_kind(i)[1])
        inactive = (n_moe_layers * (mo.num_experts - mo.experts_per_token)
                    * 3 * self.d_model * mo.d_ff_expert)
        return total - inactive

    def layer_kind(self, i: int) -> Tuple[str, bool]:
        """Return (mixer_kind, is_moe_ffn) for layer i.

        mixer_kind in {"attn", "mamba"}; is_moe_ffn selects MoE vs dense FFN.
        """
        if self.family == "ssm":
            return ("mamba", False)
        if self.family == "hybrid":
            # Jamba: 1 attention layer per `attn_every` (position attn_every-1
            # within each period); MoE every `moe_every` layers (odd layers).
            last = i % self.attn_every == self.attn_every - 1
            kind = "attn" if last else "mamba"
            is_moe = (self.moe is not None
                      and i % self.moe_every == self.moe_every - 1)
            return (kind, is_moe)
        is_moe = self.moe is not None
        return ("attn", is_moe)

    def layer_is_local(self, i: int) -> bool:
        """Gemma2-style alternating local/global: even layers local."""
        if not self.alt_local_global:
            return self.window_size > 0
        return i % 2 == 0


# ---------------------------------------------------------------------------
# Input shapes (assigned set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                           # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Mesh / run config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return (("pod", "data", "model") if self.multi_pod
                else ("data", "model"))

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class RunConfig:
    """Training/serving runtime options — the hillclimb levers live here."""
    arch: str = "smollm-360m"
    shape: str = "train_4k"
    mesh: MeshConfig = field(default_factory=MeshConfig)
    # numerics
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    # optimizer
    optimizer: str = "adamw"
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    # distribution levers
    remat_policy: str = "dots_saveable"  # none | dots_saveable | full
    fsdp: bool = True                    # shard params/opt-state over DP axes
    seq_shard_long: bool = True          # SP for long-context decode
    grad_compression: str = "none"       # none | int8
    scan_layers: bool = True
    # serving
    decode_kv_dtype: str = "bfloat16"
    # sharding rule-set name (see sharding/rules.py)
    sharding_rules: str = "default"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_REGISTRY: Dict[str, ArchConfig] = {}
_SMOKE_REGISTRY: Dict[str, ArchConfig] = {}


def register_arch(cfg: ArchConfig, smoke: ArchConfig) -> ArchConfig:
    ARCH_REGISTRY[cfg.name] = cfg
    _SMOKE_REGISTRY[cfg.name] = smoke
    return cfg


def get_arch(name: str) -> ArchConfig:
    _ensure_configs_loaded()
    if name not in ARCH_REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(ARCH_REGISTRY)}")
    return ARCH_REGISTRY[name]


def get_smoke_arch(name: str) -> ArchConfig:
    _ensure_configs_loaded()
    return _SMOKE_REGISTRY[name]


def list_archs() -> List[str]:
    _ensure_configs_loaded()
    return sorted(ARCH_REGISTRY)


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells() -> List[Tuple[str, str]]:
    """All (arch, shape) cells, incl. inapplicable (caller filters)."""
    _ensure_configs_loaded()
    return [(a, s) for a in list_archs() for s in SHAPES]


def applicable_cells() -> List[Tuple[str, str]]:
    _ensure_configs_loaded()
    out = []
    for a in list_archs():
        cfg = ARCH_REGISTRY[a]
        for s in SHAPES:
            if s in cfg.applicable_shapes:
                out.append((a, s))
    return out


_configs_loaded = False


def _ensure_configs_loaded() -> None:
    global _configs_loaded
    if _configs_loaded:
        return
    _configs_loaded = True
    from repro import configs as _configs  # noqa: F401  (registration)


def override(cfg, **kw):
    """Functional update helper for any frozen dataclass config."""
    return replace(cfg, **kw)
