"""Train step: mixed-precision loss/grad + AdamW, built for pjit.

Params are stored fp32 (master) and cast to the activation dtype inside the
loss, so FSDP all-gathers move bf16 bytes (half the traffic) — one of the
standard distributed-optimization tricks recorded in §Perf.  Microbatch
gradient accumulation is available for memory-bound cells.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro import models
from repro.config import ArchConfig, RunConfig
from repro.train import optimizer as opt


class TrainState(NamedTuple):
    params: Any
    opt: opt.AdamState
    moe_state: Dict[str, jnp.ndarray]
    step: jnp.ndarray


def init_train_state(cfg: ArchConfig, run: RunConfig, key) -> TrainState:
    params = models.init_params(cfg, key, jnp.float32)
    return TrainState(
        params=params,
        opt=opt.init_adam_state(params,
                                eight_bit=run.optimizer == "adamw8bit"),
        moe_state=models.init_moe_state(cfg),
        step=jnp.zeros((), jnp.int32))


def train_state_shapes(cfg: ArchConfig, run: RunConfig) -> TrainState:
    """Abstract train state (ShapeDtypeStructs) for AOT lowering."""
    params = models.param_shapes(cfg, jnp.float32)
    eight_bit = run.optimizer == "adamw8bit"
    if eight_bit:
        state = jax.eval_shape(
            lambda p: opt.init_adam_state(p, eight_bit=True), params)
    else:
        f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        state = opt.AdamState(m=jax.tree_util.tree_map(f32, params),
                              v=jax.tree_util.tree_map(f32, params))
    moe = jax.eval_shape(lambda: models.init_moe_state(cfg))
    return TrainState(params=params, opt=state, moe_state=moe,
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def make_train_step(cfg: ArchConfig, run: RunConfig):
    eight_bit = run.optimizer == "adamw8bit"
    act_dtype = jnp.dtype(run.activation_dtype)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        def loss_fn(params):
            compute_params = jax.tree_util.tree_map(
                lambda p: p.astype(act_dtype)
                if p.dtype == jnp.float32 and p.ndim > 1 else p, params)
            cast_batch = {
                k: (v.astype(act_dtype) if v.dtype in (jnp.float32,)
                    and v.ndim >= 3 else v)
                for k, v in batch.items()}
            return models.loss_fn(compute_params, cfg, cast_batch,
                                  state.moe_state,
                                  remat_policy=run.remat_policy)

        (loss, (new_moe, metrics)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)
        grads, gnorm = opt.clip_by_global_norm(grads, run.grad_clip)
        new_params, new_opt = opt.adamw_update(
            state.params, grads, state.opt, state.step, lr=run.learning_rate,
            beta1=run.beta1, beta2=run.beta2,
            weight_decay=run.weight_decay, eight_bit=eight_bit)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return TrainState(params=new_params, opt=new_opt,
                          moe_state=new_moe, step=state.step + 1), metrics

    return train_step


def make_eval_step(cfg: ArchConfig, run: RunConfig):
    act_dtype = jnp.dtype(run.activation_dtype)

    def eval_step(params, moe_state, batch):
        compute_params = jax.tree_util.tree_map(
            lambda p: p.astype(act_dtype)
            if p.dtype == jnp.float32 and p.ndim > 1 else p, params)
        loss, (_, metrics) = models.loss_fn(compute_params, cfg, batch,
                                            moe_state)
        return metrics

    return eval_step
