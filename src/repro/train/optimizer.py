"""Optimizers (no external deps): AdamW with fp32 state, optional 8-bit
(block-quantized) first/second moments — the memory-compression trick that
matters at 100B+ scale — plus global-norm clipping.

State sharding mirrors parameter sharding (ZeRO-style: the FSDP axes shard
both), so per-device optimizer memory scales 1/(dp·tp).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.utils import global_norm


class AdamState(NamedTuple):
    m: Any
    v: Any
    # 8-bit mode keeps per-block scales alongside int8 payloads
    m_scale: Any = None
    v_scale: Any = None


BLOCK = 256  # quantization block for 8-bit state


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    n = 1
    for s in shape:
        n *= int(s)
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[:n].reshape(shape)


def init_adam_state(params, *, eight_bit: bool = False) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    if not eight_bit:
        m = jax.tree_util.tree_map(zeros, params)
        v = jax.tree_util.tree_map(zeros, params)
        return AdamState(m=m, v=v)
    q = jax.tree_util.tree_map(lambda p: _quantize(zeros(p))[0], params)
    s = jax.tree_util.tree_map(lambda p: _quantize(zeros(p))[1], params)
    return AdamState(m=q, v=jax.tree_util.tree_map(jnp.copy, q),
                     m_scale=s, v_scale=jax.tree_util.tree_map(jnp.copy, s))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree_util.tree_map(
        lambda x: (x.astype(jnp.float32) * factor).astype(x.dtype), grads), g


def adamw_update(params, grads, state: AdamState, step: jnp.ndarray, *,
                 lr: float, beta1: float = 0.9, beta2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 eight_bit: bool = False):
    """Returns (new_params, new_state).  Params stay in their stored dtype
    (fp32 master recommended); math is fp32."""
    t = step.astype(jnp.float32) + 1.0
    c1 = 1.0 - beta1 ** t
    c2 = 1.0 - beta2 ** t

    def upd(p, g, m, v, ms, vs):
        g = g.astype(jnp.float32)
        if eight_bit:
            m_f = _dequantize(m, ms, p.shape)
            v_f = _dequantize(v, vs, p.shape)
        else:
            m_f, v_f = m, v
        m_f = beta1 * m_f + (1.0 - beta1) * g
        v_f = beta2 * v_f + (1.0 - beta2) * jnp.square(g)
        mh = m_f / c1
        vh = v_f / c2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * pf)
        if eight_bit:
            mq, msn = _quantize(m_f)
            vq, vsn = _quantize(v_f)
            return pf.astype(p.dtype), mq, vq, msn, vsn
        return pf.astype(p.dtype), m_f, v_f, None, None

    ms = state.m_scale if eight_bit else state.m
    vs = state.v_scale if eight_bit else state.v
    out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v,
                                 ms, vs)
    is5 = lambda x: isinstance(x, tuple) and len(x) == 5
    new_p = jax.tree_util.tree_map(lambda x: x[0], out, is_leaf=is5)
    new_m = jax.tree_util.tree_map(lambda x: x[1], out, is_leaf=is5)
    new_v = jax.tree_util.tree_map(lambda x: x[2], out, is_leaf=is5)
    if eight_bit:
        new_ms = jax.tree_util.tree_map(lambda x: x[3], out, is_leaf=is5)
        new_vs = jax.tree_util.tree_map(lambda x: x[4], out, is_leaf=is5)
        return new_p, AdamState(new_m, new_v, new_ms, new_vs)
    return new_p, AdamState(new_m, new_v)
