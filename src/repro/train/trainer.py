"""End-to-end training driver: data pipeline -> train step -> async
MIDAS-scheduled checkpoints -> restart/resume, with failure-detector
hooks.  Runs unchanged from 1 CPU (examples) to the production mesh (the
step function is the same jit the dry-run lowers)."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.ckpt import CheckpointManager
from repro.config import ArchConfig, RunConfig
from repro.data import Prefetcher, SyntheticLM
from repro.ft import FailureDetector
from repro.train.step import TrainState, init_train_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    batch: int = 8
    seq: int = 128
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_lanes: int = 4
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ArchConfig, run: RunConfig, tc: TrainerConfig,
                 log_fn: Callable[[str], None] = print):
        self.cfg = cfg
        self.run = run
        self.tc = tc
        self.log = log_fn
        self.step_fn = jax.jit(make_train_step(cfg, run), donate_argnums=0)
        self.ckpt = (CheckpointManager(tc.ckpt_dir, lanes=tc.ckpt_lanes)
                     if tc.ckpt_dir else None)
        self.detector = FailureDetector(hosts=1)
        self.source = SyntheticLM(cfg, tc.batch, tc.seq, seed=tc.seed)

    def init_or_resume(self) -> TrainState:
        state = init_train_state(self.cfg, self.run,
                                 jax.random.PRNGKey(self.tc.seed))
        if self.ckpt is not None:
            step, restored = self.ckpt.restore_latest(state)
            if restored is not None:
                self.log(f"[trainer] resumed from checkpoint step {step}")
                return jax.tree_util.tree_map(jax.numpy.asarray, restored)
        return state

    def train(self, state: Optional[TrainState] = None) -> TrainState:
        state = state if state is not None else self.init_or_resume()
        start = int(state.step)
        stream = Prefetcher(self.source, start_step=start)
        pending = None
        try:
            for step, batch in stream:
                if step >= self.tc.steps:
                    break
                t0 = time.monotonic()
                state, metrics = self.step_fn(state, batch)
                dt = time.monotonic() - t0
                self.detector.heartbeat(0, step_time_s=dt)
                if (step + 1) % self.tc.log_every == 0:
                    loss = float(metrics["loss"])
                    self.log(f"[trainer] step {step + 1:5d} "
                             f"loss {loss:.4f} ({dt * 1e3:.0f} ms)"
                             + (f" drop {float(metrics['moe_drop_rate']):.3f}"
                                if "moe_drop_rate" in metrics else ""))
                if (self.ckpt is not None
                        and (step + 1) % self.tc.ckpt_every == 0):
                    if pending is not None:
                        pending.result()       # one in flight at a time
                    pending = self.ckpt.save(step + 1, state,
                                             blocking=False)
            if pending is not None:
                pending.result()
        finally:
            stream.close()
        return state
