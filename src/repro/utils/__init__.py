from repro.utils.trees import (  # noqa: F401
    tree_bytes,
    tree_count,
    tree_map_with_path_names,
    global_norm,
)
