"""Small pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_count(tree) -> int:
    """Total number of array elements in a pytree."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays/ShapeDtypeStructs."""
    return sum(int(x.size) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def tree_map_with_path_names(fn, tree):
    """tree_map where fn receives ('a/b/c', leaf)."""
    def _name(path) -> str:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return "/".join(parts)

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(_name(path), leaf), tree)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))
