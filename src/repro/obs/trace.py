"""Structured run traces: spans, JSONL event log, Chrome-trace export.

The flight recorder wraps the engine's HOST-side orchestration phases —
warmup, (re)compile+execute, device transfer, host-side slicing — in
:func:`span` context managers.  Each completed span becomes one event
dict; events use the Chrome ``trace_event`` keys directly (``name``,
``cat``, ``ph``, ``ts``, ``dur``, ``pid``, ``tid``, ``args``) so the
JSONL log is simultaneously the structured schema *and*, wrapped in
``{"traceEvents": [...]}``, a file Perfetto / ``chrome://tracing`` opens
as-is.  Timestamps are microseconds on the recorder's monotonic clock;
the wall-clock epoch rides a metadata event so traces can be joined
with artifact ``meta`` timestamps.

Recording is host-only and per-call, never per-tick: nothing here runs
inside jitted code, so engine results are bit-for-bit identical with
the recorder enabled or disabled (tested), and the overhead is a few
dict appends per sweep — far under the E10 <2% ticks/sec budget.

Write-through sink: when a JSONL path is configured (the benchmark
:class:`benchmarks.common.Artifact` pairs one with every JSON artifact),
each completed event is appended immediately, so a CI timeout that
kills the process mid-run still leaves a valid prefix of whole lines.
``REPRO_OBS=0`` disables recording entirely; ``REPRO_OBS_PROFILE=1``
additionally wraps every span in a ``jax.profiler.TraceAnnotation`` so
spans line up with XLA traces in a profiler capture.
"""

from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from pathlib import Path
from typing import Iterator, List, Optional

SCHEMA_VERSION = 1

# the event keys --check requires; everything else is optional
REQUIRED_KEYS = ("name", "cat", "ph", "ts", "pid", "tid")
PHASES = ("X", "i", "M")  # complete span, instant, metadata


def _env_flag(name: str, default: bool) -> bool:
    raw = os.environ.get(name)
    if raw is None:
        return default
    return raw.strip().lower() not in ("0", "false", "no", "off", "")


class Recorder:
    """Append-only span recorder with an optional JSONL write-through
    sink.  One process-global instance (:data:`RECORDER`) serves the
    engine and the benchmark harness; tests build private ones."""

    def __init__(self, enabled: Optional[bool] = None):
        self._lock = threading.Lock()
        self.events: List[dict] = []
        self.path: Optional[Path] = None
        self.enabled = (
            _env_flag("REPRO_OBS", True) if enabled is None else enabled
        )
        self.profile = _env_flag("REPRO_OBS_PROFILE", False)
        self._epoch_perf = time.perf_counter()
        self._epoch_wall = time.time()

    # -- configuration ----------------------------------------------------
    def configure(
        self,
        path=None,
        enabled: Optional[bool] = None,
        profile: Optional[bool] = None,
        fresh: bool = False,
    ) -> None:
        """Point the recorder at a JSONL sink (and optionally reset).

        ``fresh=True`` clears buffered events and truncates the sink —
        the per-artifact idiom: one trace file per benchmark artifact.
        """
        with self._lock:
            if enabled is not None:
                self.enabled = enabled
            if profile is not None:
                self.profile = profile
            if fresh:
                self.events.clear()
                self._epoch_perf = time.perf_counter()
                self._epoch_wall = time.time()
            if path is not None:
                self.path = Path(path)
                self.path.parent.mkdir(parents=True, exist_ok=True)
                if fresh or not self.path.exists():
                    self.path.write_text("")
        if self.enabled:
            self._record(self._meta_event())

    def _meta_event(self) -> dict:
        return {
            "v": SCHEMA_VERSION,
            "name": "recorder",
            "cat": "meta",
            "ph": "M",
            "ts": 0.0,
            "pid": os.getpid(),
            "tid": threading.get_ident() & 0xFFFF,
            "args": {
                "epoch_unix": round(self._epoch_wall, 6),
                "schema": SCHEMA_VERSION,
            },
        }

    # -- event emission ---------------------------------------------------
    def _now_us(self) -> float:
        return (time.perf_counter() - self._epoch_perf) * 1e6

    def _record(self, ev: dict) -> None:
        with self._lock:
            self.events.append(ev)
            if self.path is not None:
                with self.path.open("a") as f:
                    f.write(json.dumps(ev) + "\n")

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "phase", **args) -> Iterator[dict]:
        """Record one complete (``ph="X"``) event around a code block.

        ``cat`` buckets the phase taxonomy (``warmup`` / ``execute`` /
        ``host`` / ``bench`` — DESIGN.md §13); extra keyword args land
        in the event's ``args`` and must be JSON-serializable.  Yields
        the args dict — mutate it inside the block to attach facts only
        known afterwards (e.g. ``compiled``).  A span that exits via an
        exception is still recorded, with the exception type in
        ``args.error``.
        """
        args = dict(args)
        if not self.enabled:
            yield args
            return
        ctx = contextlib.nullcontext()
        if self.profile:
            try:
                import jax

                ctx = jax.profiler.TraceAnnotation(name)
            except Exception:  # profiler unavailable: spans still record
                ctx = contextlib.nullcontext()
        t0 = self._now_us()
        err = None
        try:
            with ctx:
                yield args
        except BaseException as e:
            err = type(e).__name__
            raise
        finally:
            t1 = self._now_us()
            if err is not None:
                args["error"] = err
            self._record(
                {
                    "v": SCHEMA_VERSION,
                    "name": name,
                    "cat": cat,
                    "ph": "X",
                    "ts": round(t0, 3),
                    "dur": round(t1 - t0, 3),
                    "pid": os.getpid(),
                    "tid": threading.get_ident() & 0xFFFF,
                    "args": args,
                }
            )

    def instant(self, name: str, cat: str = "mark", **args) -> None:
        """Record one instantaneous (``ph="i"``) event."""
        if not self.enabled:
            return
        self._record(
            {
                "v": SCHEMA_VERSION,
                "name": name,
                "cat": cat,
                "ph": "i",
                "ts": round(self._now_us(), 3),
                "s": "p",
                "pid": os.getpid(),
                "tid": threading.get_ident() & 0xFFFF,
                "args": args,
            }
        )

    # -- export -----------------------------------------------------------
    def write_chrome(self, path) -> Path:
        """Write the buffered events as one Chrome-trace JSON document
        (``{"traceEvents": [...]}``) Perfetto opens directly."""
        path = Path(path)
        with self._lock:
            doc = {
                "traceEvents": list(self.events),
                "displayTimeUnit": "ms",
            }
        path.write_text(json.dumps(doc))
        return path


# The process-global recorder the engine and harness share.
RECORDER = Recorder()


def configure(**kw) -> None:
    RECORDER.configure(**kw)


def span(name: str, cat: str = "phase", **args):
    return RECORDER.span(name, cat=cat, **args)


def instant(name: str, cat: str = "mark", **args) -> None:
    RECORDER.instant(name, cat=cat, **args)


# ---------------------------------------------------------------------------
# Reading + validation (the --check side)
# ---------------------------------------------------------------------------


def read_trace(path) -> List[dict]:
    """Parse a JSONL trace.  A truncated FINAL line (the process was
    killed mid-write, e.g. a CI timeout) is tolerated and dropped —
    flight-recorder semantics; truncation anywhere else is malformed
    and raises ``ValueError``."""
    lines = Path(path).read_text().splitlines()
    events = []
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            events.append(json.loads(line))
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                break  # torn final line: drop it
            raise ValueError(
                f"{path}: malformed JSONL at line {i + 1}"
            ) from None
    return events


def validate_events(events: List[dict]) -> List[str]:
    """Schema problems in a parsed event list (empty list = valid)."""
    problems = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        missing = [k for k in REQUIRED_KEYS if k not in ev]
        if missing:
            problems.append(f"event {i}: missing keys {', '.join(missing)}")
            continue
        if ev["ph"] not in PHASES:
            problems.append(f"event {i}: unknown phase {ev['ph']!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            problems.append(f"event {i}: bad ts {ev['ts']!r}")
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: bad dur {dur!r}")
    return problems
