"""``repro-report`` — read an artifact + trace pair back as numbers.

    PYTHONPATH=src python -m repro.obs.report experiments/sim/\
control_matrix.json
    PYTHONPATH=src python -m repro.obs.report --check experiments/sim

Given a benchmark artifact (any ``BENCH_*.json`` / matrix JSON the
runners emit) and its paired JSONL trace (``<stem>.trace.jsonl``, found
automatically next to the artifact or named via ``--trace``), prints:

* the artifact's environment meta (jax version, device kind, wall-clock
  start/end);
* the per-phase time breakdown aggregated from the trace spans
  (warmup / execute / host / bench categories, DESIGN.md §13);
* compile-vs-execute ratios (first-call vs steady bench spans, and
  ``compiled=True`` execute spans vs warm ones);
* every cell's windowed-vs-raw delta (the ``window`` blocks the
  E-series runners record via ``repro.obs.windows``).

``--check`` validates instead of printing: every trace parses and
passes the event schema (a torn FINAL line — CI timeout — is tolerated,
any other malformation fails), every artifact is valid JSON, and every
``window`` block satisfies ``0 <= begin <= end <= T``.  Directories are
scanned recursively (``*.json`` artifacts, ``*.trace.jsonl`` traces;
``*.trace.json`` files are Chrome exports and only syntax-checked).
Exit code 0 = clean, 1 = problems found.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional, Tuple

from repro.obs import trace as trace_lib


# ---------------------------------------------------------------------------
# Aggregation
# ---------------------------------------------------------------------------


def phase_table(events: List[dict]) -> List[Tuple[str, int, float]]:
    """(category, span count, total seconds) rows, longest first, over
    the complete (``ph="X"``) spans of one trace."""
    totals: dict = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        cat = ev.get("cat", "?")
        n, dur = totals.get(cat, (0, 0.0))
        totals[cat] = (n + 1, dur + float(ev.get("dur", 0.0)) / 1e6)
    return sorted(
        [(c, n, d) for c, (n, d) in totals.items()],
        key=lambda r: -r[2],
    )


def compile_vs_execute(events: List[dict]) -> Optional[dict]:
    """First-call vs steady split from the harness's bench spans plus
    the engine's ``compiled`` span tag; None when the trace has no
    execute spans at all."""
    first = steady = 0.0
    compiled = warm = 0.0
    for ev in events:
        if ev.get("ph") != "X":
            continue
        args = ev.get("args") or {}
        dur_s = float(ev.get("dur", 0.0)) / 1e6
        if ev.get("name") == "bench/first_call":
            first += dur_s
        elif ev.get("name") == "bench/steady":
            steady += dur_s
        if ev.get("cat") == "execute":
            if args.get("compiled"):
                compiled += dur_s
            else:
                warm += dur_s
    if first == steady == compiled == warm == 0.0:
        return None
    out = {
        "first_call_s": round(first, 3),
        "steady_s": round(steady, 3),
        "compiling_execute_s": round(compiled, 3),
        "warm_execute_s": round(warm, 3),
    }
    if steady > 0:
        out["first_over_steady"] = round(first / steady, 2)
        out["compile_overhead_s"] = round(max(first - steady, 0.0), 3)
    return out


def window_rows(doc, path: str = "") -> List[Tuple[str, dict, dict]]:
    """Every ``window`` block in an artifact: (json-path, window,
    sibling stats) triples, found by recursive walk."""
    rows = []
    if isinstance(doc, dict):
        if isinstance(doc.get("window"), dict):
            sibs = {k: doc[k] for k in ("stable", "window_shift") if k in doc}
            rows.append((path or ".", doc["window"], sibs))
        for k, v in doc.items():
            if k != "window":
                rows.extend(window_rows(v, f"{path}.{k}" if path else k))
    elif isinstance(doc, list):
        for i, v in enumerate(doc):
            rows.extend(window_rows(v, f"{path}[{i}]"))
    return rows


def find_trace(artifact: Path) -> Optional[Path]:
    """The artifact's paired JSONL trace (``<stem>.trace.jsonl``, the
    :class:`benchmarks.common.Artifact` naming contract)."""
    cand = artifact.with_suffix(".trace.jsonl")
    return cand if cand.exists() else None


# ---------------------------------------------------------------------------
# Printing
# ---------------------------------------------------------------------------


def print_report(artifact: Path, trace: Optional[Path]) -> None:
    doc = json.loads(artifact.read_text())
    meta = doc.get("meta", {}) if isinstance(doc, dict) else {}
    print(f"artifact: {artifact}")
    if meta:
        env = ", ".join(
            str(meta[k]) for k in ("jax_version", "device_kind") if k in meta
        )
        wall = " -> ".join(
            str(meta[k]) for k in ("started_at", "written_at") if k in meta
        )
        if env:
            print(f"  env:  {env}")
        if wall:
            print(f"  wall: {wall}")
    if trace is not None:
        events = trace_lib.read_trace(trace)
        spans = phase_table(events)
        total = sum(d for _, _, d in spans) or 1.0
        print(f"  trace: {trace.name} ({len(events)} events)")
        print("  phases:")
        for cat, n, dur in spans:
            print(
                f"    {cat:<10s} {n:>4d} spans  {dur:>9.3f} s  "
                f"{100.0 * dur / total:5.1f}%"
            )
        cve = compile_vs_execute(events)
        if cve:
            line = (
                f"    first-call {cve['first_call_s']}s vs steady "
                f"{cve['steady_s']}s"
            )
            if "first_over_steady" in cve:
                line += (
                    f"  ({cve['first_over_steady']}x, compile overhead "
                    f"~{cve['compile_overhead_s']}s)"
                )
            print("  compile vs execute:")
            print(line)
    rows = window_rows(doc)
    if rows:
        print("  windows (stable-only vs whole-run):")
        for path, win, sibs in rows:
            shift = (sibs.get("window_shift") or {}).get("mean_queue")
            stable = (sibs.get("stable") or {}).get("mean_queue")
            extra = ""
            if stable is not None:
                extra += f"  stable_mean_q={stable}"
            if shift is not None:
                extra += f"  shift={100.0 * shift:+.1f}%"
            print(
                f"    {path:<44s} [{win.get('begin')}, "
                f"{win.get('end')})/{win.get('T')} "
                f"{win.get('method')}{extra}"
            )
    else:
        print("  windows: none recorded")


# ---------------------------------------------------------------------------
# --check
# ---------------------------------------------------------------------------


def check_window(win: dict, where: str) -> List[str]:
    problems = []
    try:
        b, e, t = (
            int(win["begin"]),
            int(win["end"]),
            int(win["T"]),
        )
    except (KeyError, TypeError, ValueError) as exc:
        return [f"{where}: malformed window block ({exc!r})"]
    if not 0 <= b <= e <= t:
        problems.append(
            f"{where}: window invariant violated "
            f"(begin={b} end={e} T={t})"
        )
    if win.get("method") not in ("ewma_plateau", "censored"):
        problems.append(
            f"{where}: unknown window method {win.get('method')!r}"
        )
    return problems


def check_paths(paths: List[Path]) -> List[str]:
    """Validate traces + artifacts; returns problem strings (empty =
    clean).  Missing traces are fine (a runner may not have started);
    malformed ones are not."""
    problems: List[str] = []
    jsonl, chrome, artifacts = [], [], []
    for p in paths:
        if p.is_dir():
            jsonl += sorted(p.rglob("*.trace.jsonl"))
            chrome += sorted(p.rglob("*.trace.json"))
            artifacts += sorted(
                f
                for f in p.rglob("*.json")
                if not f.name.endswith(".trace.json")
            )
        elif p.name.endswith(".trace.jsonl"):
            jsonl.append(p)
        elif p.name.endswith(".trace.json"):
            chrome.append(p)
        else:
            artifacts.append(p)
    for t in jsonl:
        try:
            events = trace_lib.read_trace(t)
        except ValueError as exc:
            problems.append(str(exc))
            continue
        problems += [
            f"{t}: {msg}" for msg in trace_lib.validate_events(events)
        ]
    for c in chrome:
        try:
            doc = json.loads(c.read_text())
            if "traceEvents" not in doc:
                problems.append(f"{c}: no traceEvents key")
        except (json.JSONDecodeError, OSError) as exc:
            problems.append(f"{c}: unreadable ({exc})")
    for a in artifacts:
        try:
            doc = json.loads(a.read_text())
        except (json.JSONDecodeError, OSError) as exc:
            problems.append(f"{a}: unreadable ({exc})")
            continue
        for where, win, _ in window_rows(doc):
            problems += check_window(win, f"{a}:{where}")
    return problems


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description=__doc__.splitlines()[0],
    )
    ap.add_argument(
        "paths",
        nargs="+",
        type=Path,
        help="artifact JSON files and/or directories to scan",
    )
    ap.add_argument(
        "--trace",
        type=Path,
        default=None,
        help="explicit JSONL trace (default: <artifact>.trace.jsonl)",
    )
    ap.add_argument(
        "--check",
        action="store_true",
        help="validate traces + window blocks instead of printing; "
        "exit 1 on any malformation",
    )
    args = ap.parse_args(argv)
    for p in args.paths:
        if not p.exists():
            print(f"error: {p} does not exist", file=sys.stderr)
            return 1
    if args.check:
        problems = check_paths(list(args.paths))
        for msg in problems:
            print(f"CHECK FAIL: {msg}", file=sys.stderr)
        print(
            f"repro-report --check: "
            f"{'FAIL' if problems else 'ok'} "
            f"({len(problems)} problem(s))"
        )
        return 1 if problems else 0
    artifacts: List[Path] = []
    for p in args.paths:
        if p.is_dir():
            artifacts += sorted(
                f
                for f in p.rglob("*.json")
                if not f.name.endswith(".trace.json")
            )
        else:
            artifacts.append(p)
    for i, a in enumerate(artifacts):
        if i:
            print()
        print_report(a, args.trace or find_trace(a))
    return 0


if __name__ == "__main__":
    sys.exit(main())
