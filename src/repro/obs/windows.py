"""The warmup/stable/cooldown windowing contract (DESIGN.md §13).

Every E-series number the repo publishes is a steady-state claim, but a
raw whole-run mean mixes the transient (queues filling from empty, the
controller still hunting for its operating point) with the steady state
the paper's claims are about.  This module is the ONE shared detector
every benchmark runner uses, so "steady state" is a measured, recorded,
machine-checkable property of each artifact cell instead of an implicit
assumption of each script.

Algorithm (``method="ewma_plateau"``), over a per-tick scalar series
(the per-tick across-server mean queue the engine now emits in both
metrics modes):

1. Smooth with :func:`repro.core.telemetry.ewma_series`, initialized at
   the first sample (``init=x[0]``) so the filter itself adds no
   artificial ramp.
2. **EWMA slope**: normalized step ``|s[t]-s[t-1]| / max|s|`` must stay
   below ``slope_tol`` — the smoothed level has stopped moving.
3. **Variance plateau**: the trailing ``hold``-tick rolling std of the
   RAW series must fall to its long-run level (``var_tol`` × the std of
   the trailing half) — the local noise floor has flattened, not just
   the mean.
4. ``begin`` is the first tick opening a ``hold``-long run where both
   conditions hold; ``end`` trims the trailing run where they fail
   (cooldown).  No such run within ``max_warmup_frac`` of the horizon,
   a horizon shorter than ``2*hold`` (pure transient), or a non-finite
   series ⇒ a **censored** window (``begin == end == T``,
   ``method="censored"``) — recorded, never a crash.

Invariant (hypothesis-tested): ``0 <= begin <= end <= T`` for arbitrary
timelines, and windowed statistics fall back to whole-run statistics
when the window is censored (with the parity shift reported as 0).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

# defaults shared by every runner — the knobs DESIGN.md §13 documents
ALPHA = 0.2  # EWMA smoothing, same fast-loop constant as the controller
SLOPE_TOL = 0.02  # normalized per-tick EWMA step bound
VAR_TOL = 1.5  # rolling-std bound, × the trailing-half std
HOLD = 8  # ticks both conditions must hold to open/keep the window
MAX_WARMUP_FRAC = 0.5  # later onsets are censored, not believed


@dataclasses.dataclass(frozen=True)
class Window:
    """One detected stable window over a T-tick series: stable ticks
    are the half-open ``[begin, end)``; ``[0, begin)`` is warmup and
    ``[end, T)`` cooldown.  ``begin == end`` means no stable region."""

    begin: int
    end: int
    T: int
    method: str

    def __post_init__(self):
        if not 0 <= self.begin <= self.end <= self.T:
            raise ValueError(
                f"window invariant violated: begin={self.begin} "
                f"end={self.end} T={self.T}"
            )

    @property
    def censored(self) -> bool:
        return self.method == "censored"

    @property
    def n_stable(self) -> int:
        return self.end - self.begin

    def to_json(self, dt_ms: Optional[float] = None) -> dict:
        doc = {
            "begin": self.begin,
            "end": self.end,
            "T": self.T,
            "method": self.method,
            "censored": self.censored,
        }
        if dt_ms is not None:
            doc["begin_ms"] = round(self.begin * dt_ms, 1)
            doc["end_ms"] = round(self.end * dt_ms, 1)
        return doc


def _rolling_std(x: np.ndarray, w: int) -> np.ndarray:
    """Trailing-window std: ``rstd[t] = std(x[max(0, t-w+1) : t+1])``
    via cumulative sums (O(T), exact up to fp cancellation, clipped)."""
    c1 = np.cumsum(np.concatenate(([0.0], x)))
    c2 = np.cumsum(np.concatenate(([0.0], x * x)))
    t = np.arange(x.size)
    lo = np.maximum(t - w + 1, 0)
    n = (t - lo + 1).astype(np.float64)
    mean = (c1[t + 1] - c1[lo]) / n
    var = (c2[t + 1] - c2[lo]) / n - mean * mean
    return np.sqrt(np.maximum(var, 0.0))


def detect(
    series,
    *,
    alpha: float = ALPHA,
    slope_tol: float = SLOPE_TOL,
    var_tol: float = VAR_TOL,
    hold: int = HOLD,
    max_warmup_frac: float = MAX_WARMUP_FRAC,
) -> Window:
    """Detect the stable window of a per-tick scalar series (see the
    module docstring for the algorithm and the censoring contract)."""
    x = np.asarray(series, np.float64).reshape(-1)
    T = int(x.size)
    if T < 2 * hold or not np.all(np.isfinite(x)):
        return Window(begin=T, end=T, T=T, method="censored")
    from repro.core.telemetry import ewma_series  # lazy: no import cycle

    # init at x[0]: the filter itself must not add an artificial ramp
    s = ewma_series(x, alpha, init=x[0])
    scale = float(np.max(np.abs(s))) + 1e-9
    slope = np.abs(np.diff(s, prepend=s[0])) / scale
    rstd = _rolling_std(x, hold)
    half = T // 2
    ref_std = float(np.std(x[half:]))
    ok = (slope < slope_tol) & (
        rstd <= var_tol * ref_std + 1e-9 + 1e-6 * scale
    )
    # first index opening a hold-long all-ok run
    runs = np.convolve(ok.astype(np.float64), np.ones(hold), "valid")
    starts = np.flatnonzero(runs >= hold - 0.5)
    if starts.size == 0 or starts[0] > max_warmup_frac * T:
        return Window(begin=T, end=T, T=T, method="censored")
    begin = int(starts[0])
    # cooldown: trim the trailing not-ok run (never past the last
    # stable run, which ends at or after begin + hold)
    end = int(np.flatnonzero(ok)[-1]) + 1
    end = max(end, begin + hold)
    return Window(begin=begin, end=end, T=T, method="ewma_plateau")


# ---------------------------------------------------------------------------
# Row/cell helpers the E-series runners share
# ---------------------------------------------------------------------------


def q_mean_series(row) -> np.ndarray:
    """The per-tick across-server mean-queue series of one engine row.

    Full-metrics :class:`repro.core.sim.SimResult` rows reduce their
    ``(T, m)`` queue timeline; streaming :class:`SummaryResult` rows
    carry the same series as ``q_mean_timeline`` (a ``KnobTrace`` ys —
    O(T) scalars survive ``metrics="summary"``).
    """
    q = getattr(row, "q_mean_timeline", None)
    if q is not None:
        return np.asarray(q, np.float64)
    tl = getattr(row, "queue_timeline", None)
    if tl is not None:
        return np.asarray(tl, np.float64).mean(axis=1)
    raise ValueError(
        f"row {type(row).__name__} carries no mean-queue series; "
        f"expected a SimResult or a SummaryResult with q_mean_timeline"
    )


def windowed_stats(series, window: Window) -> dict:
    """Raw vs stable-only mean of one series, plus the parity shift
    (relative move of the windowed number; 0.0 when censored — the
    stable number falls back to the raw one rather than vanishing)."""
    x = np.asarray(series, np.float64).reshape(-1)
    raw = float(x.mean()) if x.size else 0.0
    if window.n_stable > 0:
        stable = float(x[window.begin:window.end].mean())
    else:
        stable = raw
    shift = (stable - raw) / (abs(raw) + 1e-9)
    return {"raw": raw, "stable": stable, "shift": shift}


def cell_block(
    rows: Sequence,
    dt_ms: Optional[float] = None,
    **detect_kw,
) -> dict:
    """The ``window`` block every E-series artifact cell records.

    Detects ONE window on the seed-averaged mean-queue series (the
    cell's configuration has one steady state; averaging seeds before
    detection stops per-seed noise from fragmenting it), then computes
    stable-only statistics per seed inside that shared window and
    averages — so the stable numbers aggregate exactly like the raw
    numbers they sit next to.  ``window_shift`` is the parity field:
    how far (relative) the windowed mean queue moved from the raw one.
    """
    series = [q_mean_series(r) for r in rows]
    w = detect(np.mean(series, axis=0), **detect_kw)
    per_seed = [windowed_stats(s, w) for s in series]
    raw = float(np.mean([p["raw"] for p in per_seed]))
    stable = float(np.mean([p["stable"] for p in per_seed]))
    return {
        "window": w.to_json(dt_ms),
        "stable": {"mean_queue": round(stable, 4)},
        "window_shift": {
            "mean_queue": round((stable - raw) / (abs(raw) + 1e-9), 4)
        },
    }
