# Flight-recorder observability plane (DESIGN.md §13).
#
# Three pieces, all host-side — nothing here touches jitted math, so
# golden parity holds bit-for-bit with recording on or off:
#
# * ``repro.obs.trace``   — structured spans around the engine's
#   compile/execute/host-slice phases, emitted as a JSONL event log
#   plus a Chrome-trace (``trace_event``) export viewable in Perfetto;
# * ``repro.obs.windows`` — the shared warmup/stable/cooldown windowing
#   contract (EWMA-slope + variance plateau) every E-series runner uses
#   so artifact cells carry stable-only statistics next to whole-run
#   numbers;
# * ``repro.obs.report``  — the ``repro-report`` CLI
#   (``python -m repro.obs.report``): per-phase time breakdown,
#   compile-vs-execute ratios, windowed-vs-raw metric deltas, and a
#   ``--check`` mode CI runs against every trace/artifact pair.
from repro.obs import trace, windows  # noqa: F401
from repro.obs.trace import (  # noqa: F401
    RECORDER,
    Recorder,
    configure,
    instant,
    span,
)
from repro.obs.windows import (  # noqa: F401
    Window,
    cell_block,
    detect,
    q_mean_series,
)
