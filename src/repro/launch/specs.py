"""ShapeDtypeStruct stand-ins for every model input, per (arch × shape) —
weak-type-correct, shardable, no device allocation."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import models
from repro.config import ArchConfig, RunConfig, ShapeConfig
from repro.sharding.rules import Rules


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                act_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Training/prefill batch structure for this architecture."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.frontend == "audio_frames":
        return {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), act_dtype),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    if cfg.frontend == "vlm_patches":
        P = cfg.frontend_tokens
        return {
            "tokens": jax.ShapeDtypeStruct((B, S - P), i32),
            "patches": jax.ShapeDtypeStruct((B, P, cfg.d_model), act_dtype),
        }
    return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig,
                       cache_dtype=jnp.bfloat16):
    """(tokens, pos, cache) stand-ins for a serve_step cell."""
    B, S = shape.global_batch, shape.seq_len
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    cache = models.init_decode_cache(cfg, B, S, cache_dtype, mode="shape")
    return tokens, pos, cache


def batch_pspec(cfg: ArchConfig, shape: ShapeConfig, rules: Rules,
                act_dtype=jnp.bfloat16):
    """PartitionSpecs for the training batch (divisibility-aware)."""
    specs = input_specs(cfg, shape, act_dtype)
    names = {
        "tokens": ("batch", "seq"), "labels": ("batch", "seq"),
        "frames": ("batch", "seq", "embed"),
        "patches": ("batch", "seq", "embed"),
    }
    return {k: rules.spec(*names[k], shape=v.shape)
            for k, v in specs.items()}


def _zip_spec(axes_tree, shapes_tree, rules: Rules):
    return jax.tree_util.tree_map(
        lambda a, s: rules.spec(*a, shape=s.shape), axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple))


def params_pspec(cfg: ArchConfig, rules: Rules):
    return _zip_spec(models.param_logical_axes(cfg),
                     models.param_shapes(cfg), rules)


def cache_pspec(cfg: ArchConfig, shape: ShapeConfig, rules: Rules,
                cache_dtype=jnp.bfloat16):
    shapes = models.init_decode_cache(cfg, shape.global_batch,
                                      shape.seq_len, cache_dtype,
                                      mode="shape")
    return _zip_spec(models.cache_logical_axes(cfg), shapes, rules)


def train_state_pspec(cfg: ArchConfig, run: RunConfig, rules: Rules,
                      state_shapes):
    """Sharding for the full TrainState: optimizer state mirrors params
    (ZeRO); 8-bit payloads/scales fall back to replicated-safe specs."""
    from jax.sharding import PartitionSpec as P
    p_spec = params_pspec(cfg, rules)
    eight_bit = run.optimizer == "adamw8bit"
    if eight_bit:
        # int8 payloads are (blocks, BLOCK): shard the block dim over FSDP
        def blk_spec(s):
            return rules.spec("embed_fsdp", None, shape=s.shape)
        m = jax.tree_util.tree_map(blk_spec, state_shapes.opt.m)
        v = jax.tree_util.tree_map(blk_spec, state_shapes.opt.v)
        ms = jax.tree_util.tree_map(blk_spec, state_shapes.opt.m_scale)
        vs = jax.tree_util.tree_map(blk_spec, state_shapes.opt.v_scale)
    else:
        m = v = p_spec
        ms = vs = None
    from repro.train.step import TrainState
    from repro.train import optimizer as opt
    return TrainState(
        params=p_spec,
        opt=opt.AdamState(m=m, v=v, m_scale=ms, v_scale=vs),
        moe_state=jax.tree_util.tree_map(lambda _: P(),
                                         state_shapes.moe_state),
        step=P())
