"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
smoke tests must see 1 CPU device while the dry-run sees 512 placeholders).
"""
from __future__ import annotations

import jax

from repro.config import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    return make_production_mesh(multi_pod=cfg.multi_pod)


def make_host_mesh():
    """Single-device mesh for CPU examples/tests (degenerate 1x1)."""
    return jax.make_mesh((1, 1), ("data", "model"))
