"""Serving launcher: prefill + continuous decode behind the MIDAS router.

On a real cluster this runs one router process in front of N replica
groups, each holding the model under the serve/serve_2d/serve_decode_moe
shardings that launch/dryrun.py lowers.  On this CPU container it drives
the reduced configs end-to-end.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --requests 32 --decode-len 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.config import RunConfig, get_smoke_arch
from repro.serve import MidasRouter
from repro.serve.step import make_prefill_step, make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-len", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_arch(args.arch)
    run = RunConfig(arch=args.arch)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.decode_len
    prefill = jax.jit(make_prefill_step(cfg, run, cache_len=max_seq))
    decode = jax.jit(make_serve_step(cfg, run))
    router = MidasRouter(replicas=args.replicas, d=3, f_max=0.25)

    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    tokens_out = 0
    for req in range(args.requests):
        session = int(rng.zipf(1.4)) % 16
        replica, steered, hit = router.route(session, req * 50.0,
                                             prefix_hash=session % 4)
        prompt = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (1, args.prompt_len)),
            jnp.int32)
        if cfg.frontend == "vlm_patches":
            batch = {"tokens": prompt,
                     "patches": jnp.zeros((1, cfg.frontend_tokens,
                                           cfg.d_model))}
        else:
            batch = {"tokens": prompt}
        logits, cache = prefill(params, batch)
        cache = jax.tree_util.tree_map(
            lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16
            else a, cache)
        tok = jnp.argmax(logits[:, -1].astype(jnp.float32),
                         axis=-1)[:, None].astype(jnp.int32)
        for t in range(args.decode_len):
            pos = jnp.asarray([args.prompt_len + t], jnp.int32)
            nxt, cache = decode(params, cache, tok, pos)
            tok = nxt[:, None]
            tokens_out += 1
        router.complete(replica)
        router.ingest_telemetry()
    dt = time.monotonic() - t0
    s = router.stats()
    print(f"served {args.requests} requests, {tokens_out} tokens in "
          f"{dt:.1f}s ({tokens_out / dt:.1f} tok/s on 1 CPU)")
    print(f"router: steered={s.steered} prefix_hits={s.cache_hits} "
          f"queue_cv={router.queue_dispersion():.3f}")


if __name__ == "__main__":
    main()
