import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Placeholder devices exist ONLY for the dry-run.

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell, prove it fits (memory_analysis) and extract roofline terms
(cost_analysis + collective bytes parsed from the partitioned HLO).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
      --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse   # noqa: E402
import dataclasses  # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
from pathlib import Path  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.config import (MeshConfig, RunConfig,  # noqa: E402
                          applicable_cells, get_arch, get_shape)
from repro.launch import specs as S  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.sharding import rules as R  # noqa: E402

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# TPU v5e-class constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_SHAPE_RE = re.compile(r"(f8e4m3fn|f8e5m2|bf16|f16|f32|f64|s8|u8|s16|u16|"
                       r"s32|u32|s64|u64|pred|c64|c128)\[([0-9,]*)\]")
_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
          "f32": 4, "s32": 4, "u32": 4, "c64": 8,
          "f64": 8, "s64": 8, "u64": 8, "c128": 16}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op in the partitioned
    (per-device) HLO, bucketed by op kind."""
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        for kind in COLLECTIVES:
            # matches "%name = <shape> all-gather(...)" incl. -start variants
            if f" {kind}(" in ls or f" {kind}-start(" in ls:
                lhs = ls.split(f" {kind}")[0]
                out[kind] += _shape_bytes(lhs)
                counts[kind] += 1
                break
    return out, counts


def pick_rule_set(arch: str, shape_name: str) -> str:
    shape = get_shape(shape_name)
    cfg = get_arch(arch)
    if shape.kind == "train":
        return "train"
    if shape_name == "long_500k":
        return "long"
    # big models need 2D weight sharding to fit serving on 16 GB chips
    if cfg.n_params() * 2 / 16 > 12e9:
        return "serve_2d"
    return "serve"


def _named(mesh, spec_tree):
    from jax.sharding import NamedSharding
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, mesh_cfg: MeshConfig,
               run: RunConfig | None = None):
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    run = run or RunConfig(arch=arch, shape=shape_name, mesh=mesh_cfg)
    mesh = make_mesh(mesh_cfg)
    rule_set = run.sharding_rules if run.sharding_rules != "default" \
        else pick_rule_set(arch, shape_name)
    rules = R.make_rules(rule_set, mesh)

    with mesh, R.use_rules(rules):
        if shape.kind == "train":
            from repro.train.step import make_train_step, train_state_shapes
            step = make_train_step(cfg, run)
            state_shapes = train_state_shapes(cfg, run)
            state_spec = S.train_state_pspec(cfg, run, rules, state_shapes)
            batch = S.input_specs(cfg, shape)
            batch_spec = S.batch_pspec(cfg, shape, rules)
            lowered = jax.jit(
                step,
                in_shardings=(_named(mesh, state_spec),
                              _named(mesh, batch_spec)),
                out_shardings=(_named(mesh, state_spec), None),
                donate_argnums=(0,),
            ).lower(state_shapes, batch)
        elif shape.kind == "prefill":
            from repro.serve.step import make_prefill_step
            step = make_prefill_step(cfg, run)
            from repro import models
            params = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
                models.param_shapes(cfg))
            p_spec = S.params_pspec(cfg, rules)
            batch = S.input_specs(cfg, shape)
            batch_spec = S.batch_pspec(cfg, shape, rules)
            lowered = jax.jit(
                step,
                in_shardings=(_named(mesh, p_spec),
                              _named(mesh, batch_spec)),
            ).lower(params, batch)
        else:  # decode
            from repro import models
            from repro.serve.step import make_serve_step
            step = make_serve_step(cfg, run)
            params = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
                models.param_shapes(cfg))
            p_spec = S.params_pspec(cfg, rules)
            tokens, pos, cache = S.decode_input_specs(cfg, shape)
            c_spec = S.cache_pspec(cfg, shape, rules)
            lowered = jax.jit(
                step,
                in_shardings=(_named(mesh, p_spec), _named(mesh, c_spec),
                              _named(mesh, P()), _named(mesh, P())),
                out_shardings=(_named(mesh, P()), _named(mesh, c_spec)),
                donate_argnums=(1,),
            ).lower(params, cache, tokens, pos)
    return lowered, dict(rule_set=rule_set, kind=shape.kind)


def analyse(lowered, compiled, mesh_cfg: MeshConfig, cfg, shape):
    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        mem_info = dict(
            argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
            output_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
            temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
            peak_bytes=int(getattr(mem, "temp_size_in_bytes", 0))
            + int(getattr(mem, "argument_size_in_bytes", 0)),
        )
    except Exception as e:  # pragma: no cover
        mem_info = {"error": str(e)}
    hlo = compiled.as_text()
    coll, coll_counts = collective_bytes(hlo)
    coll_total = sum(coll.values())

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll_total / ICI_BW
    terms = dict(compute_s=compute_s, memory_s=memory_s,
                 collective_s=collective_s)
    dominant = max(terms, key=terms.get)

    # useful model flops: 6·N_active·D for train (fwd+bwd), 2·N_active·D fwd
    n_active = cfg.n_active_params()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    model_flops_total = mult * n_active * tokens
    model_flops_per_dev = model_flops_total / mesh_cfg.num_devices
    useful_ratio = model_flops_per_dev / flops if flops else 0.0

    return dict(
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        collective_bytes_per_device=coll,
        collective_counts=coll_counts,
        collective_total_bytes=coll_total,
        memory=mem_info,
        roofline=dict(**terms, dominant=dominant,
                      model_flops_per_device=model_flops_per_dev,
                      useful_flops_ratio=useful_ratio),
    )


def _costs(compiled):
    cost = compiled.cost_analysis() or {}
    coll, counts = collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll, counts)


def _cost_extrapolated(arch: str, shape_name: str, mesh_cfg: MeshConfig,
                       run: RunConfig | None):
    """XLA cost_analysis counts while-loop bodies ONCE (verified: a scan of
    10 matmuls reports 1 matmul of flops), so scanned-layer models are
    undercounted.  Fix: compile 1-block and 2-block depth variants and
    extrapolate  cost(n) = c1 + (n-1)·(c2 - c1)  — scan bodies are
    identical across iterations.  The Mamba inner chunk-scan is switched
    to the fully-parallel formulation (REPRO_SSM_PARALLEL) during these
    cost compiles so its flops are visible too."""
    import repro.config as C
    from repro.models.model import block_pattern
    cfg = get_arch(arch)
    blen = len(block_pattern(cfg))
    n = cfg.num_layers // blen
    # pin the rule set chosen for the FULL config (pick_rule_set depends on
    # n_params, which shrinks in the shallow variants)
    run = dataclasses.replace(
        run or RunConfig(arch=arch, shape=shape_name, mesh=mesh_cfg),
        sharding_rules=pick_rule_set(arch, shape_name)
        if (run is None or run.sharding_rules == "default") else
        run.sharding_rules)

    os.environ["REPRO_SSM_PARALLEL"] = "1"
    os.environ["REPRO_SCAN_FULL_UNROLL"] = "1"
    try:
        outs = []
        for k in (1, 2):
            cfg_k = dataclasses.replace(cfg, num_layers=k * blen)
            C.ARCH_REGISTRY[cfg_k.name] = cfg_k  # shadow temporarily
            try:
                lo, _ = lower_cell(cfg_k.name, shape_name, mesh_cfg, run)
                outs.append(_costs(lo.compile()))
            finally:
                C.ARCH_REGISTRY[cfg_k.name] = cfg
    finally:
        os.environ.pop("REPRO_SSM_PARALLEL", None)
        os.environ.pop("REPRO_SCAN_FULL_UNROLL", None)

    (f1, b1, c1, _), (f2, b2, c2, _) = outs
    flops = f1 + (n - 1) * (f2 - f1)
    byts = b1 + (n - 1) * (b2 - b1)
    coll = {k: c1[k] + (n - 1) * (c2[k] - c1[k]) for k in c1}
    return flops, byts, coll


def _state_bytes_per_device(arch, shape_name, mesh_cfg, run, rule_set):
    """Exact persistent-state (params/opt/cache) bytes per device from the
    shardings — the 'does it fit' number (CPU memory_analysis lacks TPU
    buffer reuse, so temp_bytes there is only an upper bound)."""
    from repro import models
    from repro.train.step import train_state_shapes
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = make_mesh(MeshConfig(multi_pod=mesh_cfg.multi_pod))
    rules = R.make_rules(rule_set, mesh)

    def shard_bytes(tree, spec_tree):
        total = 0
        specs = jax.tree_util.tree_leaves(
            spec_tree, is_leaf=lambda x: isinstance(x, P))
        for leaf, spec in zip(jax.tree_util.tree_leaves(tree), specs):
            n = 1
            for ax in spec:
                if ax is None:
                    continue
                for a in ((ax,) if isinstance(ax, str) else ax):
                    n *= mesh.shape[a]
            total += leaf.size * leaf.dtype.itemsize // n
        return total

    if shape.kind == "train":
        run = run or RunConfig(arch=arch, shape=shape_name, mesh=mesh_cfg)
        ss = train_state_shapes(cfg, run)
        spec = S.train_state_pspec(cfg, run, rules, ss)
        return shard_bytes(ss, spec)
    params = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
        models.param_shapes(cfg))
    total = shard_bytes(params, S.params_pspec(cfg, rules))
    if shape.kind == "decode":
        _, _, cache = S.decode_input_specs(cfg, shape)
        total += shard_bytes(cache, S.cache_pspec(cfg, shape, rules))
    return total


def model_memory_bytes(arch: str, shape_name: str, mesh_cfg: MeshConfig,
                       kind: str) -> float:
    """Analytic HBM traffic per device per step, assuming TPU-grade fusion
    (flash attention => no S² materialization).  The HLO 'bytes accessed'
    from the CPU backend counts pre-fusion operand bytes and overestimates
    HBM traffic by >100x, so the dominant-term decision uses this model;
    both numbers are reported."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    tp = 16
    dp = mesh_cfg.num_devices // tp
    N = cfg.n_params()
    L = cfg.num_layers
    d = cfg.d_model
    B_loc = max(shape.global_batch // dp, 1)
    S = shape.seq_len
    if kind == "train":
        weights = 3 * (2 * N / tp)            # fwd + remat'd bwd re-gather
        opt = 28 * N / (tp * dp)              # grad write + adam m/v rw fp32
        acts = 2 * 6 * L * B_loc * S * d * 2  # ckpt save + reload (bf16)
        return weights + opt + acts
    if kind == "prefill":
        weights = 2 * N / tp
        acts = 6 * L * B_loc * S * d * 2
        cache = (2 * sum(1 for i in range(L) if cfg.layer_kind(i)[0] ==
                         "attn") * B_loc * S * cfg.num_kv_heads
                 * cfg.resolved_head_dim * 2)
        return weights + acts + cache
    # decode: stream the TP weight shard + read the KV cache shard
    weights = 2 * cfg.n_active_params() / tp
    n_attn = sum(1 for i in range(L) if cfg.layer_kind(i)[0] == "attn")
    cache = 2 * n_attn * (shape.global_batch / dp) * S \
        * cfg.num_kv_heads * cfg.resolved_head_dim * 2
    return weights + cache


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             force: bool = False, run: RunConfig | None = None,
             tag: str = "") -> dict:
    mesh_cfg = MeshConfig(multi_pod=multi_pod)
    name = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    if tag:
        name += f"__{tag}"
    out_path = OUT_DIR / f"{name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, mesh_cfg, run)
    t1 = time.time()
    compiled = lowered.compile()   # full-depth: proves the cell compiles
    t2 = time.time()
    result = dict(arch=arch, shape=shape_name, multi_pod=multi_pod,
                  devices=mesh_cfg.num_devices, **meta,
                  lower_s=round(t1 - t0, 1), compile_s=round(t2 - t1, 1),
                  **analyse(lowered, compiled, mesh_cfg, cfg, shape))
    # scan-corrected costs (see _cost_extrapolated)
    flops, byts, coll = _cost_extrapolated(arch, shape_name, mesh_cfg, run)
    result["flops_per_device"] = flops
    result["bytes_per_device"] = byts
    result["collective_bytes_per_device"] = coll
    result["collective_total_bytes"] = sum(coll.values())
    rf = result["roofline"]
    rf["compute_s"] = flops / PEAK_FLOPS
    rf["memory_s_hlo"] = byts / HBM_BW          # spec formula (CPU caveat)
    mem_model = model_memory_bytes(arch, shape_name, mesh_cfg, shape.kind)
    rf["memory_bytes_model"] = mem_model
    rf["memory_s"] = mem_model / HBM_BW
    rf["collective_s"] = sum(coll.values()) / ICI_BW
    terms = {k: rf[k] for k in ("compute_s", "memory_s", "collective_s")}
    rf["dominant"] = max(terms, key=terms.get)
    rf["useful_flops_ratio"] = (rf["model_flops_per_device"] / flops
                                if flops else 0.0)
    result["state_bytes_per_device"] = _state_bytes_per_device(
        arch, shape_name, mesh_cfg, run, meta["rule_set"])
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--keep-going", action="store_true")
    args = ap.parse_args()

    cells = (applicable_cells() if args.all
             else [(args.arch, args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch, shape in cells:
        for mp in meshes:
            try:
                r = run_cell(arch, shape, mp, force=args.force)
                rf = r["roofline"]
                print(f"OK  {arch:26s} {shape:12s} pods={2 if mp else 1} "
                      f"flops/dev={r['flops_per_device']:.3e} "
                      f"comp={rf['compute_s'] * 1e3:8.2f}ms "
                      f"mem={rf['memory_s'] * 1e3:8.2f}ms "
                      f"coll={rf['collective_s'] * 1e3:8.2f}ms "
                      f"dom={rf['dominant']:13s} "
                      f"useful={rf['useful_flops_ratio'] * 100:5.1f}% "
                      f"[lower {r['lower_s']}s compile {r['compile_s']}s]",
                      flush=True)
            except Exception as e:
                print(f"FAIL {arch} {shape} pods={2 if mp else 1}: "
                      f"{type(e).__name__}: {e}", flush=True)
                if not args.keep_going:
                    raise
            finally:
                jax.clear_caches()


if __name__ == "__main__":
    main()
