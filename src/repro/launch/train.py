"""Training launcher.

On a real cluster: one process per host, ``jax.distributed.initialize()``,
the production mesh from mesh.py, shardings from launch/specs.py (exactly
what dryrun.py lowers), the Trainer loop around it.  On this CPU container
it runs the same Trainer on the reduced (smoke) configs end-to-end.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --steps 100 --batch 8 --seq 128 [--full-config] [--ckpt-dir /tmp/ck]
"""
from __future__ import annotations

import argparse

from repro.config import RunConfig, get_arch, get_smoke_arch
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full arch (needs the production mesh); "
                         "default uses the reduced smoke config")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adamw8bit"])
    args = ap.parse_args()

    cfg = (get_arch(args.arch) if args.full_config
           else get_smoke_arch(args.arch))
    run = RunConfig(arch=args.arch, learning_rate=args.lr,
                    remat_policy=args.remat, optimizer=args.optimizer)
    tc = TrainerConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    trainer = Trainer(cfg, run, tc)
    state = trainer.train()
    print(f"done at step {int(state.step)}")


if __name__ == "__main__":
    main()
