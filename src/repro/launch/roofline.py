"""Roofline report generator: reads experiments/dryrun/*.json and emits
the EXPERIMENTS.md §Roofline table (single-pod baseline per spec) plus a
per-cell bottleneck sentence.

  PYTHONPATH=src python -m repro.launch.roofline [--pods 1|2] [--markdown]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

FIX_HINTS = {
    # what would move the dominant term down, per (dominant, regime)
    ("compute_s", "replicated_attn"):
        "shard attention over head_dim (heads % 16 != 0 leaves QKV "
        "replicated on the model axis)",
    ("compute_s", "quadratic"):
        "quadratic attention dominates at 32k: block-sparse/windowed "
        "attention or context-parallel splits the S^2 term",
    ("compute_s", "moe"):
        "lower MoE capacity_factor / MIDAS dispatch to cut padded "
        "expert-buffer compute",
    ("compute_s", None):
        "increase per-device batch (more useful flops per gathered byte)",
    ("memory_s", "decode"):
        "decode is KV-bound: quantize the KV cache (int8) or shard it "
        "wider (cache_seq over data)",
    ("memory_s", None):
        "fuse/cast activations to bf16 and tighten the remat policy",
    ("collective_s", "fsdp"):
        "FSDP gathers dominate: overlap via scan pipelining, gather in "
        "bf16, or reduce-scatter grads instead of all-reduce",
    ("collective_s", "moe"):
        "EP all-to-all + FSDP gathers: keep experts resident (no FSDP on "
        "expert weights) and all-to-all only token slices",
    ("collective_s", None):
        "re-order shardings to turn all-gathers into reduce-scatters",
}


def classify(rec) -> str | None:
    arch, shape = rec["arch"], rec["shape"]
    dom = rec["roofline"]["dominant"]
    moe = "moe" in arch or "dbrx" in arch or "qwen3" in arch \
        or "jamba" in arch
    if dom == "compute_s":
        if rec["roofline"]["useful_flops_ratio"] < 0.08 and \
                "prefill" in shape:
            return "quadratic"
        if rec["roofline"]["useful_flops_ratio"] < 0.3 and moe:
            return "moe"
        if rec["roofline"]["useful_flops_ratio"] < 0.3:
            return "replicated_attn"
    if dom == "memory_s" and rec["kind"] == "decode":
        return "decode"
    if dom == "collective_s":
        return "moe" if moe else "fsdp"
    return None


def load(pods: int):
    recs = []
    for p in sorted(OUT_DIR.glob(f"*__pod{pods}*.json")):
        if "__hc" in p.name:        # hillclimb variants excluded
            continue
        recs.append(json.loads(p.read_text()))
    return recs


def report(pods: int = 1, markdown: bool = True) -> str:
    recs = load(pods)
    lines = []
    if markdown:
        lines.append(
            "| arch | shape | rules | compute s | memory s (model) | "
            "collective s | dominant | MODEL_FLOPS/dev | useful ratio | "
            "state GB/dev | bottleneck note |")
        lines.append("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        rf = r["roofline"]
        hint = FIX_HINTS.get((rf["dominant"], classify(r)),
                             FIX_HINTS[(rf["dominant"], None)])
        dom = rf["dominant"].replace("_s", "")
        state_gb = r.get("state_bytes_per_device", 0) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['rule_set']} | "
            f"{rf['compute_s']:.3f} | {rf['memory_s']:.3f} | "
            f"{rf['collective_s']:.3f} | **{dom}** | "
            f"{rf['model_flops_per_device']:.2e} | "
            f"{rf['useful_flops_ratio'] * 100:.1f}% | "
            f"{state_gb:.2f} | {hint} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pods", type=int, default=1)
    args = ap.parse_args()
    print(report(args.pods))


if __name__ == "__main__":
    main()
