"""MIDAS-scheduled checkpoint writer lanes.

Checkpoint storms are the paper's motivating scenario: thousands of ranks
dump state at once and a few I/O paths melt.  Here every tensor write is a
"request", writer lanes are the "servers", and lane assignment uses the
paper's policy: consistent-hash primary (stable leaf→lane affinity across
checkpoints => file locality) refined by power-of-d on live lane backlog
with the Δ_L margin (in bytes) — identical structure to core/routing.py,
applied host-side.
"""
from __future__ import annotations

import queue
import threading
import zlib
from pathlib import Path
from typing import List

import numpy as np

from repro.core.hashring import hash2

DELTA_L_BYTES = 1 << 20       # steer only when >= 1 MiB lighter


class WriterPool:
    def __init__(self, lanes: int, policy: str = "midas", d: int = 3):
        assert policy in ("midas", "round_robin", "hash")
        self.n = lanes
        self.policy = policy
        self.d = max(1, min(d, 4))    # paper's d range
        self._backlog = [0] * lanes          # queued bytes per lane
        self._written = [0] * lanes
        self._rr = 0
        self._queues: List[queue.Queue] = [queue.Queue() for _ in range(lanes)]
        self._threads = [threading.Thread(target=self._worker, args=(i,),
                                          daemon=True)
                         for i in range(lanes)]
        self._lock = threading.Lock()
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------ scheduling
    def assign(self, name: str, nbytes: int) -> int:
        if self.policy == "round_robin":
            lane = self._rr % self.n
            self._rr += 1
        else:
            key = zlib.crc32(name.encode())      # deterministic across runs
            primary = int(hash2(np.uint32(key), np.uint32(13))) % self.n
            lane = primary
            if self.policy == "midas" and self.n > 1:
                # power-of-d: sample d-1 alternates, steer on byte margin
                with self._lock:
                    alts = [int(hash2(np.uint32((key + i + 1)
                                                & 0xFFFFFFFF),
                                      np.uint32(29))) % self.n
                            for i in range(self.d - 1)]
                    best = min(alts, key=lambda a: self._backlog[a])
                    if (self._backlog[primary] - self._backlog[best]
                            >= DELTA_L_BYTES):
                        lane = best
        with self._lock:
            self._backlog[lane] += nbytes
        return lane

    # --------------------------------------------------------------- writing
    def submit(self, lane: int, path: Path, arr: np.ndarray) -> None:
        self._queues[lane].put((path, arr))

    def _worker(self, lane: int) -> None:
        q = self._queues[lane]
        while True:
            item = q.get()
            if item is None:
                return
            path, arr = item
            np.save(path, arr)
            with self._lock:
                self._backlog[lane] -= arr.nbytes
                self._written[lane] += arr.nbytes
            q.task_done()

    def join(self) -> None:
        for q in self._queues:
            q.join()

    def lane_bytes(self) -> List[int]:
        return list(self._written)

    def backlogs(self) -> List[int]:
        """Snapshot of queued-but-unwritten bytes per lane (the live
        load the scheduler steers on).  Public accessor — callers must
        not reach into ``_backlog``, which is lock-protected and
        mutated concurrently by the worker threads."""
        with self._lock:
            return list(self._backlog)

    def dispersion(self) -> float:
        w = np.asarray(self._written, np.float64)
        if w.mean() <= 0:
            return 0.0
        return float(w.std() / w.mean())
