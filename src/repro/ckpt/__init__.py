from repro.ckpt.checkpoint import CheckpointManager  # noqa: F401
from repro.ckpt.midas_writer import WriterPool  # noqa: F401
