"""Sharded, fault-tolerant checkpointing (no external deps).

Layout:  <root>/step_<N>/
           manifest.json     — leaf paths, shapes, dtypes, crc32, lane
           lane<k>/<idx>.npy — tensor payloads, one file per leaf

Properties needed at 1000-node scale, all implemented here:
  * atomicity      — writes go to step_<N>.tmp, fsync'd, then renamed;
                     a crashed save can never be mistaken for a complete
                     checkpoint (restore only trusts manifests).
  * integrity      — per-leaf crc32 verified on load.
  * async          — save returns a future; writer lanes run in threads
                     (the GIL is released inside np.save's IO).
  * elasticity     — restore is topology-agnostic: leaves are loaded by
                     name and re-placed under ANY mesh/sharding, so a job
                     can restart on a different pod count.
  * storm control  — leaf→lane assignment uses the MIDAS power-of-d
                     policy on live lane backlog (see midas_writer.py);
                     checkpoint storms are the paper's headline scenario.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.ckpt.midas_writer import WriterPool


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, root: str, *, lanes: int = 4, keep: int = 3,
                 policy: str = "midas"):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.lanes = lanes
        self.keep = keep
        self.policy = policy
        self._exec = ThreadPoolExecutor(max_workers=1)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, blocking: bool = True
             ) -> Optional[Future]:
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        if blocking:
            self._save(step, host_tree)
            return None
        return self._exec.submit(self._save, step, host_tree)

    def _save(self, step: int, host_tree) -> None:
        final = self.root / f"step_{step:08d}"
        tmp = self.root / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        leaves = _flatten(host_tree)
        pool = WriterPool(self.lanes, policy=self.policy)
        manifest: Dict[str, Any] = {"step": step, "leaves": {}}
        for idx, (name, arr) in enumerate(leaves):
            lane = pool.assign(name, int(arr.nbytes))
            lane_dir = tmp / f"lane{lane}"
            lane_dir.mkdir(exist_ok=True)
            fname = f"lane{lane}/{idx}.npy"
            manifest["leaves"][name] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
                "lane": lane,
            }
            pool.submit(lane, tmp / fname, arr)
        pool.join()
        manifest["lane_bytes"] = pool.lane_bytes()
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        with open(tmp / "manifest.json", "rb") as f:
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for p in self.root.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree):
        """Restore into the STRUCTURE of target_tree (shapes verified,
        checksums checked).  Device placement / sharding is the caller's
        choice — re-shard freely on a different topology."""
        d = self.root / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        names = dict(_flatten(target_tree))
        out = {}
        for name, meta in manifest["leaves"].items():
            arr = np.load(d / meta["file"])
            if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != \
                    meta["crc32"]:
                raise IOError(f"checksum mismatch for {name}")
            if name in names and tuple(arr.shape) != tuple(
                    np.shape(names[name])):
                raise ValueError(
                    f"shape mismatch for {name}: ckpt {arr.shape} vs "
                    f"target {np.shape(names[name])}")
            out[name] = arr
        missing = set(n for n, _ in _flatten(target_tree)) - set(out)
        if missing:
            raise KeyError(f"checkpoint missing leaves: {sorted(missing)}")

        leaves_meta, treedef = jax.tree_util.tree_flatten_with_path(
            target_tree)
        vals = []
        for path, _ in leaves_meta:
            name = "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            vals.append(out[name])
        return jax.tree_util.tree_unflatten(treedef, vals)

    def restore_latest(self, target_tree):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, target_tree)
