"""Public wrapper for MIDAS MoE dispatch."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.midas_route import ref

topk_dispatch = ref.topk_dispatch
expert_load = ref.expert_load


def midas_dispatch(gate_logits: jnp.ndarray, load: jnp.ndarray, k: int,
                   d: int, *, delta_l: float = 2.0, gate_slack: float = 1.0,
                   f_max: float = 0.25, impl: str | None = None):
    impl = impl or common.default_impl()
    # the Pallas kernel implements the margin-governed variant; global
    # quantile caps (f_max < 1) need a cross-tile reduction and stay on the
    # reference path (see kernel.py docstring)
    if impl == "ref" or f_max < 1.0:
        return ref.midas_dispatch(gate_logits, load, k, d, delta_l=delta_l,
                                  gate_slack=gate_slack, f_max=f_max)
    from repro.kernels.midas_route import kernel
    return kernel.midas_dispatch(gate_logits, load, k, d, delta_l=delta_l,
                                 gate_slack=gate_slack, f_max=f_max,
                                 interpret=common.interpret_mode())
