"""Public wrappers for MIDAS routing: MoE dispatch + engine wave routing."""

from __future__ import annotations

import warnings

import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.midas_route import ref

topk_dispatch = ref.topk_dispatch
expert_load = ref.expert_load

_DECLINED_WARNED = False


def _warn_declined(reason: str) -> None:
    """One-time warning when impl="pallas" was requested but declined, so a
    benchmark run can't quietly measure the reference path."""
    global _DECLINED_WARNED
    if _DECLINED_WARNED:
        return
    _DECLINED_WARNED = True
    msg = f"midas_dispatch: impl='pallas' requested but declined ({reason})"
    warnings.warn(msg, RuntimeWarning, stacklevel=3)
    try:
        from repro.obs import trace as obs_trace

        obs_trace.instant("kernel.pallas_declined", reason=reason)
    except Exception:
        pass


def midas_dispatch(
    gate_logits: jnp.ndarray,
    load: jnp.ndarray,
    k: int,
    d: int,
    *,
    delta_l: float = 2.0,
    gate_slack: float = 1.0,
    f_max: float = 1.0,
    impl: str | None = None,
):
    """MoE expert dispatch; default ``f_max=1.0`` matches ``ref`` and
    ``kernel`` (one shared default across all three layers).

    The Pallas kernel now covers BOTH variants — margin-governed
    (``f_max >= 1``, single pass) and f_max-capped (``f_max < 1``,
    two-pass grid with the cross-tile quantile between passes) — so an
    ``impl="pallas"`` request is only declined when the kernel has no
    work to do (``d_eff <= 0`` collapses to plain top-k), and that
    decline is surfaced once via ``warnings.warn`` + an obs trace event.
    """
    impl = impl or common.default_impl()
    if impl == "ref":
        return ref.midas_dispatch(
            gate_logits,
            load,
            k,
            d,
            delta_l=delta_l,
            gate_slack=gate_slack,
            f_max=f_max,
        )
    E = gate_logits.shape[-1]
    if min(d, E - k) <= 0:
        _warn_declined(f"d_eff <= 0 for E={E}, k={k}, d={d}; plain top-k")
        return ref.midas_dispatch(
            gate_logits,
            load,
            k,
            d,
            delta_l=delta_l,
            gate_slack=gate_slack,
            f_max=f_max,
        )
    from repro.kernels.midas_route import kernel

    return kernel.midas_dispatch(
        gate_logits,
        load,
        k,
        d,
        delta_l=delta_l,
        gate_slack=gate_slack,
        f_max=f_max,
        interpret=common.interpret_mode(),
    )


def route_waves(feas, load, p50, sampled, tie, scalars, *, mode: str):
    """Batched feasible-set routing for the engine's wave step.

    Accepts any number of leading batch axes on ``feas``/``sampled``/
    ``tie`` (waves × requests); they are flattened into one request axis
    for the kernel grid and restored on return.  Policies call this only
    on their ``route_impl="pallas"`` branch (the ref branch IS the
    existing jnp expression), so interpret mode simply follows the
    backend.  See :func:`repro.kernels.midas_route.kernel.route_select`
    for argument semantics.
    """
    from repro.kernels.midas_route import kernel

    lead = feas.shape[:-1]
    R = 1
    for s in lead:
        R *= s
    d_max = feas.shape[-1]
    assign, ok_any = kernel.route_select(
        feas.reshape(R, d_max),
        load,
        p50,
        sampled.reshape(R, d_max),
        tie.reshape(R, d_max),
        jnp.asarray(scalars, jnp.float32).reshape(1, 4),
        mode=mode,
        interpret=common.interpret_mode(),
    )
    return assign.reshape(lead), ok_any.reshape(lead)
