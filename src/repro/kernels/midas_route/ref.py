"""MIDAS expert dispatch — the paper's routing algorithm adapted to MoE.

Mapping (paper -> MoE):
  * servers            -> experts
  * request            -> (token, slot) assignment, slot in 0..k-1
  * consistent-hash primary -> gate-ranked expert for that slot
  * feasible set F(r)  -> top-(k+d) experts by gate logit (quality
                          constraint = namespace constraint)
  * queue telemetry L̂  -> EWMA of per-expert token load from previous
                          steps (stale telemetry, exactly like the paper's
                          one-fast-interval-delayed view)
  * Δ_L margin         -> load margin in units of mean tokens/expert;
                          Δ_L >= 2 keeps the Lyapunov argument: moving one
                          token from expert p to expert j with
                          L̂_p − L̂_j >= 2 strictly decreases
                          V = Σ(L̂_i − L̄)²
  * Δ_t latency margin -> gate-logit slack (don't steer to a much worse
                          expert)
  * f_max leaky bucket -> at most f_max of tokens steered per slot,
                          benefit-ranked
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def topk_dispatch(
    gate_logits: jnp.ndarray,
    k: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Vanilla top-k routing: experts (T, k), weights = softmax over the
    chosen logits."""
    vals, experts = jax.lax.top_k(gate_logits, k)
    weights = jax.nn.softmax(vals.astype(jnp.float32), axis=-1)
    return experts.astype(jnp.int32), weights


def steer_from_candidates(
    cand: jnp.ndarray,
    vals: jnp.ndarray,
    load: jnp.ndarray,
    k: int,
    *,
    delta_l: float = 2.0,
    gate_slack: float = 1.0,
    f_max: float = 1.0,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Margin + f_max-capped steering over precomputed top-(k+d) candidates.

    ``cand``/``vals`` are the (T, k+d) gate-ranked candidate ids/logits
    (slots 0..k-1 primary, k.. the d steering alternates).  Shared by the
    pure-jnp reference (candidates from ``jax.lax.top_k``) and the Pallas
    f_max-capped path (candidates from the tiled kernel pass) — sharing
    the function is what makes their parity bitwise, not approximate.
    The global f_max quantile is the cross-tile reduction: it ranks the
    per-token steering benefit over the WHOLE batch, so it runs between
    the two kernel passes rather than inside a token tile.
    """
    d_eff = cand.shape[1] - k
    loadf = load.astype(jnp.float32)

    chosen = []
    chosen_vals = []
    steered_flags = []
    alt_used = jnp.zeros((cand.shape[0], d_eff), bool)
    alt_ids = cand[:, k:]  # (T, d)
    alt_vals = vals[:, k:]
    for i in range(k):
        prim = cand[:, i]
        prim_val = vals[:, i]
        ok = (
            ~alt_used
            & (loadf[alt_ids] <= loadf[prim][:, None] - delta_l)
            & (alt_vals >= prim_val[:, None] - gate_slack)
        )
        alt_load = jnp.where(ok, loadf[alt_ids], jnp.inf)
        best = jnp.argmin(alt_load, axis=-1)  # (T,)
        has = jnp.any(ok, axis=-1)
        benefit = jnp.where(
            has,
            loadf[prim] - jnp.min(alt_load, axis=-1),
            -jnp.inf,
        )
        # f_max cap per slot: steer only the most-beneficial fraction
        if f_max >= 1.0:
            steer = has & (benefit >= delta_l)
        elif f_max <= 0.0:
            steer = jnp.zeros_like(has)
        else:
            finite = jnp.where(jnp.isfinite(benefit), benefit, -1e9)
            q = jnp.quantile(finite, 1.0 - f_max)
            steer = has & (benefit > jnp.maximum(q, delta_l - 1e-9))
        alt_best_id = jnp.take_along_axis(alt_ids, best[:, None], axis=1)
        alt_best_val = jnp.take_along_axis(alt_vals, best[:, None], axis=1)
        e_i = jnp.where(steer, alt_best_id[:, 0], prim)
        v_i = jnp.where(steer, alt_best_val[:, 0], prim_val)
        sel = jnp.arange(d_eff)[None] == best[:, None]
        alt_used = alt_used | (steer[:, None] & sel)
        chosen.append(e_i)
        chosen_vals.append(v_i)
        steered_flags.append(steer)

    experts = jnp.stack(chosen, axis=1)
    cv = jnp.stack(chosen_vals, 1).astype(jnp.float32)
    weights = jax.nn.softmax(cv, axis=-1)
    steered = jnp.stack(steered_flags, axis=1)
    return experts, weights, steered


def midas_dispatch(
    gate_logits: jnp.ndarray,
    load: jnp.ndarray,
    k: int,
    d: int,
    *,
    delta_l: float = 2.0,
    gate_slack: float = 1.0,
    f_max: float = 1.0,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Power-of-d steering over the top-(k+d) gate candidates.

    gate_logits: (T, E) fp32; load: (E,) EWMA token share per expert,
    normalized so a balanced system has load == 1 for every expert.
    Returns (experts (T,k) int32, weights (T,k) f32, steered (T,k) bool).
    The default ``f_max=1.0`` is the margin-governed variant — the same
    default as the Pallas kernel and the ops wrapper (one shared default,
    so which path served a call never silently changes the math).
    """
    T, E = gate_logits.shape
    d_eff = min(d, E - k)
    if d_eff <= 0:
        e, w = topk_dispatch(gate_logits, k)
        return e, w, jnp.zeros_like(e, dtype=bool)

    vals, cand = jax.lax.top_k(gate_logits, k + d_eff)  # (T, k+d)
    return steer_from_candidates(
        cand.astype(jnp.int32),
        vals,
        load,
        k,
        delta_l=delta_l,
        gate_slack=gate_slack,
        f_max=f_max,
    )


def expert_load(experts: jnp.ndarray, E: int) -> jnp.ndarray:
    """Per-expert token share, normalized to mean 1 (balanced == ones)."""
    T, k = experts.shape
    counts = jnp.zeros((E,), jnp.float32).at[experts.reshape(-1)].add(1.0)
    return counts * E / (T * k)
