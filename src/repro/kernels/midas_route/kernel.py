"""Pallas TPU kernel for MIDAS MoE dispatch (top-(k+d) + power-of-d steer).

Grid: token tiles.  Per call: the (tile, E) gate-logit block and the (E,)
load telemetry live in VMEM; top-(k+d) selection is k+d iterated
argmax/mask passes (k+d <= 16 for all assigned archs — cheaper than a full
sort on the VPU), then the steering margins are evaluated exactly as in
the reference.

The global f_max quantile cap is a cross-tile reduction, so the kernel
implements the margin-governed variant (≈ f_max = 1.0, stability still
guaranteed by Δ_L >= 2 / Lyapunov); the control-plane enforces rate caps
upstream.  ops.midas_dispatch therefore routes f_max < 1 calls to the
reference path and uses the kernel for the hot margin-only configuration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0 ** 30


def _body(logits_ref, load_ref, experts_ref, weights_ref, steered_ref, *,
          k: int, d: int, delta_l: float, gate_slack: float, E: int,
          tile: int):
    logits = logits_ref[...].astype(jnp.float32)         # (tile, E)
    load = load_ref[...].astype(jnp.float32)             # (1, E)
    load = load[0]

    # --- top-(k+d) via iterated argmax ---------------------------------
    masked = logits
    ids = []
    vals = []
    for _ in range(k + d):
        idx = jnp.argmax(masked, axis=-1).astype(jnp.int32)  # (tile,)
        val = jnp.max(masked, axis=-1)
        cols = jax.lax.broadcasted_iota(jnp.int32, (tile, E), 1)
        masked = jnp.where(cols == idx[:, None], NEG_INF, masked)
        ids.append(idx)
        vals.append(val)
    cand = jnp.stack(ids, axis=1)                        # (tile, k+d)
    cvals = jnp.stack(vals, axis=1)

    alt_ids = cand[:, k:]                                # (tile, d)
    alt_vals = cvals[:, k:]
    alt_load = load[alt_ids]
    alt_used = jnp.zeros((tile, d), jnp.bool_)

    chosen_e = []
    chosen_v = []
    steer_fl = []
    for i in range(k):
        prim = cand[:, i]
        prim_val = cvals[:, i]
        ok = (~alt_used
              & (alt_load <= load[prim][:, None] - delta_l)
              & (alt_vals >= prim_val[:, None] - gate_slack))
        a_load = jnp.where(ok, alt_load, jnp.inf)
        best = jnp.argmin(a_load, axis=-1)
        has = jnp.any(ok, axis=-1)
        benefit = jnp.where(has, load[prim] - jnp.min(a_load, axis=-1),
                            -jnp.inf)
        steer = has & (benefit >= delta_l)
        cols = jax.lax.broadcasted_iota(jnp.int32, (tile, d), 1)
        sel = cols == best[:, None]
        e_i = jnp.where(steer, jnp.sum(jnp.where(sel, alt_ids, 0), axis=1),
                        prim)
        v_i = jnp.where(steer, jnp.sum(jnp.where(sel, alt_vals, 0.0),
                                       axis=1), prim_val)
        alt_used = alt_used | (steer[:, None] & sel)
        chosen_e.append(e_i)
        chosen_v.append(v_i)
        steer_fl.append(steer)

    ce = jnp.stack(chosen_e, axis=1)
    cv = jnp.stack(chosen_v, axis=1)
    # softmax over chosen logits
    mx = jnp.max(cv, axis=1, keepdims=True)
    ex = jnp.exp(cv - mx)
    w = ex / jnp.sum(ex, axis=1, keepdims=True)

    experts_ref[...] = ce.astype(jnp.int32)
    weights_ref[...] = w.astype(jnp.float32)
    steered_ref[...] = jnp.stack(steer_fl, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "d", "delta_l",
                                             "gate_slack", "f_max", "tile",
                                             "interpret"))
def midas_dispatch(gate_logits, load, k: int, d: int, *,
                   delta_l: float = 2.0, gate_slack: float = 1.0,
                   f_max: float = 1.0, tile: int = 256,
                   interpret: bool = False):
    """Margin-governed MIDAS dispatch (see module docstring re f_max)."""
    T, E = gate_logits.shape
    d_eff = min(d, E - k)
    if d_eff <= 0:
        from repro.kernels.midas_route import ref
        e, w = ref.topk_dispatch(gate_logits, k)
        return e, w, jnp.zeros_like(e, dtype=bool)
    tl = min(tile, T)
    assert T % tl == 0, (T, tl)
    kernel = functools.partial(_body, k=k, d=d_eff, delta_l=delta_l,
                               gate_slack=gate_slack, E=E, tile=tl)
    experts, weights, steered = pl.pallas_call(
        kernel,
        grid=(T // tl,),
        in_specs=[
            pl.BlockSpec((tl, E), lambda i: (i, 0)),
            pl.BlockSpec((1, E), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tl, k), lambda i: (i, 0)),
            pl.BlockSpec((tl, k), lambda i: (i, 0)),
            pl.BlockSpec((tl, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, k), jnp.int32),
            jax.ShapeDtypeStruct((T, k), jnp.float32),
            jax.ShapeDtypeStruct((T, k), jnp.int32),
        ],
        interpret=interpret,
    )(gate_logits.astype(jnp.float32), load[None].astype(jnp.float32))
    return experts, weights, steered.astype(bool)
