"""Pallas TPU kernels for MIDAS routing — MoE dispatch AND engine waves.

This module is the repo's single routing kernel (DESIGN.md §15).  Two
entry points share the VMEM-tiled building blocks:

* :func:`midas_dispatch` — MoE expert dispatch (top-(k+d) + power-of-d
  steer).  Grid: token tiles; the (tile, E) gate-logit block and the
  (E,) load telemetry live in VMEM; top-(k+d) selection is k+d iterated
  argmax/mask passes (k+d <= 16 for all assigned archs — cheaper than a
  full sort on the VPU).  Ragged ``T`` is handled by padding the last
  tile (rows are independent, pads are sliced off).

  The f_max-capped variant runs as a TWO-PASS grid: pass 1 is the tiled
  candidate kernel (:func:`_cand_body`, the O(T·E·(k+d)) selection —
  all the arithmetic intensity); the global f_max quantile is a
  cross-tile reduction over the per-tile partials, so it runs between
  the passes as one XLA sort over the (T,) benefit vector; pass 2 (the
  slot-sequential steering, O(T·(k+d)) elementwise) shares
  ``ref.steer_from_candidates`` with the reference — which is what
  makes ref-vs-kernel parity bitwise rather than approximate.  With
  ``f_max >= 1`` (margin-governed) the single-pass kernel steers
  entirely in VMEM, as before.

* :func:`route_select` — the simulator's per-wave routing core: the
  feasible-set load gather + eligibility masking + tie-broken argmin
  that ``power_of_d`` / ``midas`` / ``chbl`` all reduce to.  Grid:
  request tiles; the (m,) telemetry views sit in VMEM and gathers are
  one-hot contractions (m <= a few hundred servers).  RNG stays
  OUTSIDE the kernel: the engine passes the exact ``jax.random``
  sampling masks and tie-break scores the jnp policies draw, so the
  kernel path is bit-for-bit the reference policy — the golden-parity
  contract extends to ``SimConfig(route_impl="pallas")``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0**30

# engine-wave routing modes served by route_select (the three policies
# whose inner loop is gather + mask + argmin)
ROUTE_MODES = ("power_of_d", "midas", "chbl")


def _iter_topk(logits, kd: int, tile: int, E: int):
    """Top-``kd`` ids/vals per row via iterated argmax/mask (VPU-friendly;
    ties resolve to the lowest index, matching ``jax.lax.top_k``)."""
    masked = logits
    ids = []
    vals = []
    for _ in range(kd):
        idx = jnp.argmax(masked, axis=-1).astype(jnp.int32)  # (tile,)
        val = jnp.max(masked, axis=-1)
        cols = jax.lax.broadcasted_iota(jnp.int32, (tile, E), 1)
        masked = jnp.where(cols == idx[:, None], NEG_INF, masked)
        ids.append(idx)
        vals.append(val)
    return jnp.stack(ids, axis=1), jnp.stack(vals, axis=1)


def _body(
    logits_ref,
    load_ref,
    experts_ref,
    weights_ref,
    steered_ref,
    *,
    k: int,
    d: int,
    delta_l: float,
    gate_slack: float,
    E: int,
    tile: int,
):
    logits = logits_ref[...].astype(jnp.float32)  # (tile, E)
    load = load_ref[...].astype(jnp.float32)  # (1, E)
    load = load[0]

    # --- top-(k+d) via iterated argmax ---------------------------------
    cand, cvals = _iter_topk(logits, k + d, tile, E)  # (tile, k+d)

    alt_ids = cand[:, k:]  # (tile, d)
    alt_vals = cvals[:, k:]
    alt_load = load[alt_ids]
    alt_used = jnp.zeros((tile, d), jnp.bool_)

    chosen_e = []
    chosen_v = []
    steer_fl = []
    for i in range(k):
        prim = cand[:, i]
        prim_val = cvals[:, i]
        ok = (
            ~alt_used
            & (alt_load <= load[prim][:, None] - delta_l)
            & (alt_vals >= prim_val[:, None] - gate_slack)
        )
        a_load = jnp.where(ok, alt_load, jnp.inf)
        best = jnp.argmin(a_load, axis=-1)
        has = jnp.any(ok, axis=-1)
        benefit = jnp.where(
            has,
            load[prim] - jnp.min(a_load, axis=-1),
            -jnp.inf,
        )
        steer = has & (benefit >= delta_l)
        cols = jax.lax.broadcasted_iota(jnp.int32, (tile, d), 1)
        sel = cols == best[:, None]
        sel_id = jnp.sum(jnp.where(sel, alt_ids, 0), axis=1)
        sel_val = jnp.sum(jnp.where(sel, alt_vals, 0.0), axis=1)
        e_i = jnp.where(steer, sel_id, prim)
        v_i = jnp.where(steer, sel_val, prim_val)
        alt_used = alt_used | (steer[:, None] & sel)
        chosen_e.append(e_i)
        chosen_v.append(v_i)
        steer_fl.append(steer)

    ce = jnp.stack(chosen_e, axis=1)
    cv = jnp.stack(chosen_v, axis=1)
    # softmax over chosen logits
    mx = jnp.max(cv, axis=1, keepdims=True)
    ex = jnp.exp(cv - mx)
    w = ex / jnp.sum(ex, axis=1, keepdims=True)

    experts_ref[...] = ce.astype(jnp.int32)
    weights_ref[...] = w.astype(jnp.float32)
    steered_ref[...] = jnp.stack(steer_fl, axis=1).astype(jnp.int32)


def _cand_body(logits_ref, cand_ref, vals_ref, *, kd: int, E: int, tile: int):
    """Pass 1 of the f_max-capped variant: top-(k+d) candidates only."""
    logits = logits_ref[...].astype(jnp.float32)
    cand, cvals = _iter_topk(logits, kd, tile, E)
    cand_ref[...] = cand
    vals_ref[...] = cvals.astype(jnp.float32)


def _pad_rows(x, rows: int):
    """Zero-pad axis 0 to ``rows`` (no-op when already there)."""
    if x.shape[0] == rows:
        return x
    return jnp.pad(x, ((0, rows - x.shape[0]),) + ((0, 0),) * (x.ndim - 1))


@functools.partial(
    jax.jit,
    static_argnames=(
        "k",
        "d",
        "delta_l",
        "gate_slack",
        "f_max",
        "tile",
        "interpret",
    ),
)
def midas_dispatch(
    gate_logits,
    load,
    k: int,
    d: int,
    *,
    delta_l: float = 2.0,
    gate_slack: float = 1.0,
    f_max: float = 1.0,
    tile: int = 256,
    interpret: bool = False,
):
    """MIDAS MoE dispatch (margin-governed AND f_max-capped variants)."""
    T, E = gate_logits.shape
    d_eff = min(d, E - k)
    if d_eff <= 0:
        from repro.kernels.midas_route import ref

        e, w = ref.topk_dispatch(gate_logits, k)
        return e, w, jnp.zeros_like(e, dtype=bool)
    tl = min(tile, T)
    Tp = -(-T // tl) * tl  # rows are independent: pad ragged last tile
    logits_p = _pad_rows(gate_logits.astype(jnp.float32), Tp)
    load2 = load[None].astype(jnp.float32)

    if f_max < 1.0:
        # two-pass grid: tiled candidate kernel, then the cross-tile
        # quantile + steering shared with the reference (module docstring)
        from repro.kernels.midas_route import ref

        kernel = functools.partial(_cand_body, kd=k + d_eff, E=E, tile=tl)
        cand, cvals = pl.pallas_call(
            kernel,
            grid=(Tp // tl,),
            in_specs=[pl.BlockSpec((tl, E), lambda i: (i, 0))],
            out_specs=[
                pl.BlockSpec((tl, k + d_eff), lambda i: (i, 0)),
                pl.BlockSpec((tl, k + d_eff), lambda i: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((Tp, k + d_eff), jnp.int32),
                jax.ShapeDtypeStruct((Tp, k + d_eff), jnp.float32),
            ],
            interpret=interpret,
        )(logits_p)
        return ref.steer_from_candidates(
            cand[:T],
            cvals[:T],
            load,
            k,
            delta_l=delta_l,
            gate_slack=gate_slack,
            f_max=f_max,
        )

    kernel = functools.partial(
        _body,
        k=k,
        d=d_eff,
        delta_l=delta_l,
        gate_slack=gate_slack,
        E=E,
        tile=tl,
    )
    experts, weights, steered = pl.pallas_call(
        kernel,
        grid=(Tp // tl,),
        in_specs=[
            pl.BlockSpec((tl, E), lambda i: (i, 0)),
            pl.BlockSpec((1, E), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tl, k), lambda i: (i, 0)),
            pl.BlockSpec((tl, k), lambda i: (i, 0)),
            pl.BlockSpec((tl, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tp, k), jnp.int32),
            jax.ShapeDtypeStruct((Tp, k), jnp.float32),
            jax.ShapeDtypeStruct((Tp, k), jnp.int32),
        ],
        interpret=interpret,
    )(logits_p, load2)
    return experts[:T], weights[:T], steered[:T].astype(bool)


# ---------------------------------------------------------------------------
# Engine wave routing (simulator hot path)
# ---------------------------------------------------------------------------


def _route_body(
    feas_ref,
    samp_ref,
    tie_ref,
    load_ref,
    p50_ref,
    scal_ref,
    assign_ref,
    okany_ref,
    *,
    mode: str,
    m: int,
    d_max: int,
    tile: int,
):
    """One request tile of wave routing.

    The (m,) telemetry views live whole in VMEM; the feasible-set load
    gather is a one-hot contraction (TPU-friendly for small m).  Every
    comparison/argmin mirrors the jnp policy expression for the same
    inputs, so results are bitwise identical to the reference path.
    """
    feas = feas_ref[...]  # (tile, d_max)
    samp = samp_ref[...] != 0
    tie = tie_ref[...].astype(jnp.float32)
    load = load_ref[...].astype(jnp.float32)[0]  # (m,)

    iota = jax.lax.broadcasted_iota(jnp.int32, (tile, d_max, m), 2)
    oh = feas[..., None] == iota
    lf = jnp.sum(jnp.where(oh, load, 0.0), axis=-1)  # L_view[feas]

    ok_any = jnp.zeros((tile,), jnp.int32)
    if mode == "power_of_d":
        loadv = jnp.where(samp, lf, jnp.inf)
        slot = jnp.argmin(loadv + tie, axis=-1)
    elif mode == "midas":
        p50 = p50_ref[...].astype(jnp.float32)[0]
        p50f = jnp.sum(jnp.where(oh, p50, 0.0), axis=-1)
        delta_l = scal_ref[0, 0]
        delta_t = scal_ref[0, 1]
        # slot 0 IS the primary: lf[:, :1] == L_view[feas[:, 0]]
        ok = (
            samp
            & (lf <= lf[:, :1] - delta_l)
            & (p50f <= p50f[:, :1] - delta_t)
        )
        loadv = jnp.where(ok, lf, jnp.inf)
        slot = jnp.argmin(loadv + tie, axis=-1)
        ok_any = jnp.any(ok, axis=-1).astype(jnp.int32)
    elif mode == "chbl":
        cap = scal_ref[0, 2]
        under = lf <= cap
        first_under = jnp.argmax(under, axis=-1)
        least_loaded = jnp.argmin(lf, axis=-1)
        has_under = jnp.any(under, axis=-1)
        slot = jnp.where(has_under, first_under, least_loaded)
    else:  # pragma: no cover - guarded by route_select
        raise ValueError(f"unknown route mode {mode!r}")

    cols = jax.lax.broadcasted_iota(jnp.int32, (tile, d_max), 1)
    assign = jnp.sum(jnp.where(cols == slot[:, None], feas, 0), axis=-1)
    assign_ref[...] = assign[:, None].astype(jnp.int32)
    okany_ref[...] = ok_any[:, None]


@functools.partial(jax.jit, static_argnames=("mode", "tile", "interpret"))
def route_select(
    feas,
    load,
    p50,
    sampled,
    tie,
    scalars,
    *,
    mode: str,
    tile: int = 128,
    interpret: bool = False,
):
    """Wave-routing core: per-request best feasible server.

    feas: (R, d_max) int32 feasible sets (slot 0 = primary); load/p50:
    (m,) telemetry views; sampled: (R, d_max) int32 0/1 power-of-d
    sampling mask (host-drawn, ignored by chbl); tie: (R, d_max) f32
    tie-break scores (host-drawn); scalars: (1, 4) f32 packed traced
    scalars [delta_l, delta_t, cap, unused].  Returns
    ``(assign (R,) int32, ok_any (R,) bool)`` — ``ok_any`` is midas's
    per-request "any eligible candidate" flag (False elsewhere).
    """
    if mode not in ROUTE_MODES:
        raise ValueError(
            f"unknown route mode {mode!r}; available: "
            f"{', '.join(ROUTE_MODES)}"
        )
    R, d_max = feas.shape
    m = load.shape[0]
    tl = min(tile, R)
    Rp = -(-R // tl) * tl  # requests are independent: pad the last tile
    kernel = functools.partial(
        _route_body,
        mode=mode,
        m=m,
        d_max=d_max,
        tile=tl,
    )
    assign, ok_any = pl.pallas_call(
        kernel,
        grid=(Rp // tl,),
        in_specs=[
            pl.BlockSpec((tl, d_max), lambda i: (i, 0)),
            pl.BlockSpec((tl, d_max), lambda i: (i, 0)),
            pl.BlockSpec((tl, d_max), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tl, 1), lambda i: (i, 0)),
            pl.BlockSpec((tl, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        _pad_rows(feas.astype(jnp.int32), Rp),
        _pad_rows(sampled.astype(jnp.int32), Rp),
        _pad_rows(tie.astype(jnp.float32), Rp),
        load[None].astype(jnp.float32),
        p50[None].astype(jnp.float32),
        scalars.astype(jnp.float32),
    )
    return assign[:R, 0], ok_any[:R, 0].astype(bool)
