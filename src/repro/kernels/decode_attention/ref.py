"""Pure-jnp oracle for single-token KV-cache attention (GQA, windowed)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0**30


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,
    *,
    window: int = 0,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """q: (B, H, D); caches: (B, S, KV, D); pos: (B,) index of the newest
    token (attends to cache[0..pos] inclusive)."""
    B, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    logits = jnp.einsum(
        "bkgd,bskd->bkgs",
        qg.astype(jnp.float32),
        k_cache.astype(jnp.float32),
    )
    logits = logits / jnp.sqrt(D).astype(jnp.float32)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    si = jnp.arange(S)[None, :]
    mask = si <= pos[:, None]
    if window > 0:
        mask &= si > pos[:, None] - window
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)
