"""Pallas TPU decode attention: one query token against a long KV cache.

This op is memory-bound (the whole KV cache streams HBM->VMEM once), so the
kernel's job is to keep the streaming dense and fuse the online softmax.
Grid: (batch, kv_heads, k_blocks); the G = H/KV query heads of a kv group
are processed together as a (G, D) tile — G·D is MXU-aligned for all
assigned archs.  The position bound arrives via scalar prefetch (SMEM) so
block masking needs no HBM traffic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30


def _body(
    pos_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    window: int,
    softcap: float,
    bk: int,
    G: int,
):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    b = pl.program_id(0)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[b]
    q = q_ref[0, 0, :, :].astype(jnp.float32)  # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)

    s = jax.lax.dot_general(
        q,
        k,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s = s * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (G, bk), 1)
    mask = kpos <= pos
    if window > 0:
        mask &= kpos > pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    pv = jax.lax.dot_general(
        p,
        v,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    m_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "block_k", "interpret"),
)
def decode_attention(
    q,
    k_cache,
    v_cache,
    pos,
    *,
    window: int = 0,
    softcap: float = 0.0,
    block_k: int = 1024,
    interpret: bool = False,
):
    """q: (B, H, D); caches: (B, S, KV, D); pos: (B,) -> (B, H, D)."""
    B, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    bk = min(block_k, S)
    assert S % bk == 0, (S, bk)
    qg = q.reshape(B, KV, G, D)
    scale = 1.0 / (D**0.5)

    kernel = functools.partial(
        _body,
        scale=scale,
        window=window,
        softcap=softcap,
        bk=bk,
        G=G,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, S // bk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik, pos: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ik, pos: (b, ik, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ik, pos: (b, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, D),
            lambda b, h, ik, pos: (b, h, 0, 0),
        ),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(pos.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(B, H, D)
