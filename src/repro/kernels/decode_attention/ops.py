"""Jit'd public wrapper for single-token KV-cache attention."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.decode_attention import ref


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,
    *,
    window: int = 0,
    softcap: float = 0.0,
    impl: str | None = None,
) -> jnp.ndarray:
    impl = impl or common.default_impl()
    if impl == "ref":
        return ref.decode_attention(
            q,
            k_cache,
            v_cache,
            pos,
            window=window,
            softcap=softcap,
        )
    from repro.kernels.decode_attention import kernel

    return kernel.decode_attention(
        q,
        k_cache,
        v_cache,
        pos,
        window=window,
        softcap=softcap,
        interpret=common.interpret_mode(),
    )
