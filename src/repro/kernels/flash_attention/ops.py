"""Jit'd public wrapper for fused attention."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.flash_attention import ref


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    impl: str | None = None,
) -> jnp.ndarray:
    """Fused attention.  q: (B,S,H,D); k,v: (B,S,KV,D).  On TPU this lowers
    to the Pallas flash kernel; elsewhere the jnp reference is used (the
    kernel itself is validated in interpret mode by tests)."""
    impl = impl or common.default_impl()
    if impl == "ref":
        return ref.mha(
            q,
            k,
            v,
            causal=causal,
            window=window,
            softcap=softcap,
        )
    from repro.kernels.flash_attention import kernel

    return kernel.flash_attention(
        q,
        k,
        v,
        causal=causal,
        window=window,
        softcap=softcap,
        interpret=common.interpret_mode(),
    )
