"""Pure-jnp oracle for fused attention (causal / sliding-window / softcap,
GQA).  Numerics: fp32 logits + softmax, output cast back to input dtype."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0**30


def mha(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """q: (B, S, H, D); k, v: (B, S, KV, D) with H % KV == 0."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, D)
    logits = jnp.einsum(
        "bskgd,btkd->bkgst",
        qg.astype(jnp.float32),
        k.astype(jnp.float32),
    )
    logits = logits / jnp.sqrt(D).astype(jnp.float32)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    si = jnp.arange(S)[:, None]
    ti = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ti <= si
    if window > 0:
        mask &= ti > si - window
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)
