"""Pallas TPU flash attention (forward): online-softmax over K/V blocks.

Grid: (batch, q_heads, q_blocks, k_blocks) — k_blocks innermost so the
(acc, m, l) scratch carries across the K sweep in VMEM.  BlockSpecs tile
Q/K/V so the working set is (bq + 2·bk)·head_dim fp32 + (bq, bk) logits,
well inside the ~16 MB VMEM budget for bq = bk = 512, D = 256.

GQA is handled in the index maps (q head h reads kv head h // G).  Causal
and sliding-window masks are applied blockwise; fully-masked blocks are
cheap (masked to -inf) — further block pruning is a grid-level
optimization noted in EXPERIMENTS §Perf.

MXU alignment: bq/bk default 512 and are clamped to multiples of 128 when
the sequence allows; head_dim is zero-padded to a lane multiple by the
caller if needed (all assigned archs have D in {64, 128, 256}).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30


def _body(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    window: int,
    softcap: float,
    bq: int,
    bk: int,
):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)

    s = jax.lax.dot_general(
        q,
        k,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s = s * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    iq = pl.program_id(2)
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    pv = jax.lax.dot_general(
        p,
        v,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    m_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "window",
        "softcap",
        "block_q",
        "block_k",
        "interpret",
    ),
)
def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
):
    """q: (B, S, H, D); k, v: (B, S, KV, D) -> (B, S, H, D)."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    grid = (B, H, S // bq, S // bk)
    scale = 1.0 / (D**0.5)

    kernel = functools.partial(
        _body,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        bq=bq,
        bk=bk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec(
                (1, bk, 1, D),
                lambda b, h, iq, ik, G=G: (b, ik, h // G, 0),
            ),
            pl.BlockSpec(
                (1, bk, 1, D),
                lambda b, h, iq, ik, G=G: (b, ik, h // G, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, bq, 1, D),
            lambda b, h, iq, ik: (b, iq, h, 0),
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
