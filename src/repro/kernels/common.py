"""Kernel dispatch helpers: Pallas on TPU, pure-jnp reference elsewhere.

Kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling) and
validated on CPU in interpret mode by the test suite.  Model code goes
through ops.py wrappers, which pick the implementation per platform so the
whole framework runs end-to-end on CPU unchanged.
"""
from __future__ import annotations

import os

import jax


def default_impl() -> str:
    forced = os.environ.get("REPRO_KERNEL_IMPL")
    if forced:
        return forced
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def interpret_mode() -> bool:
    return jax.default_backend() != "tpu"
