"""Kernel dispatch helpers: Pallas on TPU, pure-jnp reference elsewhere.

Kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling) and
validated on CPU in interpret mode by the test suite.  Model code goes
through ops.py wrappers, which pick the implementation per platform so the
whole framework runs end-to-end on CPU unchanged.
"""

from __future__ import annotations

import os

import jax


def default_impl() -> str:
    forced = os.environ.get("REPRO_KERNEL_IMPL")
    if forced:
        return forced
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def interpret_mode() -> bool:
    return jax.default_backend() != "tpu"


# choices for SimConfig.route_impl — "auto" defers to default_impl()
# (REPRO_KERNEL_IMPL override, else Pallas iff a TPU backend is present)
ROUTE_IMPLS = ("auto", "ref", "pallas")


def resolve_route_impl(name: str) -> str:
    """Resolve a SimConfig.route_impl choice to a concrete "ref"/"pallas"."""
    if name == "auto":
        return default_impl()
    return name
