"""Pure-jnp oracle for the Mamba-1 selective scan: a plain sequential
``lax.scan`` over time — slow, but obviously correct.

    h_t = exp(Δ_t ⊙ A) · h_{t-1} + Δ_t ⊙ B_t · x_t
    y_t = C_t · h_t + D ⊙ x_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    D: jnp.ndarray,
    h0: jnp.ndarray | None = None,
):
    """x, dt: (Bt, S, DI); A: (DI, ST); B, C: (Bt, S, ST); D: (DI,).
    Returns (y: (Bt, S, DI), h_final: (Bt, DI, ST))."""
    Bt, S, DI = x.shape
    ST = A.shape[1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Df = D.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((Bt, DI, ST), jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp  # (Bt,DI),(Bt,DI),(Bt,ST),(Bt,ST)
        da = jnp.exp(dt_t[..., None] * Af[None])  # (Bt, DI, ST)
        db = dt_t[..., None] * b_t[:, None, :]  # (Bt, DI, ST)
        h = da * h + db * x_t[..., None]
        y_t = jnp.einsum("bds,bs->bd", h, c_t) + Df[None] * x_t
        return h, y_t

    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_final


def selective_step(x_t, dt_t, A, B_t, C_t, D, h):
    """One decode step.  x_t, dt_t: (Bt, DI); B_t, C_t: (Bt, ST);
    h: (Bt, DI, ST).  Returns (y_t: (Bt, DI), h_new)."""
    Af = A.astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    xf = x_t.astype(jnp.float32)
    da = jnp.exp(dtf[..., None] * Af[None])
    db = dtf[..., None] * B_t.astype(jnp.float32)[:, None, :]
    h = da * h + db * xf[..., None]
    y = jnp.einsum("bds,bs->bd", h, C_t.astype(jnp.float32))
    y = y + D.astype(jnp.float32)[None] * xf
    return y.astype(x_t.dtype), h
