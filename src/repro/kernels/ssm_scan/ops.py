"""Fast selective-scan wrapper.

The production path is *chunked*: the sequence is cut into chunks of Q
steps; within a chunk the recurrence is solved with an associative scan
(parallel prefix, TPU-friendly); the (Bt, DI, ST) state is carried across
chunks with ``lax.scan``.  Peak memory is O(Bt·Q·DI·ST) instead of
O(Bt·S·DI·ST) — this is the TPU adaptation of the CUDA selective-scan
(which keeps state in registers/SRAM): VMEM holds one chunk of decayed
states, HBM only sees x/dt/B/C tiles and the y output.

On TPU the inner chunk computation is the Pallas kernel; elsewhere it runs
as the same algorithm in pure jnp.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.ssm_scan import ref


def _chunk_scan(h0, x, dt, A, B, C):
    """Solve the recurrence for one chunk via associative scan.
    x, dt: (Bt, Q, DI); B, C: (Bt, Q, ST); h0: (Bt, DI, ST) carry.
    Returns (y: (Bt, Q, DI) WITHOUT the D·x skip, h_last)."""
    da = jnp.exp(dt[..., None] * A[None, None])  # (Bt,Q,DI,ST)
    db = dt[..., None] * B[:, :, None, :]  # (Bt,Q,DI,ST)
    bx = db * x[..., None]

    def combine(a, b):
        # composition of affine maps h -> a1*h + a2
        (a1, a2), (b1, b2) = a, b
        return a1 * b1, b1 * a2 + b2

    decays, states = jax.lax.associative_scan(combine, (da, bx), axis=1)
    # fold in the carry: h_t = decays_t * h0 + states_t
    h_all = decays * h0[:, None] + states  # (Bt,Q,DI,ST)
    y = jnp.einsum("bqds,bqs->bqd", h_all, C)
    return y, h_all[:, -1]


def _parallel_scan(x, dt, A, B, C, h0, chunk: int):
    """Two-level associative scan with NO sequential loop: within-chunk
    prefix scan + a second prefix scan over chunk summaries.  Fully
    parallel (log-depth), so XLA cost analysis sees every flop — used by
    the dry-run cost compiles (while-loop bodies are otherwise counted
    once) and valid as a throughput-optimal path when memory allows."""
    Bt, S, DI = x.shape
    n = S // chunk
    xs = x.reshape(Bt, n, chunk, DI)
    dts = dt.reshape(Bt, n, chunk, DI)
    Bs = B.reshape(Bt, n, chunk, -1)
    Cs = C.reshape(Bt, n, chunk, -1)

    da = jnp.exp(dts[..., None] * A[None, None, None])  # (Bt,n,Q,DI,ST)
    bx = (dts[..., None] * Bs[:, :, :, None, :]) * xs[..., None]

    def combine(a, b):
        (a1, a2), (b1, b2) = a, b
        return a1 * b1, b1 * a2 + b2

    decays, states = jax.lax.associative_scan(combine, (da, bx), axis=2)
    # chunk summaries -> prefix over chunks (sequential dependency removed)
    Pc = decays[:, :, -1]  # (Bt,n,DI,ST)
    Sc = states[:, :, -1]
    Pp, Sp = jax.lax.associative_scan(combine, (Pc, Sc), axis=1)
    # initial state entering chunk c: h0 folded through prefix c-1
    Pprev = jnp.concatenate([jnp.ones_like(Pp[:, :1]), Pp[:, :-1]], axis=1)
    Sprev = jnp.concatenate([jnp.zeros_like(Sp[:, :1]), Sp[:, :-1]], axis=1)
    h_in = Pprev * h0[:, None, :, :] + Sprev  # (Bt,n,DI,ST)
    h_all = decays * h_in[:, :, None] + states  # (Bt,n,Q,DI,ST)
    y = jnp.einsum("bnqds,bnqs->bnqd", h_all, Cs)
    h_final = Pp[:, -1] * h0 + Sp[:, -1]
    return y.reshape(Bt, S, DI), h_final


def selective_scan(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    D: jnp.ndarray,
    h0: jnp.ndarray | None = None,
    *,
    chunk: int = 128,
    impl: str | None = None,
):
    """Chunked selective scan; same contract as ref.selective_scan."""
    import os

    impl = impl or common.default_impl()
    if os.environ.get("REPRO_SSM_PARALLEL"):
        impl = "parallel"
    Bt, S, DI = x.shape
    ST = A.shape[1]
    if impl == "ref" and S <= chunk:
        return ref.selective_scan(x, dt, A, B, C, D, h0)
    if h0 is None:
        h0 = jnp.zeros((Bt, DI, ST), jnp.float32)

    n = -(-S // chunk)
    pad = n * chunk - S
    xf = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    dtf = jnp.pad(dt.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    Bf = jnp.pad(B.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    Cf = jnp.pad(C.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    Af = A.astype(jnp.float32)

    if impl == "parallel":
        y, h_final = _parallel_scan(
            xf,
            dtf,
            Af,
            Bf,
            Cf,
            h0.astype(jnp.float32),
            chunk,
        )
        y = y[:, :S]
        y = y + D.astype(jnp.float32)[None, None] * x.astype(jnp.float32)
        return y.astype(x.dtype), h_final

    def to_chunks(a):
        return jnp.moveaxis(a.reshape(Bt, n, chunk, -1), 1, 0)

    def body_jnp(h, inp):
        xc, dc, bc, cc = inp
        y, h2 = _chunk_scan(h, xc, dc, Af, bc, cc)
        return h2, y

    body = body_jnp
    if impl == "pallas":
        from repro.kernels.ssm_scan import kernel

        def body_pallas(h, inp):
            xc, dc, bc, cc = inp
            y, h2 = kernel.chunk_scan(
                h,
                xc,
                dc,
                Af,
                bc,
                cc,
                interpret=common.interpret_mode(),
            )
            return h2, y

        body = body_pallas

    h_final, ys = jax.lax.scan(
        body,
        h0.astype(jnp.float32),
        (to_chunks(xf), to_chunks(dtf), to_chunks(Bf), to_chunks(Cf)),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(Bt, n * chunk, DI)[:, :S]
    y = y + D.astype(jnp.float32)[None, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), h_final


selective_step = ref.selective_step
