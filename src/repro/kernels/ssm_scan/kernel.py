"""Pallas TPU chunked selective-scan (Mamba-1) kernel.

TPU adaptation of the CUDA selective-scan: the GPU version keeps the
recurrent state in registers/shared memory per thread-block; here one
chunk's state lives in VMEM and the channel dimension is tiled across the
grid.  Grid: (batch, d_inner tiles); within a call a ``fori_loop`` walks
the Q timesteps of the chunk, updating the (tile, d_state) state in VMEM
scratch and storing one y row per step.  The chunk-to-chunk carry is
orchestrated by ops.selective_scan's outer ``lax.scan``.

All elementwise math is fp32 (matching the oracle); inputs may be bf16.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _body(
    h0_ref,
    x_ref,
    dt_ref,
    A_ref,
    B_ref,
    C_ref,
    y_ref,
    hout_ref,
    h_ref,
    *,
    Q: int,
):
    h_ref[...] = h0_ref[0].astype(jnp.float32)  # (tile, ST)
    A = A_ref[...].astype(jnp.float32)  # (tile, ST)

    def step(t, _):
        x_t = x_ref[0, t, :].astype(jnp.float32)  # (tile,)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)  # (tile,)
        b_t = B_ref[0, t, :].astype(jnp.float32)  # (ST,)
        c_t = C_ref[0, t, :].astype(jnp.float32)  # (ST,)
        da = jnp.exp(dt_t[:, None] * A)  # (tile, ST)
        db = dt_t[:, None] * b_t[None, :]
        h = da * h_ref[...] + db * x_t[:, None]
        h_ref[...] = h
        yt = jnp.sum(h * c_t[None, :], axis=1)
        y_ref[0, t, :] = yt.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, Q, step, 0)
    hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def chunk_scan(
    h0,
    x,
    dt,
    A,
    B,
    C,
    *,
    tile: int = 512,
    interpret: bool = False,
):
    """One chunk of the selective scan.

    h0: (Bt, DI, ST) carry; x, dt: (Bt, Q, DI); A: (DI, ST);
    B, C: (Bt, Q, ST).  Returns (y: (Bt, Q, DI) fp32 — WITHOUT the D·x
    skip, matching ops._chunk_scan — and h_out: (Bt, DI, ST) fp32).
    """
    Bt, Q, DI = x.shape
    ST = A.shape[1]
    tl = min(tile, DI)
    assert DI % tl == 0, (DI, tl)
    grid = (Bt, DI // tl)

    kernel = functools.partial(_body, Q=Q)
    y, hout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tl, ST), lambda b, i: (b, i, 0)),  # h0
            pl.BlockSpec((1, Q, tl), lambda b, i: (b, 0, i)),  # x
            pl.BlockSpec((1, Q, tl), lambda b, i: (b, 0, i)),  # dt
            pl.BlockSpec((tl, ST), lambda b, i: (i, 0)),  # A
            pl.BlockSpec((1, Q, ST), lambda b, i: (b, 0, 0)),  # B
            pl.BlockSpec((1, Q, ST), lambda b, i: (b, 0, 0)),  # C
        ],
        out_specs=[
            pl.BlockSpec((1, Q, tl), lambda b, i: (b, 0, i)),  # y
            pl.BlockSpec((1, tl, ST), lambda b, i: (b, i, 0)),  # h_out
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, Q, DI), jnp.float32),
            jax.ShapeDtypeStruct((Bt, DI, ST), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((tl, ST), jnp.float32)],
        interpret=interpret,
    )(h0, x, dt, A, B, C)
    return y, hout
