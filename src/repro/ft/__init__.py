from repro.ft.failures import FailureDetector, elastic_plan  # noqa: F401
