"""Fault-tolerance runtime: failure detection, straggler mitigation,
elastic topology changes.

On a real cluster these hooks wrap the coordinator (jax.distributed /
GKE); the logic — heartbeats with EWMA'd deadlines, straggler scoring via
the same telemetry sketches the MIDAS control loop uses, and elastic
resharding through the topology-agnostic checkpoint — is identical, so it
is implemented and tested host-side here.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Set

import numpy as np



@dataclasses.dataclass
class HostState:
    last_heartbeat: float
    step_times: List[float] = dataclasses.field(default_factory=list)
    ewma_step: float = 0.0


class FailureDetector:
    """Heartbeat-based failure detection + straggler scoring.

    A host is FAILED if silent for > timeout; a STRAGGLER if its EWMA step
    time exceeds ``straggler_factor`` x the cluster median (the p99/median
    telemetry pattern from the paper's control loop)."""

    def __init__(self, hosts: int, *, timeout_s: float = 10.0,
                 straggler_factor: float = 1.5, alpha: float = 0.2,
                 now: Optional[float] = None):
        # ``now`` injects the initial clock (tests / simulated time);
        # every host starts presumed-alive as of that instant
        now = now if now is not None else time.monotonic()
        self.hosts: Dict[int, HostState] = {
            h: HostState(last_heartbeat=now) for h in range(hosts)}
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.alpha = alpha

    def heartbeat(self, host: int, step_time_s: Optional[float] = None,
                  now: Optional[float] = None) -> None:
        st = self.hosts[host]
        st.last_heartbeat = now if now is not None else time.monotonic()
        if step_time_s is not None:
            st.ewma_step = ((1 - self.alpha) * st.ewma_step
                            + self.alpha * step_time_s
                            if st.ewma_step else step_time_s)
            st.step_times.append(step_time_s)

    def failed(self, now: Optional[float] = None) -> Set[int]:
        now = now if now is not None else time.monotonic()
        return {h for h, st in self.hosts.items()
                if now - st.last_heartbeat > self.timeout_s}

    def stragglers(self) -> Set[int]:
        ew = [st.ewma_step for st in self.hosts.values() if st.ewma_step]
        if len(ew) < 2:
            return set()
        med = float(np.median(ew))
        return {h for h, st in self.hosts.items()
                if st.ewma_step > self.straggler_factor * med}


def elastic_plan(old_hosts: int, alive: Set[int], *,
                 min_hosts: int = 1) -> Dict[str, object]:
    """Decide the post-failure topology.  Data-parallel ranks shrink to the
    survivors; the restart path is: load latest checkpoint (topology-
    agnostic), rebuild the mesh at the new size, re-shard, resume the data
    stream at the checkpointed step (pipeline is seekable)."""
    n_alive = len(alive)
    if n_alive < min_hosts:
        return {"action": "abort", "alive": sorted(alive)}
    # keep the largest power-of-two survivors for a regular mesh
    usable = 1 << (n_alive.bit_length() - 1)
    return {
        "action": "resume" if usable == old_hosts else "reshard",
        "alive": sorted(alive),
        "new_dp": usable,
        "dropped": sorted(set(range(old_hosts)) - alive),
    }
