"""E12 — the resilience matrix: fault × policy × controller.

Every prior experiment measured a healthy system.  E12 injects the
registered fault events (``repro.core.faults``) into the fleet engine
and measures what the adaptive stack buys when things BREAK: how high
the queues spike while a fault is active, how fast the system returns
to its own pre-fault baseline once the fault clears, and what staleness
the caches paid along the way.

Per (fault, policy, controller) cell, per seed:

  * ``peak_queue_during_fault`` — max queue over the fault's active
    window (the hotspot the fault manufactures);
  * ``recovery_ms`` — time from the fault clearing until the mean queue
    stays inside the cell's own zero-fault band for ``HOLD`` ticks
    (censored at the horizon when it never re-enters);
  * ``stale_rate`` / ``bypasses`` — coherence cost from the fleet
    cache's own counters;
  * ``steady_delta_mean_queue`` — end-of-run drift vs the zero-fault
    cell (did the system actually return to baseline?).

The headline contract (tested): the full adaptive stack
(midas + hysteresis) recovers from a proxy crash faster than the static
baseline (round_robin + static).  Emits
``experiments/sim/resilience_matrix.json`` incrementally — the doc is
rewritten after every fault block, so a CI timeout still uploads a
valid partial artifact.  ``--only`` subsets the fault blocks (the
zero-fault baseline is always kept: recovery bands are measured against
it); ``--devices`` shards each sweep's seed axis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from benchmarks.common import (Artifact, BenchOpts, emit, parse_opts,
                               timed)
from repro.core import (FaultEvent, SimConfig, SweepSpec, make_workload,
                        run_sweep)
from repro.core import faults as faults_lib
from repro.obs import windows

T = 900            # 45 s at dt=50 ms: 15 s pre-fault, fault, recovery
M = 8
N = 1024
SEEDS = (0, 1)
SCENARIO = "bursty"
GOSSIP_MS = 100.0
HOLD = 20          # ticks the mean queue must hold inside the band
POLICIES = ("midas", "round_robin", "power_of_d")
CONTROLLERS = ("hysteresis", "static")

FAULTS = {
    "none": None,
    "proxy_crash": (
        FaultEvent("proxy_crash", t0=300, duration=250, target=0),),
    "proxy_join": (
        FaultEvent("proxy_join", t0=300, target=0),),
    "server_brownout": (
        FaultEvent("server_brownout", t0=300, duration=250, target=1,
                   magnitude=0.25),),
    "gossip_partition": (
        FaultEvent("gossip_partition", t0=300, duration=250, target=-1),),
    "ckpt_storm_fleet": (
        FaultEvent("ckpt_storm_fleet", t0=300, duration=200,
                   magnitude=0.6),),
}


def _active_window(cfg: SimConfig) -> tuple:
    """[first, last] active tick of the compiled schedule (None when
    the schedule is empty or never fires)."""
    fc = faults_lib.compile_faults(cfg, T)
    if fc is None or not fc.active.any():
        return None, None
    idx = np.flatnonzero(fc.active)
    return int(idx[0]), int(idx[-1])


def _recovery_ms(mean_q: np.ndarray, t_clear: int, band: float,
                 dt_ms: float) -> float:
    """ms from fault clearance until mean queue stays <= band for HOLD
    consecutive ticks; censored at the remaining horizon."""
    tail = mean_q[t_clear:]
    ok = tail <= band
    run = 0
    for i, good in enumerate(ok):
        run = run + 1 if good else 0
        if run >= HOLD:
            return float((i - HOLD + 1) * dt_ms)
    return float(len(tail) * dt_ms)  # censored: never re-entered


def _cfg(policy: str, controller: str, faults) -> SimConfig:
    return SimConfig(
        m=M, N=N, policy=policy, controller=controller,
        middleware=("fleet_cache",), gossip_ms=GOSSIP_MS,
        faults=faults,
    )


def run(opts: Optional[BenchOpts] = None) -> None:
    opts = opts or BenchOpts()
    fault_names = opts.pick(tuple(FAULTS), "faults")
    if "none" not in fault_names:
        # recovery bands are measured against the zero-fault cells
        fault_names = ("none",) + fault_names
    seeds = opts.seeds(SEEDS)
    wl = make_workload(SCENARIO, T=T, m=M, seed=0, N=N)
    art = Artifact("resilience_matrix.json", opts.out)
    doc = {
        "T": T, "m": M, "N": N, "seeds": list(seeds),
        "scenario": SCENARIO, "gossip_ms": GOSSIP_MS, "hold": HOLD,
        "policies": list(POLICIES), "controllers": list(CONTROLLERS),
        "devices": opts.devices,
        "faults": {
            k: [dataclasses.asdict(e) for e in FAULTS[k]]
            if FAULTS[k] else []
            for k in fault_names},
        "cells": {},
    }

    # zero-fault baselines: per (policy, controller), the band the
    # recovery metric measures re-entry into, and the steady-state
    # reference the drift column compares against
    base_q: dict = {}
    for fault_name in fault_names:
        events = FAULTS[fault_name]
        doc["cells"][fault_name] = {}
        t0, t1 = (None, None)
        if events:
            t0, t1 = _active_window(_cfg(POLICIES[0], CONTROLLERS[0],
                                         events))
        for ctrl in CONTROLLERS:
            cfg = _cfg(POLICIES[0], ctrl, events)
            # policies × seeds batched onto one compiled sweep; full
            # metrics because the recovery band needs the timelines
            spec = SweepSpec(
                config=cfg, workloads=(wl,), policies=POLICIES,
                seeds=seeds, metrics="full", devices=opts.devices,
                do_warmup=False)
            res, us = timed(
                run_sweep, spec, label=f"resilience/{fault_name}/{ctrl}")
            for policy in POLICIES:
                key = f"{policy}+{ctrl}"
                rows = res.rows(policy=policy)
                qs = np.stack([r.queue_timeline for r in rows])  # (S,T,m)
                mean_q = qs.mean(axis=2)                         # (S,T)
                cell = windows.cell_block(rows, dt_ms=cfg.dt_ms)
                cell.update({
                    "mean_queue": round(float(qs.mean()), 3),
                    "max_queue": round(float(qs.max()), 2),
                    "steady_mean_queue": round(
                        float(mean_q[:, -100:].mean()), 3),
                })
                fc0 = rows[0].final_cache
                if fc0 is not None:
                    hits = sum(int(r.final_cache.hits) for r in rows)
                    stale = sum(
                        int(r.final_cache.stale_serves) for r in rows)
                    cell["stale_rate"] = round(
                        stale / max(hits, 1), 6)
                    cell["bypasses"] = sum(
                        int(r.final_cache.bypasses) for r in rows)
                if fault_name == "none":
                    # the recovery band: 1.5x the healthy mean (floored
                    # so near-zero baselines don't make it unreachable)
                    mu = float(mean_q.mean())
                    base_q[key] = {
                        "mean": mu,
                        "band": max(1.5 * mu, mu + 0.5),
                        "steady": cell["steady_mean_queue"],
                    }
                else:
                    base = base_q[key]
                    cell["peak_queue_during_fault"] = round(
                        float(qs[:, t0:t1 + 1].max()), 2)
                    rec = [
                        _recovery_ms(mean_q[s], t1 + 1, base["band"],
                                     cfg.dt_ms)
                        for s in range(len(seeds))]
                    cell["recovery_ms"] = round(float(np.mean(rec)), 1)
                    cell["recovery_censored"] = bool(
                        max(rec) >= (T - (t1 + 1)) * cfg.dt_ms)
                    cell["steady_delta_mean_queue"] = round(
                        cell["steady_mean_queue"] - base["steady"], 3)
                doc["cells"][fault_name][key] = cell
            emit(f"resilience/{fault_name}/{ctrl}", us,
                 f"policies={len(POLICIES)};seeds={len(seeds)}")
        # incremental artifact: a timeout still leaves valid JSON
        art.write(doc)

    # headline: the adaptive stack beats the static baseline on crash
    # recovery (the claim the resilience matrix exists to check)
    if "proxy_crash" not in doc["cells"]:
        return
    adaptive = doc["cells"]["proxy_crash"]["midas+hysteresis"]
    static = doc["cells"]["proxy_crash"]["round_robin+static"]
    doc["headline"] = {
        "crash_recovery_ms_adaptive": adaptive["recovery_ms"],
        "crash_recovery_ms_static": static["recovery_ms"],
        "adaptive_recovers_faster": bool(
            adaptive["recovery_ms"] < static["recovery_ms"]),
        "crash_peak_adaptive": adaptive["peak_queue_during_fault"],
        "crash_peak_static": static["peak_queue_during_fault"],
    }
    art.write(doc)
    emit("resilience/headline_crash_recovery_ms", 0.0,
         f"midas+hysteresis={adaptive['recovery_ms']};"
         f"round_robin+static={static['recovery_ms']};"
         f"adaptive_faster={doc['headline']['adaptive_recovers_faster']}")


def main(argv=None) -> None:
    run(parse_opts(argv, prog="benchmarks.resilience",
                   description=__doc__.splitlines()[0],
                   axis="faults"))


if __name__ == "__main__":
    main()
