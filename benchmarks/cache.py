"""E5 — cooperative cache: hit rate and staleness per coherence mode
(lease / per-key adaptive TTL / aggregate TTL) on the skewed workload."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import SimConfig, make_workload, simulate


def run() -> None:
    wl = make_workload("skewed", T=3000, m=8, seed=0)
    for mode in ("lease", "ttl_per_key", "ttl_aggregate"):
        # cache as an explicit pipeline stage (new middleware API)
        cfg = SimConfig(m=8, policy="midas", middleware=("cache",),
                        cache_mode=mode)
        res, us = timed(simulate, cfg, wl)
        fc = res.final_cache
        hits = int(fc.hits)
        total = hits + int(fc.misses)
        stale = int(fc.stale_serves)
        emit(f"cache/{mode}", us,
             f"hit_rate={hits / max(total, 1):.3f};"
             f"stale_ratio={stale / max(hits, 1):.2e};"
             f"mean_q={res.mean_queue():.2f} (p*=1e-4)")
