"""E1+E2 — the paper's §VI evaluation: queue length over time (Fig. 3/4)
and the claims table (mean queue ~23% lower, worst-case 50-80% shorter,
dispersion bands RR 20-88% vs MIDAS 0-43%)."""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from benchmarks.common import emit, timed
from repro.core import SimConfig, make_workload, simulate_sweep

T = 3000           # 150 s at dt=50 ms
M = 8
PAPER_POLICIES = ("round_robin", "power_of_d")
PAPER_WORKLOADS = ("light", "bursty", "periodic", "diurnal", "skewed")
OUT = Path(__file__).resolve().parents[1] / "experiments" / "sim"


def run() -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    mean_reductions = []
    wc_reductions = []
    disp_rr, disp_midas = [], []
    timelines = {}
    for wl_name in PAPER_WORKLOADS:
        wl = make_workload(wl_name, T=T, m=M, seed=0)
        # one sweep call per policy: per-policy timing stays honest, and the
        # scan still compiles once per policy however many seeds are swept
        res = {}
        for policy in PAPER_POLICIES:
            sweep, us = timed(simulate_sweep, SimConfig(m=M), wl,
                              policies=(policy,), seeds=(0,),
                              do_warmup=False)
            r = res[policy] = sweep[policy][0]
            emit(f"sim/{wl_name}/{policy}", us,
                 f"mean_q={r.mean_queue():.2f};wc_q={r.worst_case_queue():.1f}"
                 f";dispersion={r.dispersion():.3f}")
        rr, pod = res["round_robin"], res["power_of_d"]
        mq = 1 - pod.mean_queue() / max(rr.mean_queue(), 1e-9)
        wc = 1 - pod.worst_case_queue() / max(rr.worst_case_queue(), 1e-9)
        mean_reductions.append(mq)
        wc_reductions.append(wc)
        disp_rr.append(rr.dispersion())
        disp_midas.append(pod.dispersion())
        timelines[wl_name] = {
            "round_robin": rr.queue_timeline[::10].tolist(),
            "midas_power_of_d": pod.queue_timeline[::10].tolist(),
        }

    (OUT / "queue_timelines.json").write_text(json.dumps(timelines))
    emit("paper/mean_queue_reduction_avg", 0.0,
         f"{np.mean(mean_reductions) * 100:.1f}% (paper: ~23%)")
    emit("paper/worst_case_reduction_range", 0.0,
         f"{min(wc_reductions) * 100:.0f}%..{max(wc_reductions) * 100:.0f}%"
         f" (paper: 50-80%)")
    emit("paper/dispersion_rr_range", 0.0,
         f"{min(disp_rr) * 100:.0f}%..{max(disp_rr) * 100:.0f}%"
         f" (paper: 20-88%)")
    emit("paper/dispersion_midas_range", 0.0,
         f"{min(disp_midas) * 100:.0f}%..{max(disp_midas) * 100:.0f}%"
         f" (paper: 0-43%)")
