"""Benchmark harness helpers: timing, CSV emission, the shared runner
CLI, and the incremental JSON artifact writer.

Every sweep-style runner (E4 control, E8 scenario matrix, E10 engine,
E11 shard, E12 resilience) used to duplicate its arg parsing and its
rewrite-after-every-block JSON idiom; both now live here.  A runner
exposes ``run(opts: BenchOpts | None = None)`` (what ``benchmarks.run``
dispatches with defaults) plus a ``main()`` built from
:func:`parse_opts`, so

    PYTHONPATH=src python -m benchmarks.control_stability --only aimd
    PYTHONPATH=src python -m benchmarks.scenario_matrix --seeds 2 \
        --devices 4 --out /tmp/artifacts

work uniformly: ``--only`` filters the runner's primary sweep axis
(controllers / policies / faults / configs), ``--seeds`` overrides the
seed count per cell, ``--devices`` shards each sweep's seed axis over an
emulated or real device mesh (``SweepSpec.devices``), and ``--out``
redirects the JSON artifacts.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path
from typing import Callable, List, Optional, Sequence, Tuple

from repro.obs import trace as obs_trace

ROWS: List[Tuple[str, float, str]] = []

# default artifact directory — every sweep runner writes here
OUT = Path(__file__).resolve().parents[1] / "experiments" / "sim"


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def timed(fn: Callable, *args, repeat: int = 1, label: str = "", **kw):
    """Run fn, return (result, us_per_call) — first call includes compile,
    so time the SECOND call when repeat > 1.  Both halves are recorded
    as flight-recorder spans (``bench/first_call`` / ``bench/steady``)
    so ``repro-report`` can split compile from execute time."""
    with obs_trace.span("bench/first_call", cat="bench", label=label):
        out = fn(*args, **kw)
    with obs_trace.span(
        "bench/steady", cat="bench", label=label, repeat=repeat
    ):
        t0 = time.perf_counter()
        for _ in range(repeat):
            out = fn(*args, **kw)
        dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


@dataclasses.dataclass(frozen=True)
class BenchOpts:
    """Parsed shared CLI options, with runner defaults as fallbacks."""

    only: Tuple[str, ...] = ()
    n_seeds: Optional[int] = None
    devices: int = 1
    out: Optional[Path] = None

    def pick(self, values: Sequence[str], axis: str) -> Tuple[str, ...]:
        """Filter a runner's primary sweep axis by ``--only`` (no-op
        when unset); unknown names raise with the alternatives."""
        values = tuple(values)
        if not self.only:
            return values
        unknown = [o for o in self.only if o not in values]
        if unknown:
            raise ValueError(
                f"unknown {axis} {', '.join(map(repr, unknown))}; "
                f"available: {', '.join(values)}"
            )
        return tuple(v for v in values if v in self.only)

    def seeds(self, default: Tuple[int, ...]) -> Tuple[int, ...]:
        """Seed tuple: ``--seeds N`` means seeds 0..N-1."""
        if self.n_seeds is None:
            return tuple(default)
        return tuple(range(self.n_seeds))


def parse_opts(
    argv: Optional[Sequence[str]] = None,
    *,
    prog: str,
    description: str,
    axis: str = "cells",
) -> BenchOpts:
    """The shared runner CLI (``--only``, ``--seeds``, ``--devices``,
    ``--out``)."""
    ap = argparse.ArgumentParser(prog=prog, description=description)
    ap.add_argument(
        "--only",
        default="",
        help=f"comma-separated subset of this runner's {axis}",
    )
    ap.add_argument(
        "--seeds",
        type=int,
        default=None,
        metavar="N",
        help="run seeds 0..N-1 per cell (overrides the runner default)",
    )
    ap.add_argument(
        "--devices",
        type=int,
        default=1,
        help="shard each sweep's seed axis over this many devices "
        "(SweepSpec.devices; on CPU needs XLA_FLAGS="
        "--xla_force_host_platform_device_count)",
    )
    ap.add_argument(
        "--out",
        default=None,
        metavar="DIR",
        help="artifact output directory (default: experiments/sim)",
    )
    args = ap.parse_args(argv)
    only = tuple(s.strip() for s in args.only.split(",") if s.strip())
    return BenchOpts(
        only=only,
        n_seeds=args.seeds,
        devices=args.devices,
        out=Path(args.out) if args.out else None,
    )


def _env_meta() -> dict:
    """Environment provenance every artifact records (ISSUE 8): numbers
    without the stack + device that produced them aren't comparable."""
    meta = {}
    try:
        import jax

        meta["jax_version"] = jax.__version__
        meta["device_kind"] = jax.devices()[0].device_kind
        meta["n_devices"] = len(jax.devices())
    except Exception:  # keep artifacts writable even if jax breaks late
        pass
    return meta


def _utc(ts: float) -> str:
    return time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime(ts))


class Artifact:
    """Incremental JSON artifact: call :meth:`write` after every block,
    rewriting the whole doc — a CI timeout (rc 124, tolerated) still
    uploads valid partial JSON.

    Every artifact is paired with a flight-recorder trace: constructing
    one points the process-global recorder at ``<stem>.trace.jsonl``
    (write-through JSONL) and each :meth:`write` refreshes the
    Chrome-trace export ``<stem>.trace.json`` plus the artifact's
    ``meta`` block (jax version, device kind, wall-clock start/end).
    """

    def __init__(self, filename: str, out: Optional[Path] = None):
        base = out if out is not None else OUT
        base.mkdir(parents=True, exist_ok=True)
        self.path = base / filename
        self.started = time.time()
        self.trace_path = self.path.with_suffix(".trace.jsonl")
        obs_trace.configure(path=self.trace_path, fresh=True)

    def write(self, doc: dict) -> None:
        meta = doc.setdefault("meta", {})
        meta.update(_env_meta())
        meta.setdefault("started_at", _utc(self.started))
        meta["written_at"] = _utc(time.time())
        meta["trace_file"] = self.trace_path.name
        self.path.write_text(json.dumps(doc, indent=1))
        if obs_trace.RECORDER.enabled:
            obs_trace.RECORDER.write_chrome(
                self.path.with_suffix(".trace.json")
            )
