"""Benchmark harness helpers: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    """Run fn, return (result, us_per_call) — first call includes compile,
    so time the SECOND call when repeat > 1."""
    out = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6
