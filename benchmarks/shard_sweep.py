"""E11 — the sharded million-key sweep (DESIGN.md §12).

The paper's deployment scale — a ~10⁶-key namespace served through a
128-proxy fleet — run through the declarative sweep engine
(:class:`repro.core.sweep.SweepSpec`) with the seed axis sharded over a
device mesh.  Four sections, each a claim from the §12 contract:

* ``parity``   — sharded ``run_sweep`` reproduces the single-device
  nested-vmap results **bit-for-bit** at the full E11 configuration
  (million-key namespace, non-dividing seed count included via the
  seed-axis padding path);
* ``scaling``  — aggregate ticks/s of the SAME total grid (4 scenarios
  × seeds × T) at 1, 2, 4, 8 emulated devices.  Each device count runs
  in its own subprocess because XLA fixes the host device count at
  first init (``--xla_force_host_platform_device_count``).  Honest
  numbers: ``meta.cpus`` records the cores backing the emulated
  devices — emulated devices only speed things up when real cores back
  them, so the ≥2× headline is a multi-core (CI) result;
* ``memory``   — peak host RSS of the identical sweep at R = 10⁵ vs
  R = 10⁶ namespace keys, in fresh subprocesses.  Flat-in-R contract:
  nothing materializes O(R·P); the ratio stays ~1 (midas pin state is
  the only O(R) term, 8 bytes/key/seed);
* ``ring``     — the million-key ring audit: every key resolved
  shard-by-shard from O(m·V/n_shards + tail) subrings
  (``hashring.np_subring``), primaries AND d_max feasible sets
  bit-for-bit equal to the global ring, shards partitioning the keys.

Emits ``experiments/sim/BENCH_shard.json`` incrementally (a CI timeout
still uploads a valid partial artifact) plus CSV rows.  ``--only``
subsets the sections; ``--devices N`` caps the mesh sizes; ``--seeds``
shrinks the grid for smoke runs.
"""
from __future__ import annotations

import json
import os
import resource
import subprocess
import sys
import time
from pathlib import Path
from typing import Optional

import numpy as np

from benchmarks.common import (Artifact, BenchOpts, emit, parse_opts)

ROOT = Path(__file__).resolve().parents[1]

# the E11 grid: paper scale
SCENARIOS = ("bursty", "rename_storm", "flash_crowd", "job_startup")
SEEDS = tuple(range(8))
N_KEYS = 1_000_000     # namespace size R (the paper's ~10⁶ keys)
M = 64                 # metadata servers
V = 64                 # vnodes/server -> 4096-slot ring
P = 128                # proxy fleet (one routing wave per proxy)
R_SLOTS = 512          # request slots per tick
T = 240                # 12 s at dt=50 ms
DEVICES = (1, 2, 4, 8)
N_SHARDS = 8           # subring arcs for the ring audit
D_MAX = 4
MEM_NS = (100_000, 1_000_000)
# §III-B targets pinned (warmup at million-key scale is a separate
# experiment; E11 measures the sweep engine, not the warmup)
TARGETS = (0.5, 400.0)
SECTIONS = ("parity", "scaling", "memory", "ring")
_TAG = "E11-RESULT "


def _spec(n=N_KEYS, t=T, seeds=SEEDS, devices=1, scenarios=SCENARIOS):
    from repro.core import SimConfig, SweepSpec, make_workload

    wls = tuple(
        make_workload(s, T=t, m=M, seed=0, N=n, R=R_SLOTS)
        for s in scenarios
    )
    cfg = SimConfig(
        m=M,
        N=n,
        V=V,
        P=P,
        policy="midas",
        fleet_routing=True,
        gossip_ms=100.0,
    )
    return SweepSpec(
        config=cfg,
        workloads=wls,
        policies=("midas",),
        seeds=seeds,
        metrics="summary",
        devices=devices,
        do_warmup=False,
        targets=TARGETS,
    )


def _rss_mb() -> float:
    """Peak RSS of this process in MB (ru_maxrss is KB on Linux)."""
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _rows_equal(ra, rb) -> bool:
    names = (
        ra._fields
        if hasattr(ra, "_fields")
        else tuple(f.name for f in __import__("dataclasses").fields(ra))
    )
    for name in names:
        if name in ("config", "final_cache"):
            continue
        a, b = getattr(ra, name), getattr(rb, name)
        if a is None or b is None:
            if a is not b:
                return False
            continue
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            return False
    return True


# --------------------------------------------------------------------------
# Worker modes (run in subprocesses so each gets its own device count)
# --------------------------------------------------------------------------


def _worker(req: dict) -> dict:
    import jax

    from repro.core import run_sweep
    from repro.core.sweep import _SHARD_TRACES

    seeds = tuple(range(req["seeds"]))
    if req["mode"] == "scaling":
        spec = _spec(
            n=req["n"], t=req["t"], seeds=seeds, devices=req["devices"]
        )
        t0 = time.perf_counter()
        run_sweep(spec)
        compile_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = run_sweep(spec)
        run_s = time.perf_counter() - t0
        ticks = len(spec.workloads) * len(seeds) * req["t"]
        # windowing contract rides the subprocess result line (the
        # block is plain JSON); first scenario stands for the grid
        from repro.obs import windows

        cell = windows.cell_block(
            res.rows(policy="midas", workload=SCENARIOS[0])
        )
        return {
            "devices": req["devices"],
            "visible_devices": len(jax.devices()),
            "cells": spec.n_cells,
            "first_call_s": round(compile_s, 2),
            "run_s": round(run_s, 3),
            "ticks": ticks,
            "ticks_per_s": round(ticks / run_s, 1),
            "key_slots_per_s": round(ticks * R_SLOTS / run_s),
            "rss_mb": round(_rss_mb(), 1),
            "rows": len(res.cells),
            **cell,
        }
    if req["mode"] == "parity":
        n_dev = req["devices"]
        single = run_sweep(
            _spec(n=req["n"], t=req["t"], seeds=seeds, devices=1)
        )
        sharded = run_sweep(
            _spec(n=req["n"], t=req["t"], seeds=seeds, devices=n_dev)
        )
        ok = set(single.cells) == set(sharded.cells) and all(
            _rows_equal(single.cells[c], sharded.cells[c])
            for c in single.cells
        )
        return {
            "devices": n_dev,
            "seeds": len(seeds),
            "padded": bool(len(seeds) % n_dev),
            "cells": len(single.cells),
            "bitwise_equal": bool(ok),
            "shard_traces": _SHARD_TRACES[0],
        }
    if req["mode"] == "memory":
        spec = _spec(
            n=req["n"],
            t=req["t"],
            seeds=seeds,
            scenarios=SCENARIOS[:1],
            devices=req["devices"],
        )
        run_sweep(spec)
        return {"n": req["n"], "rss_mb": round(_rss_mb(), 1)}
    raise ValueError(f"unknown worker mode {req['mode']!r}")


def _launch(req: dict, devices: int) -> dict:
    """Run one worker in a fresh subprocess with its own device count
    (XLA locks the host platform device count at first jax init)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [str(ROOT / "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "benchmarks.shard_sweep",
            "--worker",
            json.dumps(req),
        ],
        cwd=ROOT,
        env=env,
        capture_output=True,
        text=True,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"shard_sweep worker {req['mode']!r} failed "
            f"(rc={proc.returncode}):\n{proc.stderr[-2000:]}"
        )
    for line in reversed(proc.stdout.splitlines()):
        if line.startswith(_TAG):
            return json.loads(line[len(_TAG):])
    raise RuntimeError(
        f"shard_sweep worker {req['mode']!r} produced no result line"
    )


# --------------------------------------------------------------------------
# Ring audit (pure numpy — no devices involved)
# --------------------------------------------------------------------------


def _ring_audit(n_keys: int) -> dict:
    from repro.core import hashring

    rng = np.random.default_rng(0)
    keys = rng.integers(0, 1 << 31, size=n_keys, dtype=np.int64)
    shard_of = hashring.np_key_shard(keys, N_SHARDS)
    # global reference = the single-shard "subring" (whole position
    # space + tail), so reference and per-shard paths share one code path
    whole = hashring.np_subring(M, V, 0, 1)
    primaries_ok = feasible_ok = True
    covered = 0
    max_sub = 0
    for s in range(N_SHARDS):
        sub = hashring.np_subring(M, V, s, N_SHARDS)
        max_sub = max(max_sub, sub.positions.size)
        ks = keys[shard_of == s]
        covered += ks.size
        if not np.array_equal(
            hashring.np_subring_primary(sub, ks),
            hashring.np_subring_primary(whole, ks),
        ):
            primaries_ok = False
        if not np.array_equal(
            hashring.np_subring_feasible(sub, ks, D_MAX),
            hashring.np_subring_feasible(whole, ks, D_MAX),
        ):
            feasible_ok = False
    return {
        "n_keys": n_keys,
        "m": M,
        "V": V,
        "n_shards": N_SHARDS,
        "d_max": D_MAX,
        "shards_partition_keys": bool(covered == n_keys),
        "primaries_bitwise_equal": bool(primaries_ok),
        "feasible_sets_bitwise_equal": bool(feasible_ok),
        "global_ring_slots": int(whole.positions.size),
        "max_subring_slots": int(max_sub),
        "subring_memory_ratio": round(max_sub / whole.positions.size, 4),
    }


# --------------------------------------------------------------------------
# Orchestrator
# --------------------------------------------------------------------------


def run(opts: Optional[BenchOpts] = None) -> None:
    opts = opts or BenchOpts()
    sections = opts.pick(SECTIONS, "sections")
    n_seeds = len(opts.seeds(SEEDS))
    devs = DEVICES
    if opts.devices > 1:
        devs = tuple(sorted({1, opts.devices}))
    devs = tuple(d for d in devs if d <= (os.cpu_count() or 1) * 8)
    import jax

    art = Artifact("BENCH_shard.json", opts.out)
    doc: dict = {
        "meta": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "cpus": os.cpu_count(),
            "n_keys": N_KEYS,
            "m": M,
            "V": V,
            "P": P,
            "r_slots": R_SLOTS,
            "T": T,
            "scenarios": list(SCENARIOS),
            "seeds": n_seeds,
            "device_counts": list(devs),
        },
    }
    art.write(doc)

    if "parity" in sections:
        n_dev = max(d for d in devs if d > 1) if len(devs) > 1 else 2
        # seed count chosen to NOT divide the mesh -> exercises padding
        res = _launch(
            {
                "mode": "parity",
                "n": N_KEYS,
                "t": max(T // 4, 8),
                "seeds": max(n_dev - 1, 2),
                "devices": n_dev,
            },
            devices=n_dev,
        )
        doc["parity"] = res
        art.write(doc)
        emit(
            "shard_sweep/parity",
            0.0,
            f"bitwise_equal={res['bitwise_equal']} "
            f"devices={res['devices']} cells={res['cells']} "
            f"padded={res['padded']}",
        )

    if "scaling" in sections:
        doc["scaling"] = {}
        base = None
        for d in devs:
            res = _launch(
                {
                    "mode": "scaling",
                    "n": N_KEYS,
                    "t": T,
                    "seeds": n_seeds,
                    "devices": d,
                },
                devices=d,
            )
            if base is None:
                base = res["run_s"]
            res["speedup_vs_1dev"] = round(base / res["run_s"], 2)
            doc["scaling"][str(d)] = res
            art.write(doc)
            emit(
                f"shard_sweep/scaling/{d}dev",
                res["run_s"] * 1e6,
                f"ticks/s={res['ticks_per_s']:,.0f} "
                f"speedup={res['speedup_vs_1dev']}x "
                f"rss={res['rss_mb']:.0f}MB",
            )

    if "memory" in sections:
        doc["memory"] = {"runs": []}
        rss = []
        for n in MEM_NS:
            res = _launch(
                {
                    "mode": "memory",
                    "n": n,
                    "t": max(T // 2, 8),
                    "seeds": min(n_seeds, 2),
                    "devices": 1,
                },
                devices=1,
            )
            rss.append(res["rss_mb"])
            doc["memory"]["runs"].append(res)
            art.write(doc)
        ratio = rss[-1] / max(rss[0], 1e-9)
        doc["memory"]["peak_rss_ratio"] = round(ratio, 3)
        doc["memory"]["flat_in_R"] = bool(ratio < 1.5)
        art.write(doc)
        emit(
            "shard_sweep/memory",
            0.0,
            f"rss@{MEM_NS[0]}={rss[0]:.0f}MB "
            f"rss@{MEM_NS[-1]}={rss[-1]:.0f}MB "
            f"ratio={ratio:.2f} flat={doc['memory']['flat_in_R']}",
        )

    if "ring" in sections:
        doc["ring_audit"] = _ring_audit(N_KEYS)
        art.write(doc)
        ra = doc["ring_audit"]
        emit(
            "shard_sweep/ring_audit",
            0.0,
            f"keys={ra['n_keys']:,} "
            f"primaries_ok={ra['primaries_bitwise_equal']} "
            f"feasible_ok={ra['feasible_sets_bitwise_equal']} "
            f"subring_mem={ra['subring_memory_ratio']:.3f}x",
        )


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv[:1] == ["--worker"]:
        out = _worker(json.loads(argv[1]))
        print(_TAG + json.dumps(out), flush=True)
        return
    run(
        parse_opts(
            argv,
            prog="benchmarks.shard_sweep",
            description=__doc__.splitlines()[0],
            axis="sections",
        )
    )


if __name__ == "__main__":
    main()
