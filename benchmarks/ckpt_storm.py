"""E7 — checkpoint storm: writer-lane hotspot with static hash vs MIDAS
power-of-d lane scheduling (and real end-to-end save/restore timing)."""
from __future__ import annotations

import tempfile

import numpy as np

from benchmarks.common import emit, timed
from repro.ckpt import CheckpointManager, WriterPool


def run() -> None:
    # scheduling-only storm (nothing drains): worst-lane backlog
    probe = WriterPool(4, policy="hash")
    first = probe.assign("giant0", 0)
    twin = next(f"giant{i}" for i in range(1, 64)
                if probe.assign(f"giant{i}", 0) == first)
    GIANT = 200 << 20
    worst = {}
    for policy in ("round_robin", "hash", "midas"):
        pool = WriterPool(4, policy=policy)
        pool.assign("giant0", GIANT)
        pool.assign(twin, GIANT)
        for i in range(64):
            pool.assign(f"leaf{i}", 4 << 20)
        worst[policy] = max(pool.backlogs()) / (1 << 20)
    emit("ckpt/storm_worst_lane_mb", 0.0,
         ";".join(f"{p}={v:.0f}" for p, v in worst.items())
         + ";midas_vs_hash="
         + f"-{(1 - worst['midas'] / worst['hash']) * 100:.0f}%")

    # real end-to-end save + restore
    rng = np.random.default_rng(0)
    tree = {f"layer{i}": {"w": rng.normal(size=(256, 256)).astype(np.float32)}
            for i in range(24)}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, lanes=4)
        _, us_save = timed(cm.save, 1, tree)
        _, us_restore = timed(cm.restore, 1, tree)
        nbytes = 24 * 256 * 256 * 4
        emit("ckpt/save", us_save,
             f"{nbytes / max(us_save, 1):.0f}MB_per_s_x1e-0")
        emit("ckpt/restore", us_restore, "crc32-verified")
