"""Stability-mechanism ablations (paper §IV-E): remove each of the
self-stabilizing guards and measure what breaks.

  no_margin — steer whenever any candidate looks lighter (Δ_L = 0);
              violates the Lyapunov condition, expect steering churn
  no_pin    — re-evaluate every request (C = 0); expect key flapping
  no_bucket — uncapped steering (f_max = 1); expect steering bursts

``SimConfig.ablate`` resolves to the controller-registry ablation
decorators (``controllers.wrap_ablations``): the configured controller's
dynamics run untouched while the knob view it EMITS has the named
mechanism removed — ablations compose with any registered controller,
not just the default hysteresis loop (sim.py no longer special-cases
them).  The full control-plane ablation — no adaptive loop at all — is
``SimConfig(controller="static")``, reported by E4's stability matrix.
"""
from __future__ import annotations

import dataclasses


from benchmarks.common import emit, timed
from repro.core import SimConfig, make_workload, simulate


def run() -> None:
    wl = make_workload("bursty", T=2400, m=8, seed=9)
    base = SimConfig(m=8, policy="midas", middleware=("cache",),
                     cache_mode="lease")
    results = {}
    for name, abl in (("full", ""), ("no_margin", "no_margin"),
                      ("no_pin", "no_pin"), ("no_bucket", "no_bucket")):
        cfg = dataclasses.replace(base, ablate=abl)
        res, us = timed(simulate, cfg, wl)
        steer_rate = res.steered.sum() / max(res.eligible.sum(), 1)
        results[name] = res
        emit(f"ablation/{name}", us,
             f"mean_q={res.mean_queue():.2f};"
             f"steered_total={int(res.steered.sum())};"
             f"steer_rate={steer_rate:.3f};"
             f"dispersion_t={res.dispersion_t():.3f}")
    full, nm = results["full"], results["no_margin"]
    emit("ablation/margin_guard_effect", 0.0,
         f"steering x{nm.steered.sum() / max(full.steered.sum(), 1):.1f} "
         f"without the Lyapunov margin")
