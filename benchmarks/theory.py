"""E3 — §V theory: balls-into-bins max-load gaps and M/M/1 latency."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import theory


def run() -> None:
    m = 256
    for d in (1, 2, 4):
        (gap, std), us = timed(theory.maxload_gap_empirical,
                               n_balls=m, m=m, d=d, trials=30)
        pred = (theory.uniform_maxload_gap_theory(m) if d == 1
                else theory.power_of_d_maxload_gap_theory(m, d))
        emit(f"theory/maxload_d{d}", us,
             f"gap={gap:.2f};theory={pred:.2f}")
    emit("theory/mm1", 0.0,
         f"E[T](lam=5,mu=10)={theory.mm1_latency(5, 10):.3f}s"
         f";E[T](9,10)={theory.mm1_latency(9, 10):.3f}s")
