"""E10 — engine performance: compile time vs steady-state ticks/sec.

Two measurements back DESIGN.md §9 and the README perf quick-look:

* ``engine/*``: per-config compile time, lowered-HLO size, and steady
  ticks/sec of the wave-scan engine vs the unrolled reference across
  policy × middleware × n_groups × P × fleet — the O(1)-vs-O(G) trace
  contract as a number.
* ``backends``: the per-hardware-target axis (DESIGN.md §15) — the
  wave-scan engine with ``route_impl="ref"`` (pure-jnp policies) vs
  ``route_impl="pallas"`` (the ``midas_route.route_select`` kernel),
  tagged with the platform (cpu/gpu/tpu) and whether the kernel ran
  through the Pallas interpreter (off-TPU: a correctness-costed proxy,
  not a speed claim).  Ref-vs-pallas ticks/sec per engine config.
* ``kernels``: the ``benchmarks.kernels_bench`` micro-benchmark rows,
  embedded so the kernel and engine numbers live in one artifact.
* ``e8_sweep``: the E8 scenario-matrix configuration (full workload
  registry × 8 seeds per policy stack) run by the pre-PR engine — flat
  vmap over ``jnp.repeat``-duplicated grids, Python-unrolled waves, a
  *carried* (hence vmap-batched) tick counter that degrades every
  cadence ``lax.cond`` to a both-branches ``select``, full TickOut
  timelines, per-combo device slicing — versus the current engine:
  nested vmap sharing grids across seeds, scan-over-waves, unbatched
  tick clock, hoisted feasible sets, streaming summary metrics.  The
  "before" number is recorded in the JSON next to "after" and the
  speedup: the repo's first perf-trajectory artifact.

Emits ``experiments/sim/BENCH_engine.json`` (written incrementally, so a
CI timeout still leaves a valid artifact) and CSV rows.  ``--only``
subsets the sections (the engine config names plus ``e8_sweep``);
``--devices`` shards the "after" sweep's seed axis.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (Artifact, BenchOpts, emit, parse_opts)
# the "e8_sweep" section must measure exactly the E8 configuration —
# import it rather than re-declaring, so the two can never drift
from benchmarks.scenario_matrix import M, POLICY_STACKS as E8_STACKS
from benchmarks.scenario_matrix import SEED, SEEDS as SWEEP_SEEDS
from benchmarks.scenario_matrix import T as T_SWEEP
from repro.core import (SimConfig, SweepSpec, hashring, make_workload,
                        run_sweep, workloads)
from repro.core import policies as policy_lib
from repro.core import sim as sim_lib
from repro.kernels import common as kernels_common
from repro.obs import trace as obs_trace
from repro.obs import windows

T_ENGINE = 400          # single-run horizon (compile + steady timing)
REPEAT = 3

# single-run configs: policy × middleware × n_groups × P × fleet
CONFIGS = (
    ("rr_g8", dict(policy="round_robin")),
    ("pod_g8", dict(policy="power_of_d")),
    ("midas_cache_g8", dict(policy="midas", middleware=("cache",))),
    ("midas_cache_g32", dict(policy="midas", middleware=("cache",),
                             n_groups=32)),
    ("midas_fleet_p8", dict(policy="midas", middleware=("fleet_cache",),
                            fleet_routing=True, P=8, gossip_ms=100.0)),
)
# configs measured on the backend (route_impl) axis: ≥ 2 per the E10
# acceptance contract — one pure power-of-d, one full midas stack
BACKEND_CONFIGS = ("pod_g8", "midas_cache_g8")
SECTIONS = tuple(name for name, _ in CONFIGS) + (
    "backends", "kernels", "e8_sweep")


def _time_run(fn, *args, label: str = ""):
    """(compile_s, steady_s, out): first call vs best of REPEAT warm
    calls, plus the last result (the windowing contract needs the
    timelines the timed run produced)."""
    with obs_trace.span("bench/first_call", cat="bench", label=label):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        compile_s = time.perf_counter() - t0
    steady = []
    with obs_trace.span(
        "bench/steady", cat="bench", label=label, repeat=REPEAT
    ):
        for _ in range(REPEAT):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            steady.append(time.perf_counter() - t0)
    return compile_s, min(steady), out


def _bench_engine(name: str, overrides: dict) -> dict:
    """Compile / steady / HLO size for scan vs unrolled on one config."""
    wl = make_workload("bursty", T=T_ENGINE, m=M, seed=SEED)
    row: dict = {"name": name, "T": T_ENGINE, "m": M, **{
        k: (list(v) if isinstance(v, tuple) else v)
        for k, v in overrides.items()}}
    for engine, unroll in (("scan", False), ("unrolled", True)):
        cfg = SimConfig(m=M, unroll_waves=unroll, **overrides)
        st = sim_lib.init_state(cfg)
        args = (cfg, st, wl.keys, wl.mask, wl.is_write)
        hlo_chars = len(
            sim_lib._run_scan.lower(*args).as_text())
        compile_s, steady_s, (_, outs) = _time_run(
            sim_lib._run_scan, *args, label=f"engine/{name}/{engine}")
        q_mean = np.asarray(outs.L, np.float64).mean(axis=1)
        w = windows.detect(q_mean)
        wstats = windows.windowed_stats(q_mean, w)
        row[engine] = {
            "hlo_chars": hlo_chars,
            "compile_s": round(compile_s, 3),
            "steady_s": round(steady_s, 4),
            "ticks_per_s": round(T_ENGINE / steady_s),
            "window": w.to_json(),
            "stable": {"mean_queue": round(wstats["stable"], 4)},
            "window_shift": {"mean_queue": round(wstats["shift"], 4)},
        }
        emit(f"engine_perf/{name}/{engine}", steady_s * 1e6,
             f"compile={compile_s:.2f}s "
             f"ticks/s={T_ENGINE / steady_s:,.0f} hlo={hlo_chars}")
    row["hlo_ratio_unrolled_over_scan"] = round(
        row["unrolled"]["hlo_chars"] / row["scan"]["hlo_chars"], 2)
    return row


def _bench_backends() -> list:
    """route_impl="ref" vs "pallas" on the scan engine, per platform.

    Off-TPU the kernel path runs through the Pallas interpreter — the
    row says so (``interpret: true``), making it a correctness-costed
    proxy rather than a speed claim; on TPU the same code is the real
    Mosaic kernel and this axis becomes the hardware scorecard."""
    wl = make_workload("bursty", T=T_ENGINE, m=M, seed=SEED)
    cfg_by_name = dict(CONFIGS)
    rows = []
    for name in BACKEND_CONFIGS:
        overrides = cfg_by_name[name]
        row: dict = {
            "name": name,
            "platform": jax.default_backend(),
            "interpret": kernels_common.interpret_mode(),
            "impls": {},
        }
        for impl in ("ref", "pallas"):
            cfg = SimConfig(m=M, route_impl=impl, **overrides)
            st = sim_lib.init_state(cfg)
            args = (cfg, st, wl.keys, wl.mask, wl.is_write)
            compile_s, steady_s, (_, outs) = _time_run(
                sim_lib._run_scan, *args,
                label=f"backends/{name}/{impl}")
            q_mean = np.asarray(outs.L, np.float64).mean(axis=1)
            w = windows.detect(q_mean)
            wstats = windows.windowed_stats(q_mean, w)
            row["impls"][impl] = {
                "compile_s": round(compile_s, 3),
                "steady_s": round(steady_s, 4),
                "ticks_per_s": round(T_ENGINE / steady_s),
                "window": w.to_json(),
                "stable": {"mean_queue": round(wstats["stable"], 4)},
            }
            emit(f"engine_perf/backends/{name}/{impl}", steady_s * 1e6,
                 f"platform={row['platform']} "
                 f"interpret={row['interpret']} "
                 f"ticks/s={T_ENGINE / steady_s:,.0f}")
        row["pallas_over_ref"] = round(
            row["impls"]["ref"]["steady_s"]
            / row["impls"]["pallas"]["steady_s"], 2)
        rows.append(row)
    return rows


# --------------------------------------------------------------------------
# The pre-PR sweep engine, reconstructed for the "before" number
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(0,))
def _legacy_sweep(cfg: SimConfig, states, tick0, keys, mask, is_write):
    """Pre-PR sweep semantics: one flat vmap over all (workload, seed)
    combos (grids jnp.repeat-duplicated by the caller), Python-unrolled
    waves (cfg.unroll_waves=True), and the tick counter CARRIED through
    the scan — under vmap it is batched, so every cadence ``lax.cond``
    runs both branches as a ``select``, exactly as the pre-PR engine
    compiled."""
    ring = hashring.make_ring(cfg.m, cfg.V)
    # fc=None: the legacy engine predates the fault registry — without
    # pinning it the partial would bind the scan carry as fc and crash
    step = functools.partial(
        sim_lib._tick, cfg, ring, policy_lib.get(cfg.policy),
        sim_lib._middlewares(cfg), sim_lib._controller(cfg), None)

    def one(st, t0, k, mk, w):
        def body(carry, xs):
            s, tick = carry
            kk, mm, ww = xs
            s, out = step(s, (tick, kk, mm, ww))
            return (s, tick + 1), out

        (final, _), outs = jax.lax.scan(body, (st, t0), (k, mk, w))
        return final, outs

    return jax.vmap(one)(states, tick0, keys, mask, is_write)


def _bench_e8_before(policy: str, mw, wls, seeds) -> dict:
    cfg = SimConfig(m=M, policy=policy, middleware=mw, unroll_waves=True)
    S, W = len(seeds), len(wls)
    keys = jnp.repeat(jnp.stack([w.keys for w in wls]), S, axis=0)
    mask = jnp.repeat(jnp.stack([w.mask for w in wls]), S, axis=0)
    isw = jnp.repeat(jnp.stack([w.is_write for w in wls]), S, axis=0)
    per_seed = [
        sim_lib.init_state(dataclasses.replace(cfg, seed=s))
        for s in seeds]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_seed)
    states = jax.tree_util.tree_map(
        lambda x: jnp.tile(x, (W,) + (1,) * (x.ndim - 1)), stacked)
    tick0 = jnp.zeros((W * S,), jnp.int32)

    def run():
        final, outs = _legacy_sweep(cfg, states, tick0, keys, mask, isw)
        # pre-PR per-combo slicing: B × fields tiny device transfers
        rows = []
        for b in range(W * S):
            outs_b = jax.tree_util.tree_map(lambda x: x[b], outs)
            rows.append(sim_lib._to_result(cfg, outs_b, None))
        return rows

    compile_s, steady_s, _ = _time_run(
        run, label=f"e8_before/{policy}")
    return {"compile_s": compile_s, "steady_s": steady_s}


def _bench_e8_after(policy: str, mw, wls, seeds, devices: int) -> dict:
    spec = SweepSpec(
        config=SimConfig(m=M, policy=policy, middleware=mw),
        workloads=tuple(wls), policies=(policy,), seeds=seeds,
        metrics="summary", devices=devices, do_warmup=False)

    def run():
        return run_sweep(spec)

    compile_s, steady_s, res = _time_run(
        run, label=f"e8_after/{policy}")
    # windowing contract on the sweep the perf number came from (first
    # workload cell: one window per policy keeps the artifact small)
    rows = res.rows(policy=policy, workload=wls[0].name)
    return {
        "compile_s": compile_s,
        "steady_s": steady_s,
        **windows.cell_block(rows),
    }


def run(opts: Optional[BenchOpts] = None) -> None:
    opts = opts or BenchOpts()
    sections = opts.pick(SECTIONS, "sections")
    seeds = opts.seeds(SWEEP_SEEDS)
    art = Artifact("BENCH_engine.json", opts.out)
    doc: dict = {
        "meta": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "T_engine": T_ENGINE,
            "T_sweep": T_SWEEP,
            "m": M,
            "sweep_seeds": len(seeds),
            "devices": opts.devices,
            "repeat": REPEAT,
        },
        "engine": [],
    }
    for name, overrides in CONFIGS:
        if name not in sections:
            continue
        doc["engine"].append(_bench_engine(name, overrides))
        art.write(doc)  # incremental: a timeout still leaves an artifact

    # ---- backend (route_impl) axis + kernel micro-bench rows ------------
    if "backends" in sections:
        doc["backends"] = _bench_backends()
        art.write(doc)
    if "kernels" in sections:
        from benchmarks import kernels_bench

        doc["kernels"] = kernels_bench.collect()
        art.write(doc)

    # ---- E8 sweep config, before (pre-PR engine) vs after ---------------
    if "e8_sweep" not in sections:
        return
    names = workloads.available()
    wls = [make_workload(n, T=T_SWEEP, m=M, seed=SEED) for n in names]
    ticks = len(wls) * len(seeds) * T_SWEEP
    sweep: dict = {
        "workloads": len(wls), "seeds": len(seeds), "T": T_SWEEP,
        "policies": {}, "before": {}, "after": {},
    }
    doc["e8_sweep"] = sweep
    tot_b = tot_a = 0.0
    for policy, mw in E8_STACKS.items():
        after = _bench_e8_after(policy, mw, wls, seeds, opts.devices)
        before = _bench_e8_before(policy, mw, wls, seeds)
        tot_b += before["steady_s"]
        tot_a += after["steady_s"]
        sweep["policies"][policy] = {
            "before_ticks_per_s": round(ticks / before["steady_s"]),
            "after_ticks_per_s": round(ticks / after["steady_s"]),
            "speedup_steady": round(
                before["steady_s"] / after["steady_s"], 2),
            "before_compile_s": round(before["compile_s"], 2),
            "after_compile_s": round(after["compile_s"], 2),
            "window": after["window"],
            "stable": after["stable"],
            "window_shift": after["window_shift"],
        }
        emit(f"engine_perf/e8_sweep/{policy}", after["steady_s"] * 1e6,
             f"{sweep['policies'][policy]['speedup_steady']}x steady "
             f"({ticks / before['steady_s']:,.0f} -> "
             f"{ticks / after['steady_s']:,.0f} ticks/s)")
        art.write(doc)
    total = ticks * len(E8_STACKS)
    sweep["before"] = {"steady_s": round(tot_b, 2),
                       "ticks_per_s": round(total / tot_b)}
    sweep["after"] = {"steady_s": round(tot_a, 2),
                      "ticks_per_s": round(total / tot_a)}
    sweep["speedup_steady"] = round(tot_b / tot_a, 2)
    art.write(doc)
    emit("engine_perf/e8_sweep/total", tot_a * 1e6,
         f"{sweep['speedup_steady']}x steady over pre-PR engine "
         f"({sweep['before']['ticks_per_s']:,} -> "
         f"{sweep['after']['ticks_per_s']:,} ticks/s)")


def main(argv=None) -> None:
    run(parse_opts(argv, prog="benchmarks.engine_perf",
                   description=__doc__.splitlines()[0],
                   axis="sections"))


if __name__ == "__main__":
    main()
