"""Kernel micro-benchmarks (CPU jnp paths; Pallas timings are TPU-only —
the interpret-mode run here is a correctness-costed proxy, noted as such)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.ssm_scan import ops as ssm_ops
from repro.kernels.midas_route import ref as mr_ref


def run() -> None:
    key = jax.random.PRNGKey(0)
    B, S, H, KV, D = 1, 1024, 8, 2, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(key, (B, S, KV, D), jnp.bfloat16)
    v = jax.random.normal(key, (B, S, KV, D), jnp.bfloat16)
    mha = jax.jit(lambda q, k, v: fa_ref.mha(q, k, v))
    _, us = timed(lambda: jax.block_until_ready(mha(q, k, v)), repeat=3)
    flops = 4 * B * S * S * H * D
    emit("kernel/attention_ref_cpu", us, f"gflops={flops / us / 1e3:.1f}")

    Bt, S2, DI, ST = 2, 1024, 256, 16
    x = jax.random.normal(key, (Bt, S2, DI))
    dt = jax.nn.softplus(jax.random.normal(key, (Bt, S2, DI)))
    A = -jnp.exp(jax.random.normal(key, (DI, ST)) * 0.5)
    Bm = jax.random.normal(key, (Bt, S2, ST))
    Cm = jax.random.normal(key, (Bt, S2, ST))
    Dm = jnp.ones((DI,))
    for impl in ("jnp_chunked", "parallel"):
        f = jax.jit(lambda *a: ssm_ops.selective_scan(*a, chunk=128,
                                                      impl=impl))
        _, us = timed(lambda: jax.block_until_ready(
            f(x, dt, A, Bm, Cm, Dm)[0]), repeat=3)
        emit(f"kernel/ssm_{impl}", us, f"S={S2};DI={DI}")

    T, E, kk = 4096, 128, 8
    logits = jax.random.normal(key, (T, E))
    load = jnp.abs(jax.random.normal(key, (E,))) * 3
    f = jax.jit(lambda l, ld: mr_ref.midas_dispatch(l, ld, kk, 4,
                                                    f_max=1.0))
    _, us = timed(lambda: jax.block_until_ready(f(logits, load)[0]),
                  repeat=3)
    emit("kernel/midas_route_ref", us, f"T={T};E={E};k={kk}")
