"""Kernel micro-benchmarks (CPU jnp paths; Pallas timings are TPU-only —
the interpret-mode runs here are correctness-costed proxies, noted as
such).  ``collect()`` returns the rows so E10 (benchmarks.engine_perf)
can embed the kernel numbers in ``BENCH_engine.json`` next to the
engine backend axis."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels import common as kernels_common
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.midas_route import kernel as mr_kernel
from repro.kernels.midas_route import ref as mr_ref
from repro.kernels.ssm_scan import ops as ssm_ops


def collect() -> List[dict]:
    rows: List[dict] = []

    def add(name: str, us: float, note: str) -> None:
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "note": note})
        emit(name, us, note)

    key = jax.random.PRNGKey(0)
    B, S, H, KV, D = 1, 1024, 8, 2, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(key, (B, S, KV, D), jnp.bfloat16)
    v = jax.random.normal(key, (B, S, KV, D), jnp.bfloat16)
    mha = jax.jit(lambda q, k, v: fa_ref.mha(q, k, v))
    _, us = timed(lambda: jax.block_until_ready(mha(q, k, v)), repeat=3)
    flops = 4 * B * S * S * H * D
    add("kernel/attention_ref_cpu", us, f"gflops={flops / us / 1e3:.1f}")

    Bt, S2, DI, ST = 2, 1024, 256, 16
    x = jax.random.normal(key, (Bt, S2, DI))
    dt = jax.nn.softplus(jax.random.normal(key, (Bt, S2, DI)))
    A = -jnp.exp(jax.random.normal(key, (DI, ST)) * 0.5)
    Bm = jax.random.normal(key, (Bt, S2, ST))
    Cm = jax.random.normal(key, (Bt, S2, ST))
    Dm = jnp.ones((DI,))
    for impl in ("jnp_chunked", "parallel"):
        f = jax.jit(lambda *a: ssm_ops.selective_scan(*a, chunk=128,
                                                      impl=impl))
        _, us = timed(lambda: jax.block_until_ready(
            f(x, dt, A, Bm, Cm, Dm)[0]), repeat=3)
        add(f"kernel/ssm_{impl}", us, f"S={S2};DI={DI}")

    # ---- midas_route: MoE dispatch, both variants, ref vs kernel --------
    T, E, kk = 4096, 128, 8
    logits = jax.random.normal(key, (T, E))
    load = jnp.abs(jax.random.normal(key, (E,))) * 3
    interp = kernels_common.interpret_mode()
    proxy = ";interpret-proxy" if interp else ""
    for fmax, tag in ((1.0, "margin"), (0.25, "fmax_capped")):
        f = jax.jit(lambda l, ld, fm=fmax: mr_ref.midas_dispatch(
            l, ld, kk, 4, f_max=fm))
        _, us = timed(lambda: jax.block_until_ready(f(logits, load)[0]),
                      repeat=3)
        add(f"kernel/midas_route_ref_{tag}", us, f"T={T};E={E};k={kk}")
        g = jax.jit(lambda l, ld, fm=fmax: mr_kernel.midas_dispatch(
            l, ld, kk, 4, f_max=fm, interpret=interp))
        _, us = timed(lambda: jax.block_until_ready(g(logits, load)[0]),
                      repeat=3)
        add(f"kernel/midas_route_pallas_{tag}", us,
            f"T={T};E={E};k={kk}{proxy}")

    # ---- route_select: the engine wave-routing core ---------------------
    R, m, d_max = 4096, 64, 4
    ks = jax.random.split(key, 3)
    feas = jax.random.randint(ks[0], (R, d_max), 0, m, jnp.int32)
    lview = jnp.abs(jax.random.normal(ks[1], (m,))) * 3.0
    sampled = jnp.ones((R, d_max), jnp.int32)
    tie = jax.random.uniform(ks[2], (R, d_max)) * 1e-3

    def _jnp_route(feas, lview, sampled, tie):
        loadv = jnp.where(sampled != 0, lview[feas], jnp.inf)
        best = jnp.argmin(loadv + tie, axis=1)
        return jnp.take_along_axis(feas, best[:, None], axis=1)[:, 0]

    f = jax.jit(_jnp_route)
    _, us = timed(lambda: jax.block_until_ready(
        f(feas, lview, sampled, tie)), repeat=3)
    add("kernel/route_select_ref", us, f"R={R};m={m};d={d_max}")
    scal = jnp.zeros((1, 4), jnp.float32)
    g = jax.jit(lambda *a: mr_kernel.route_select(
        *a, mode="power_of_d", interpret=interp))
    _, us = timed(lambda: jax.block_until_ready(
        g(feas, lview, lview, sampled, tie, scal)[0]), repeat=3)
    add("kernel/route_select_pallas", us, f"R={R};m={m};d={d_max}{proxy}")
    return rows


def run() -> None:
    collect()
