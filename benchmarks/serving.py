"""Serving-router benchmark: session-affine MIDAS routing vs round-robin
under a hot-session storm, plus prefix-cache effect."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.serve import MidasRouter


def _drive(policy: str, prefix_cache: bool = False) -> MidasRouter:
    rng = np.random.default_rng(0)
    r = MidasRouter(replicas=8, d=3, delta_l=2.0, f_max=0.25,
                    policy=policy, prefix_cache=prefix_cache)
    now = 0.0
    for step in range(4000):
        # zipf sessions: a few hot sessions hammer their primary
        session = int(rng.zipf(1.3)) % 64
        prefix = session % 16 if prefix_cache else None
        r.route(session, now, prefix_hash=prefix)
        if step % 4 == 0:
            r.ingest_telemetry()
        if step % 2 == 0:          # replicas drain slowly => backlog forms
            r.complete(int(rng.integers(0, 8)))
        now += 1.0
    return r


def run() -> None:
    for policy in ("round_robin", "hash", "midas"):
        r, us = timed(_drive, policy)
        emit(f"serving/{policy}", us / 4000,
             f"queue_cv={r.queue_dispersion():.3f};"
             f"steered={r.stats().steered}")
    r, us = timed(_drive, "midas", True)
    s = r.stats()
    emit("serving/midas_prefix_cache", us / 4000,
         f"hit_rate={s.cache_hits / max(s.routed, 1):.3f}")
