"""E8 — the policy × scenario claims matrix over the workload registry.

Sweeps every registered workload (the legacy Fig. 2 seven, the composed
scenarios, and the checked-in trace replay) under the baseline and MIDAS
policies in one batched sweep per policy, then emits the full claims
table — mean / worst-case queue, dispersion, latency quantiles, and the
reduction vs the round-robin baseline — as JSON
(``experiments/sim/scenario_matrix.json``) and CSV rows.

This is the generalization of the paper's §VI table: the headline numbers
(−23% mean queue, −50..80% worst case) are recomputed across the *space*
of bursty metadata scenarios rather than the hardcoded seven — and, since
the engine's streaming-metrics mode (``metrics="summary"``, DESIGN.md §9)
keeps sweep memory at O(B·m) instead of O(B·T·m), each cell now averages
``SEEDS`` independent seeds instead of a single run, in less memory than
one full-timeline seed used to take.

Each policy's cells ride one :class:`repro.core.sweep.SweepSpec`; pass
``--devices N`` (under ``XLA_FLAGS=--xla_force_host_platform_device_count``
on CPU) to shard the seed axis, ``--only`` to subset policies.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from benchmarks.common import (Artifact, BenchOpts, emit, parse_opts,
                               timed)
from repro.core import (SimConfig, SweepSpec, make_workload, run_sweep,
                        workloads)
from repro.obs import windows

DT_MS = 50.0  # SimConfig default; used only to annotate window bounds

T = 1200           # 60 s at dt=50 ms — covers a full storm cycle
M = 8
SEED = 0
SEEDS = tuple(range(8))   # seeds averaged per (policy, scenario) cell
BASELINE = "round_robin"
# policy -> middleware chain: the baselines run bare, the full MIDAS stack
# includes its cooperative cache (the paper's deployed configuration)
POLICY_STACKS = {
    BASELINE: (),
    "power_of_d": (),
    "midas": ("cache",),
}
POLICIES = tuple(POLICY_STACKS)


def _row(rows) -> dict:
    """Seed-averaged claims-table cell from per-seed summary rows."""
    qs = np.array([r.latency_quantiles() for r in rows])
    cell = windows.cell_block(rows, dt_ms=DT_MS)
    cell.update({
        "mean_queue": round(
            float(np.mean([r.mean_queue() for r in rows])), 3),
        "worst_case_queue": round(
            float(np.mean([r.worst_case_queue() for r in rows])), 2),
        "max_queue": round(
            float(np.mean([r.max_queue() for r in rows])), 2),
        "dispersion": round(
            float(np.mean([r.dispersion() for r in rows])), 4),
        "p50_ms": round(float(qs[:, 0].mean()), 1),
        "p99_ms": round(float(qs[:, 1].mean()), 1),
    })
    return cell


def run(opts: Optional[BenchOpts] = None) -> None:
    opts = opts or BenchOpts()
    policies = opts.pick(POLICIES, "policies")
    seeds = opts.seeds(SEEDS)
    names = workloads.available()
    wls = tuple(make_workload(n, T=T, m=M, seed=SEED) for n in names)
    art = Artifact("scenario_matrix.json", opts.out)
    table: dict = {p: {} for p in policies}
    doc = {
        "T": T, "m": M, "seed": SEED, "seeds": list(seeds),
        "metrics": "summary", "baseline": BASELINE,
        "policies": list(policies), "workloads": list(names),
        "devices": opts.devices,
        "table": table, "reductions_vs_baseline": {},
    }
    for policy in policies:
        # one declarative spec per policy: every scenario grid rides the
        # same compiled scan as a vmapped input, seeds share the grids
        # (optionally sharded over a device mesh), and the summary
        # accumulators keep memory independent of T.  Warmup derives the
        # adaptive control targets (§III-B) for midas; non-adaptive
        # policies skip it inside _targets.
        spec = SweepSpec(
            config=SimConfig(m=M, middleware=POLICY_STACKS[policy]),
            workloads=wls, policies=(policy,), seeds=seeds,
            metrics="summary", devices=opts.devices)
        res, us = timed(run_sweep, spec, label=f"scenario_matrix/{policy}")
        for wl_name in names:
            table[policy][wl_name] = _row(
                res.rows(policy=policy, workload=wl_name))
        art.write(doc)  # incremental: a timeout still leaves valid JSON
        emit(f"scenario_matrix/{policy}", us,
             f"workloads={len(names)} seeds={len(seeds)}")

    if BASELINE not in policies:
        return
    reductions = doc["reductions_vs_baseline"]
    for wl_name in names:
        base = table[BASELINE][wl_name]
        reductions[wl_name] = {
            p: {
                "mean_queue_reduction": round(
                    1 - table[p][wl_name]["mean_queue"]
                    / max(base["mean_queue"], 1e-9), 4),
                "worst_case_reduction": round(
                    1 - table[p][wl_name]["worst_case_queue"]
                    / max(base["worst_case_queue"], 1e-9), 4),
            }
            for p in policies if p != BASELINE
        }
    art.write(doc)

    for p in policies:
        if p == BASELINE:
            continue
        mq = [reductions[w][p]["mean_queue_reduction"] for w in names]
        wc = [reductions[w][p]["worst_case_reduction"] for w in names]
        emit(f"scenario_matrix/{p}/mean_queue_reduction_avg", 0.0,
             f"{np.mean(mq) * 100:.1f}% over {len(names)} scenarios "
             f"(paper: ~23%)")
        emit(f"scenario_matrix/{p}/worst_case_reduction_range", 0.0,
             f"{min(wc) * 100:.0f}%..{max(wc) * 100:.0f}% "
             f"(paper: 50-80%)")


def main(argv=None) -> None:
    run(parse_opts(argv, prog="benchmarks.scenario_matrix",
                   description=__doc__.splitlines()[0],
                   axis="policies"))


if __name__ == "__main__":
    main()
