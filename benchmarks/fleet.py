"""E9 — the fleet matrix: gossip delay × coherence mode × scenario.

E8 found full MIDAS (cache + pinning) degrading under the write-hot
``rename_storm`` while the converged-shared-table cache model makes the
cause invisible: is the regression the *mutation rate* (entries die before
reuse) or *propagation lag* (proxies serving entries other proxies already
invalidated)?  E9 drops the converged-table assumption: the
``fleet_cache`` stage runs ``P`` real proxies whose views lag gossip by
``gossip_ms`` (see ``repro.core.fleet``), swept over delays × coherence
modes × scenarios.  Scenarios ride one batched ``simulate_sweep`` per
(delay, mode) cell — one compile per policy per cell.

The decomposition per scenario/mode:
  * mutation penalty = metric(Δ=0)   − metric(no cache)    (cache churn)
  * lag penalty(Δ)   = metric(Δ)     − metric(Δ=0)         (coherence)
plus the stale-serve rate each coherence mode actually pays once views can
lag — lease mode's "staleness is zero by construction" only holds at Δ=0.

Emits ``experiments/sim/fleet_matrix.json``.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from benchmarks.common import emit, timed
from repro.core import SimConfig, make_workload, simulate_sweep

T = 900            # 45 s at dt=50 ms — covers the storm cycles
M = 8
P = 8
SEED = 0
POLICY = "midas"
GOSSIP_MS = (0.0, 100.0, 400.0)
MODES = ("lease", "ttl_aggregate", "ttl_per_key")
SCENARIOS = ("rename_storm", "job_startup", "flash_crowd", "skewed")
OUT = Path(__file__).resolve().parents[1] / "experiments" / "sim"


def _row(r) -> dict:
    fc = r.final_cache
    out = {
        "mean_queue": round(r.mean_queue(), 3),
        "worst_case_queue": round(r.worst_case_queue(), 2),
        "dispersion": round(r.dispersion(), 4),
    }
    if fc is None:
        return out
    hits, misses = int(fc.hits), int(fc.misses)
    stale = int(fc.stale_serves)
    hits_p = np.asarray(fc.hits_p, dtype=np.float64)
    out.update({
        "hits": hits,
        "misses": misses,
        "hit_rate": round(hits / max(hits + misses, 1), 4),
        "stale_serves": stale,
        "stale_rate": round(stale / max(hits, 1), 6),
        "bypasses": int(fc.bypasses),
        # telemetry divergence the shared table hides: per-proxy hit CV
        "proxy_hit_cv": round(
            float(hits_p.std() / max(hits_p.mean(), 1e-9)), 4),
    })
    return out


def run() -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    wls = [make_workload(n, T=T, m=M, seed=SEED) for n in SCENARIOS]

    # reference: MIDAS with no cache at all (mutation-penalty baseline).
    # fleet_routing matches the cells so the decomposition isolates the
    # cache — otherwise the routing-model switch would be misattributed
    # to mutation churn; fixed control targets keep cells like-for-like
    bare, us = timed(
        simulate_sweep,
        SimConfig(m=M, P=P, policy=POLICY, fleet_routing=True),
        wls, policies=(POLICY,), seeds=(SEED,), do_warmup=False)
    reference = {n: _row(bare[POLICY][n][0]) for n in SCENARIOS}
    emit("fleet/reference_no_cache", us, f"scenarios={len(SCENARIOS)}")

    cells: dict = {mode: {} for mode in MODES}
    for mode in MODES:
        for gossip in GOSSIP_MS:
            cfg = SimConfig(m=M, P=P, policy=POLICY,
                            middleware=("fleet_cache",), cache_mode=mode,
                            gossip_ms=gossip, fleet_routing=True)
            sweep, us = timed(simulate_sweep, cfg, wls,
                              policies=(POLICY,), seeds=(SEED,),
                              do_warmup=False)
            cells[mode][str(gossip)] = {
                n: _row(sweep[POLICY][n][0]) for n in SCENARIOS}
            emit(f"fleet/{mode}/gossip_{gossip:g}ms", us,
                 f"scenarios={len(SCENARIOS)}")

    # decomposition: how much of each scenario's cache effect is mutation
    # churn (already there at Δ=0) vs propagation lag (grows with Δ)
    decomposition: dict = {}
    for mode in MODES:
        decomposition[mode] = {}
        for n in SCENARIOS:
            zero = cells[mode][str(GOSSIP_MS[0])][n]
            decomposition[mode][n] = {
                "mutation_penalty_mean_queue": round(
                    zero["mean_queue"] - reference[n]["mean_queue"], 3),
                "lag_penalty_mean_queue": {
                    str(g): round(
                        cells[mode][str(g)][n]["mean_queue"]
                        - zero["mean_queue"], 3)
                    for g in GOSSIP_MS[1:]},
                "stale_rate_by_delay": {
                    str(g): cells[mode][str(g)][n]["stale_rate"]
                    for g in GOSSIP_MS},
            }

    doc = {
        "T": T, "m": M, "P": P, "seed": SEED, "policy": POLICY,
        "gossip_ms": list(GOSSIP_MS), "modes": list(MODES),
        "scenarios": list(SCENARIOS),
        "reference_no_cache": reference,
        "cells": cells,
        "decomposition": decomposition,
    }
    (OUT / "fleet_matrix.json").write_text(json.dumps(doc, indent=1))

    for mode in MODES:
        d = decomposition[mode]["rename_storm"]
        lag = d["lag_penalty_mean_queue"]
        emit(f"fleet/{mode}/rename_storm_decomposition", 0.0,
             f"mutation={d['mutation_penalty_mean_queue']};"
             f"lag={';'.join(f'{g}ms:{v}' for g, v in lag.items())}")
