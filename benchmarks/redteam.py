"""E13 — the red-team matrix: adversarial cell × controller × ±guard.

E12 measured single faults against a survivable workload.  E13 is the
hostile version: every cell is an input DESIGNED to break the control
plane — the worst synthesized adversarial traffic found by
``run_hillclimb.py advtraffic`` (committed as
``tests/data/redteam_worst.npz``, replayed through ``trace_replay``)
plus three compound fault programs built from ``repro.core.faults``
combinators (a proxy crash DURING a checkpoint storm, a rolling
brownout marching across three servers, and a cascade where a gossip
partition fires at the crash's *detection* time).  Each cell runs every
controller twice, with and without the oscillation guard
(``SimConfig.guard``), so the matrix answers both red-team questions:
how badly does each control law limit-cycle under resonant input, and
how much of that does the guard's circuit breaker buy back.

Per (cell, controller, ±guard), averaged over seeds:

  * ``oscillation_per_min`` / ``settle_ms`` / ``knob_churn`` — the E4
    trajectory stats on the d/Δl/f_max timelines (the limit cycle);
  * the PR 8 ``window`` / ``stable`` / ``window_shift`` block;
  * ``peak_mean_queue_during_fault`` and ``recovery_ms`` vs the cell's
    own zero-fault band (fault-program cells only).

The headline contract (tested): on the worst synthesized trace the
guarded hysteresis controller oscillates strictly less than the
unguarded one.  Emits ``experiments/sim/redteam_matrix.json``
incrementally; ``--only`` subsets the adversarial cells (the
``none`` baseline is always kept: recovery bands are measured
against it).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Optional

import numpy as np

from benchmarks.common import (Artifact, BenchOpts, emit, parse_opts, timed)
from repro.core import (
    FaultEvent,
    SimConfig,
    SweepSpec,
    make_workload,
    run_sweep,
)
from repro.core import controllers as ctrl_lib
from repro.core import faults as faults_lib
from repro.obs import windows

T = 1200           # 60 s at dt=50 ms: room for ~10 adversarial cycles
M = 8
N = 1024
SEEDS = (0, 1)
POLICY = "midas"
GOSSIP_MS = 100.0
HOLD = 20          # ticks the mean queue must hold inside the band
CONTROLLERS = ("hysteresis", "aimd")

FIXTURE = (
    Path(__file__).resolve().parents[1]
    / "tests"
    / "data"
    / "redteam_worst.npz"
)

# The three compound fault programs (repro.core.faults combinators).
# Timings leave ~20 s of pre-fault baseline and ~20 s of recovery tail.
PROGRAMS = {
    "crash_during_storm": faults_lib.overlap(
        FaultEvent("ckpt_storm_fleet", t0=400, duration=300, magnitude=0.6),
        FaultEvent("proxy_crash", t0=450, duration=200, target=0),
    ),
    "rolling_brownout": faults_lib.rolling(
        "server_brownout",
        targets=(1, 2, 3),
        t0=400,
        duration=150,
        stagger=100,
        magnitude=0.3,
    ),
    "cascade_partition": (
        faults_lib.CascadeEvent(
            trigger=FaultEvent("proxy_crash", t0=400, duration=250, target=0),
            effect=FaultEvent(
                "gossip_partition", t0=0, duration=200, target=-1
            ),
            offset=20,
        ),
    ),
}

# every adversarial cell; "none" is the zero-fault recovery baseline
CELL_NAMES = ("none", "adv_trace") + tuple(PROGRAMS)


def _workload(cell: str):
    if cell == "adv_trace":
        # the committed worst case from the advtraffic search, replayed
        # without looping so the grid matches the synthesized one
        # tick-for-tick (multiset-exact; see workloads.adversary)
        return make_workload(
            "trace_replay", T=T, m=M, seed=0, N=N, trace=FIXTURE, loop=False
        )
    return make_workload("bursty", T=T, m=M, seed=0, N=N)


def _cfg(cell: str, ctrl: str, guard: bool) -> SimConfig:
    return SimConfig(
        m=M,
        N=N,
        policy=POLICY,
        controller=ctrl,
        guard=guard,
        middleware=("fleet_cache",),
        gossip_ms=GOSSIP_MS,
        faults=PROGRAMS.get(cell, ()),
    )


def _cell_spec(cell: str):
    """JSON-able description of what the cell injects (provenance)."""
    if cell == "adv_trace":
        return {"trace": FIXTURE.name}
    out = []
    for e in PROGRAMS.get(cell, ()):
        if isinstance(e, faults_lib.CascadeEvent):
            d = dataclasses.asdict(e.trigger)
            d["cascade_effect"] = dataclasses.asdict(e.effect)
            d["cascade_offset"] = e.offset
        else:
            d = dataclasses.asdict(e)
        out.append(d)
    return out


def _active_window(cfg: SimConfig) -> tuple:
    """[first, last] active tick of the compiled (cascade-resolved)
    schedule; (None, None) when the cell injects nothing."""
    fc = faults_lib.compile_faults(cfg, T)
    if fc is None or not fc.active.any():
        return None, None
    idx = np.flatnonzero(fc.active)
    return int(idx[0]), int(idx[-1])


def _recovery_ms(
    mean_q: np.ndarray, t_clear: int, band: float, dt_ms: float
) -> float:
    """ms from program clearance until the mean queue stays <= band for
    HOLD consecutive ticks; censored at the remaining horizon."""
    tail = mean_q[t_clear:]
    run = 0
    for i, good in enumerate(tail <= band):
        run = run + 1 if good else 0
        if run >= HOLD:
            return float((i - HOLD + 1) * dt_ms)
    return float(len(tail) * dt_ms)  # censored: never re-entered


def _traj(row, dt_ms: float) -> dict:
    return ctrl_lib.trajectory_stats(
        row.d_timeline,
        row.delta_l_timeline,
        row.f_max_timeline,
        row.pressure,
        dt_ms,
    )


def run(opts: Optional[BenchOpts] = None) -> None:
    opts = opts or BenchOpts()
    cells = opts.pick(CELL_NAMES, "cells")
    if "none" not in cells:
        # recovery bands are measured against the zero-fault cells
        cells = ("none",) + cells
    seeds = opts.seeds(SEEDS)
    art = Artifact("redteam_matrix.json", opts.out)

    doc = {
        "T": T,
        "m": M,
        "N": N,
        "seeds": list(seeds),
        "policy": POLICY,
        "gossip_ms": GOSSIP_MS,
        "hold": HOLD,
        "devices": opts.devices,
        "controllers": list(CONTROLLERS),
        "cells_spec": {c: _cell_spec(c) for c in cells},
        "cells": {},
    }

    base_q: dict = {}
    for cell in cells:
        wl = _workload(cell)
        doc["cells"][cell] = {}
        t0, t1 = _active_window(_cfg(cell, CONTROLLERS[0], False))
        for guard in (False, True):
            cfg = _cfg(cell, CONTROLLERS[0], guard)
            spec = SweepSpec(
                config=cfg,
                workloads=(wl,),
                policies=(POLICY,),
                controllers=CONTROLLERS,
                seeds=seeds,
                metrics="summary",
                devices=opts.devices,
                do_warmup=True,
            )
            label = f"redteam/{cell}/{'guard' if guard else 'raw'}"
            res, us = timed(run_sweep, spec, label=label)
            for ctrl in CONTROLLERS:
                key = f"{ctrl}{'+guard' if guard else ''}"
                rows = res.rows(policy=POLICY, controller=ctrl)
                mean_q = np.stack(
                    [np.asarray(r.q_mean_timeline) for r in rows]
                )
                stats = [_traj(r, cfg.dt_ms) for r in rows]
                mq = [r.mean_queue() for r in rows]
                mxq = [r.max_queue() for r in rows]
                wcq = [r.worst_case_queue() for r in rows]
                osc = [s["oscillation_per_min"] for s in stats]
                settle = [s["settle_ms"] for s in stats]
                churn = [s["knob_churn"] for s in stats]
                cell_doc = windows.cell_block(rows, dt_ms=cfg.dt_ms)
                cell_doc["mean_queue"] = round(float(np.mean(mq)), 3)
                cell_doc["max_queue"] = round(float(max(mxq)), 2)
                cell_doc["worst_case_queue"] = round(float(np.mean(wcq)), 2)
                cell_doc["oscillation_per_min"] = round(
                    float(np.mean(osc)), 2
                )
                cell_doc["settle_ms"] = round(float(np.mean(settle)), 1)
                cell_doc["knob_churn"] = round(float(np.mean(churn)), 3)
                if cell == "none":
                    mu = float(mean_q.mean())
                    base_q[key] = {
                        "mean": mu,
                        "band": max(1.5 * mu, mu + 0.5),
                    }
                elif t0 is not None:
                    band = base_q[key]["band"]
                    peak = float(mean_q[:, t0 : t1 + 1].max())
                    cell_doc["peak_mean_queue_during_fault"] = round(peak, 2)
                    rec = [
                        _recovery_ms(mean_q[s], t1 + 1, band, cfg.dt_ms)
                        for s in range(len(seeds))
                    ]
                    cen = max(rec) >= (T - (t1 + 1)) * cfg.dt_ms
                    cell_doc["recovery_ms"] = round(float(np.mean(rec)), 1)
                    cell_doc["recovery_censored"] = bool(cen)
                doc["cells"][cell][key] = cell_doc
            detail = f"controllers={len(CONTROLLERS)};seeds={len(seeds)}"
            emit(label, us, detail)
        # incremental artifact: a timeout still leaves valid JSON
        art.write(doc)

    # headline: the guard's circuit breaker suppresses the limit cycle
    # the synthesized worst case induces (the claim E13 exists to check)
    if "adv_trace" not in doc["cells"]:
        return
    raw = doc["cells"]["adv_trace"]["hysteresis"]
    grd = doc["cells"]["adv_trace"]["hysteresis+guard"]
    doc["headline"] = {
        "adv_osc_per_min_unguarded": raw["oscillation_per_min"],
        "adv_osc_per_min_guarded": grd["oscillation_per_min"],
        "guard_suppresses_limit_cycle": bool(
            grd["oscillation_per_min"] < raw["oscillation_per_min"]
        ),
        "adv_peak_queue_unguarded": raw["max_queue"],
        "adv_peak_queue_guarded": grd["max_queue"],
    }
    art.write(doc)
    emit(
        "redteam/headline_adv_oscillation_per_min",
        0.0,
        f"hysteresis={raw['oscillation_per_min']};"
        f"hysteresis+guard={grd['oscillation_per_min']};"
        f"guard_wins={doc['headline']['guard_suppresses_limit_cycle']}",
    )


def main(argv=None) -> None:
    run(
        parse_opts(
            argv,
            prog="benchmarks.redteam",
            description=__doc__.splitlines()[0],
            axis="cells",
        )
    )


if __name__ == "__main__":
    main()
