"""E4 — self-stabilizing control loop under bursty load: knob bounds,
oscillation rate, Lyapunov ΔV of admitted steers, steering-cap compliance."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import SimConfig, make_workload, simulate


def run() -> None:
    wl = make_workload("bursty", T=3000, m=8, seed=5)
    cfg = SimConfig(m=8, policy="midas", middleware=("cache",),
                    cache_mode="lease")
    res, us = timed(simulate, cfg, wl)
    d = res.d_timeline
    flips = int(np.sum(np.abs(np.diff(d)) > 0))
    minutes = 3000 * 0.05 / 60
    steered, eligible = res.steered.sum(), max(res.eligible.sum(), 1)
    emit("control/knob_bounds", us,
         f"d_in[{d.min()},{d.max()}];dL_in[{res.delta_l_timeline.min():.0f},"
         f"{res.delta_l_timeline.max():.0f}] (paper: d 1-4, dL 2-8)")
    emit("control/oscillation", 0.0,
         f"d_flips_per_min={flips / minutes:.1f}")
    f = res.f_max_timeline
    emit("control/steering_cap", 0.0,
         f"steered/eligible={steered / eligible:.3f} "
         f"(adaptive f_max in [{f.min():.2f},{f.max():.2f}], "
         f"floor 0.10)")
    emit("control/pressure_p99", 0.0,
         f"{np.percentile(res.pressure, 99):.3f}")
