"""E4 — the controller × scenario stability matrix.

The paper's §IV-E stability story, measured across the whole controller
registry instead of a single hardcoded loop: every registered controller
(`hysteresis` reference, `aimd`, `deadband_pid`, `static` baseline) runs
the full MIDAS stack over composed scenarios, one
:class:`repro.core.sweep.SweepSpec` per controller (scenarios and seeds
ride the vmapped scan — ONE compile per controller), under
``metrics="summary"``, whose :class:`repro.core.sim.KnobTrace` ys keep
the knob trajectories that stability metrics need without materializing
(T, m) timelines.

Per (controller, scenario) cell:
  * oscillation_per_min — d-knob flips per minute (the paper's measure);
  * settle_ms           — LAST pressure onset to the last knob change
                          (anchored on the final burst so recurring-burst
                          scenarios don't saturate at the horizon);
  * knob_churn          — mean per-tick |Δknob| / range, summed knobs;
  * steer_rate / f_max_mean / f_max_granted / cap_utilization —
                          aggregate steering vs the time-mean and peak
                          cap; ``cap_compliant`` checks the sound
                          aggregate bound (steered/eligible ≤ peak
                          f_max).  The exact per-window leaky-bucket
                          invariant needs full timelines and is
                          asserted in tests/test_core_sim.py;
  * mean_queue / worst_case_queue — what stability buys.

The §III-B warmup targets are controller-independent (warmup runs the
``hash`` policy bare), so they are derived ONCE and shared across every
cell via ``SweepSpec(..., targets=...)`` — one warmup compile for the
whole matrix instead of one per controller.

Emits ``experiments/sim/control_matrix.json`` incrementally (the doc is
rewritten after every controller, so a CI timeout still uploads a valid
partial artifact) plus CSV rows.  ``--only`` subsets controllers;
``--devices`` shards each sweep's seed axis.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from benchmarks.common import (Artifact, BenchOpts, emit, parse_opts,
                               timed)
from repro.core import (SimConfig, SweepSpec, controllers,
                        make_workload, run_sweep)
from repro.core.sim import warmup
from repro.obs import windows

T = 1200           # 60 s at dt=50 ms — several burst/storm cycles
M = 8
SEEDS = (0, 1, 2, 3)
POLICY = "midas"
MIDDLEWARE = ("cache",)
SCENARIOS = ("bursty", "rename_storm", "flash_crowd", "job_startup")
DT_MS = 50.0


def _cell(rows) -> dict:
    """Seed-averaged stability + queue metrics for one (ctrl, scenario)."""
    stats = [
        controllers.trajectory_stats(
            r.d_timeline, r.delta_l_timeline, r.f_max_timeline,
            r.pressure, DT_MS)
        for r in rows
    ]
    steered = float(np.sum([r.steered_total for r in rows]))
    eligible = float(max(np.sum([r.eligible_total for r in rows]), 1.0))
    f_granted = float(np.max([r.f_max_timeline.max() for r in rows]))
    f_mean = float(np.mean([r.f_max_timeline.mean() for r in rows]))
    steer_rate = steered / eligible
    cell = windows.cell_block(rows, dt_ms=DT_MS)
    cell.update({
        "oscillation_per_min": round(
            float(np.mean([s["oscillation_per_min"] for s in stats])), 2),
        "settle_ms": round(
            float(np.mean([s["settle_ms"] for s in stats])), 0),
        "knob_churn": round(
            float(np.mean([s["knob_churn"] for s in stats])), 5),
        "settled_frac": round(
            float(np.mean([s["settled"] for s in stats])), 2),
        "steer_rate": round(steer_rate, 4),
        "f_max_mean": round(f_mean, 4),
        "f_max_granted": round(f_granted, 2),
        "cap_utilization": round(steer_rate / max(f_mean, 1e-9), 3),
        "cap_compliant": bool(steer_rate <= f_granted + 1e-3),
        "mean_queue": round(
            float(np.mean([r.mean_queue() for r in rows])), 3),
        "worst_case_queue": round(
            float(np.mean([r.worst_case_queue() for r in rows])), 2),
        "pressure_p99": round(
            float(np.mean(
                [np.percentile(r.pressure, 99) for r in rows])), 3),
    })
    return cell


def run(opts: Optional[BenchOpts] = None) -> None:
    opts = opts or BenchOpts()
    ctrl_names = opts.pick(controllers.available(), "controllers")
    seeds = opts.seeds(SEEDS)
    wls = tuple(make_workload(n, T=T, m=M, seed=0) for n in SCENARIOS)
    # artifact first: its flight-recorder trace covers the warmup too
    art = Artifact("control_matrix.json", opts.out)
    # one §III-B warmup for the whole matrix (controller-independent)
    targets, warm_us = timed(
        warmup, SimConfig(m=M, policy=POLICY, middleware=MIDDLEWARE),
        label="control/warmup",
    )
    emit("control/warmup_targets", warm_us,
         f"b_tgt={targets[0]:.3f};p99_tgt={targets[1]:.1f}ms (shared)")
    doc = {
        "T": T, "m": M, "dt_ms": DT_MS, "seeds": list(seeds),
        "policy": POLICY, "middleware": list(MIDDLEWARE),
        "controllers": list(ctrl_names), "scenarios": list(SCENARIOS),
        "devices": opts.devices,
        "knob_specs": [
            {"name": s.name, "lo": s.lo, "hi": s.hi, "init": s.init,
             "step": s.step}
            for s in controllers.KNOB_SPECS
        ],
        "cells": {},
    }
    for ctrl in ctrl_names:
        # scenarios × seeds batched onto one compiled sweep per
        # controller; summary metrics carry the knob trajectories
        spec = SweepSpec(
            config=SimConfig(m=M, policy=POLICY, middleware=MIDDLEWARE,
                             controller=ctrl),
            workloads=wls, policies=(POLICY,), seeds=seeds,
            metrics="summary", devices=opts.devices,
            targets=targets)
        res, us = timed(run_sweep, spec, label=f"control/{ctrl}")
        doc["cells"][ctrl] = {
            name: _cell(res.rows(policy=POLICY, workload=name))
            for name in SCENARIOS
        }
        # incremental artifact: a timeout still leaves valid JSON
        art.write(doc)
        for name in SCENARIOS:
            c = doc["cells"][ctrl][name]
            emit(f"control/{ctrl}/{name}", us,
                 f"osc/min={c['oscillation_per_min']};"
                 f"settle_ms={c['settle_ms']:.0f};"
                 f"churn={c['knob_churn']};"
                 f"cap_ok={int(c['cap_compliant'])};"
                 f"mean_q={c['mean_queue']}")

    # headline: stability across the registry under the storm scenario
    for ctrl in ctrl_names:
        c = doc["cells"][ctrl]["rename_storm"]
        emit(f"control/summary/{ctrl}", 0.0,
             f"rename_storm: osc/min={c['oscillation_per_min']} "
             f"settle={c['settle_ms']:.0f}ms churn={c['knob_churn']} "
             f"mean_q={c['mean_queue']}")


def main(argv=None) -> None:
    run(parse_opts(argv, prog="benchmarks.control_stability",
                   description=__doc__.splitlines()[0],
                   axis="controllers"))


if __name__ == "__main__":
    main()
