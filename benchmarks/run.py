"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (stdout) and writes artifacts
under experiments/.  E-numbers refer to DESIGN.md §6.

  PYTHONPATH=src python -m benchmarks.run [--only paper,theory,...] [--list]
"""
from __future__ import annotations

import argparse
import sys
import time

SECTIONS = {
    "paper": "benchmarks.paper_claims",        # E1+E2 (Fig 3/4 + §VI table)
    "theory": "benchmarks.theory",             # E3
    "control": "benchmarks.control_stability",  # E4
    "cache": "benchmarks.cache",               # E5
    "moe": "benchmarks.moe_balance",           # E6
    "ckpt": "benchmarks.ckpt_storm",           # E7
    "scenario_matrix": "benchmarks.scenario_matrix",  # E8
    "fleet": "benchmarks.fleet",               # E9 (gossip × coherence)
    "engine": "benchmarks.engine_perf",        # E10 (compile + ticks/sec)
    "shard": "benchmarks.shard_sweep",         # E11 (sharded 10^6-key sweep)
    "resilience": "benchmarks.resilience",     # E12 (fault x policy x ctrl)
    "redteam": "benchmarks.redteam",           # E13 (adversarial x ±guard)
    "serving": "benchmarks.serving",
    "kernels": "benchmarks.kernels_bench",
    "ablations": "benchmarks.ablations",       # §IV-E stability guards
}


def main() -> None:
    ap = argparse.ArgumentParser(
        description="MIDAS benchmark suite (see DESIGN.md §6)")
    ap.add_argument("--only", default=None,
                    help="comma-separated section names")
    ap.add_argument("--list", action="store_true",
                    help="list available sections and exit")
    args = ap.parse_args()
    if args.list:
        for name, mod in SECTIONS.items():
            print(f"{name:10s} {mod}")
        return
    if args.only:
        # tolerate whitespace and stray commas; run each section once, in
        # the order first named
        names = []
        for n in (s.strip() for s in args.only.split(",")):
            if n and n not in names:
                names.append(n)
        if not names:
            ap.error("--only named no sections; "
                     f"available: {', '.join(SECTIONS)} (try --list)")
    else:
        names = list(SECTIONS)
    unknown = [n for n in names if n not in SECTIONS]
    if unknown:
        ap.error(f"unknown section(s): {', '.join(unknown)}; "
                 f"available: {', '.join(SECTIONS)} (try --list)")
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in names:
        mod = __import__(SECTIONS[name], fromlist=["run"])
        try:
            mod.run()
        except Exception as e:   # pragma: no cover
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            raise
    print(f"# total {time.time() - t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
