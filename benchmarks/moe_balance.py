"""E6 — MIDAS at the MoE layer: token drop rate and expert-load dispersion,
vanilla top-k vs MIDAS power-of-d dispatch, under skewed gate logits
(the metadata-hotspot analogue)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels.midas_route import ref as route


def _skewed_logits(key, T, E, hot=4, bias=2.0):
    base = jax.random.normal(key, (T, E))
    return base.at[:, :hot].add(bias)    # a few 'hot directory' experts


def run() -> None:
    T, E, k = 8192, 64, 8
    key = jax.random.PRNGKey(0)
    logits = _skewed_logits(key, T, E)

    (e_van, _), us_v = timed(route.topk_dispatch, logits, k, repeat=3)
    load_v = route.expert_load(e_van, E)
    cv_v = float(jnp.std(load_v) / jnp.mean(load_v))

    # EWMA telemetry converges over steps; emulate 5 steps
    load = jnp.ones((E,))
    for i in range(5):
        e_mid, _, steered = route.midas_dispatch(
            _skewed_logits(jax.random.fold_in(key, i), T, E), load, k, d=4,
            delta_l=2.0, f_max=0.25)
        load = 0.8 * load + 0.2 * route.expert_load(e_mid, E)
    (e_mid, _, steered), us_m = timed(
        route.midas_dispatch, logits, load, k, 4, delta_l=2.0, f_max=0.25,
        repeat=3)
    load_m = route.expert_load(e_mid, E)
    cv_m = float(jnp.std(load_m) / jnp.mean(load_m))

    def drop_rate(experts, cf=1.25):
        C = int(np.ceil(k * T / E * cf))
        flat = np.asarray(experts).reshape(-1)
        counts = np.bincount(flat, minlength=E)
        return float(np.maximum(counts - C, 0).sum() / flat.size)

    emit("moe/topk", us_v,
         f"load_cv={cv_v:.3f};drop_rate={drop_rate(e_van):.4f}")
    emit("moe/midas", us_m,
         f"load_cv={cv_m:.3f};drop_rate={drop_rate(e_mid):.4f};"
         f"steer_rate={float(steered.mean()):.3f}")
    emit("moe/improvement", 0.0,
         f"load_cv -{(1 - cv_m / max(cv_v, 1e-9)) * 100:.0f}%;"
         "drops "
         f"-{(1 - drop_rate(e_mid) / max(drop_rate(e_van), 1e-9)) * 100:.0f}"
         "%")
