"""Hill-climb drivers: roofline cells (hc*) and the controller-
adversarial fault search (adv).

  python experiments/run_hillclimb.py hc1a
  python experiments/run_hillclimb.py adv --faults \\
      "proxy_crash:t0=300,duration=250,target=0;ckpt_storm_fleet"

``adv`` evaluates every registered controller (plus the
``no_fault_signal`` ablation of each) under the SAME injected fault
schedule and ranks them by worst-case queue — the adversarial question
being "which control plane degrades least when this fault fires".
``--faults`` takes ';'-separated ``faults.parse_fault`` specs (',' is
the key=value separator inside one spec).
"""
import argparse
import os
import sys


def adv_main(argv) -> None:
    ap = argparse.ArgumentParser(
        prog="run_hillclimb.py adv",
        description="controller-adversarial fault search")
    ap.add_argument(
        "--faults", default="proxy_crash:t0=300,duration=250,target=0",
        help="';'-separated fault specs (kind[:k=v,...])")
    ap.add_argument("--policy", default="midas")
    ap.add_argument("--T", type=int, default=900)
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument(
        "--devices", type=int, default=1,
        help="shard the seed axis over this many devices (on CPU needs "
        "XLA_FLAGS=--xla_force_host_platform_device_count)")
    args = ap.parse_args(argv)

    from repro.core import (SimConfig, SweepSpec, make_workload,
                            run_sweep)
    from repro.core import controllers as ctrl_lib
    from repro.core import faults as faults_lib

    events = tuple(
        faults_lib.parse_fault(s)
        for s in args.faults.split(";") if s.strip()
    )
    wl = make_workload("bursty", T=args.T, m=8, seed=0, N=1024)
    seeds = tuple(range(args.seeds))
    rows = []
    # one declarative spec per ablation: the whole controller registry
    # rides the spec's controllers axis (ablate lives in the config, so
    # it stays an outer loop)
    for ablate in ("", "no_fault_signal"):
        spec = SweepSpec(
            config=SimConfig(
                m=8, N=1024, policy=args.policy, ablate=ablate,
                middleware=("fleet_cache",), gossip_ms=100.0,
                faults=events,
            ),
            workloads=(wl,), policies=(args.policy,),
            controllers=ctrl_lib.available(), seeds=seeds,
            metrics="summary", devices=args.devices, do_warmup=False)
        res = run_sweep(spec)
        for ctrl in ctrl_lib.available():
            rs = res.rows(policy=args.policy, controller=ctrl)
            label = ctrl + (f"[{ablate}]" if ablate else "")
            rows.append((
                label,
                sum(r.mean_queue() for r in rs) / len(rs),
                max(r.max_queue() for r in rs),
                sum(r.worst_case_queue() for r in rs) / len(rs),
            ))
            print(f"ran {label}", flush=True)
    rows.sort(key=lambda r: r[2])
    print(f"\nfaults={[e.kind for e in events]} policy={args.policy} "
          f"T={args.T} seeds={len(seeds)}")
    print(f"{'controller':28s} {'mean_q':>8s} {'max_q':>8s} {'p99.9':>8s}")
    for label, mq, xq, wq in rows:
        print(f"{label:28s} {mq:8.3f} {xq:8.1f} {wq:8.2f}")
    best, worst = rows[0][0], rows[-1][0]
    print(f"\nbest-under-fault: {best}   worst: {worst}")


if len(sys.argv) > 1 and sys.argv[1] == "adv":
    adv_main(sys.argv[2:])
    sys.exit(0)

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.config import RunConfig, MeshConfig  # noqa: E402
from repro.launch.dryrun import run_cell  # noqa: E402

which = sys.argv[1]
mesh = MeshConfig(multi_pod=False)

def show(r, label):
    rf = r["roofline"]
    print(f"{label}: comp={rf['compute_s']:.3f}s mem={rf['memory_s']:.3f}s "
          f"coll={rf['collective_s']:.3f}s dom={rf['dominant']} "
          f"useful={rf['useful_flops_ratio']*100:.1f}% "
          f"state={r['state_bytes_per_device']/1e9:.2f}GB", flush=True)

if which == "hc1a":  # qwen3 + shard_map MoE dispatch (default path now)
    r = run_cell("qwen3-moe-235b-a22b", "train_4k", False, force=True,
                 tag="hc1a_shardmap")
    show(r, "hc1a qwen3 train shard_map")
elif which == "hc1b":  # + expert-resident (no FSDP on expert weights)
    run = RunConfig(arch="qwen3-moe-235b-a22b", shape="train_4k", mesh=mesh,
                    sharding_rules="train_ep_resident")
    r = run_cell("qwen3-moe-235b-a22b", "train_4k", False, force=True,
                 run=run, tag="hc1b_epresident")
    show(r, "hc1b qwen3 train shard_map+ep_resident")
elif which == "hc2a":  # smollm prefill with head_dim TP
    run = RunConfig(arch="smollm-360m", shape="prefill_32k", mesh=mesh,
                    sharding_rules="serve_hd")
    r = run_cell("smollm-360m", "prefill_32k", False, force=True,
                 run=run, tag="hc2a_hd")
    show(r, "hc2a smollm prefill head_dim TP")
elif which == "hc3a":  # dbrx decode with cache_seq over model
    run = RunConfig(arch="dbrx-132b", shape="decode_32k", mesh=mesh,
                    sharding_rules="serve_kvseq")
    r = run_cell("dbrx-132b", "decode_32k", False, force=True,
                 run=run, tag="hc3a_kvseq")
    show(r, "hc3a dbrx decode kvseq")
if which == "hc2b":  # smollm prefill with context parallelism
    run = RunConfig(arch="smollm-360m", shape="prefill_32k", mesh=mesh,
                    sharding_rules="serve_seq")
    r = run_cell("smollm-360m", "prefill_32k", False, force=True,
                 run=run, tag="hc2b_seq")
    show(r, "hc2b smollm prefill context-parallel")
if which == "hc1c":  # shard_map + no remat (recompute re-runs collectives)
    run = RunConfig(arch="qwen3-moe-235b-a22b", shape="train_4k", mesh=mesh,
                    remat_policy="none")
    r = run_cell("qwen3-moe-235b-a22b", "train_4k", False, force=True,
                 run=run, tag="hc1c_noremat")
    show(r, "hc1c qwen3 train shard_map+noremat")
if which == "hc3b":  # weight-stationary MoE decode
    run = RunConfig(arch="dbrx-132b", shape="decode_32k", mesh=mesh,
                    sharding_rules="serve_decode_moe")
    r = run_cell("dbrx-132b", "decode_32k", False, force=True,
                 run=run, tag="hc3b_fres")
    show(r, "hc3b dbrx decode weight-stationary moe")
