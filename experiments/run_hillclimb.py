import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
from repro.config import RunConfig, MeshConfig
from repro.launch.dryrun import run_cell

which = sys.argv[1]
mesh = MeshConfig(multi_pod=False)

def show(r, label):
    rf = r["roofline"]
    print(f"{label}: comp={rf['compute_s']:.3f}s mem={rf['memory_s']:.3f}s "
          f"coll={rf['collective_s']:.3f}s dom={rf['dominant']} "
          f"useful={rf['useful_flops_ratio']*100:.1f}% "
          f"state={r['state_bytes_per_device']/1e9:.2f}GB", flush=True)

if which == "hc1a":  # qwen3 + shard_map MoE dispatch (default path now)
    r = run_cell("qwen3-moe-235b-a22b", "train_4k", False, force=True,
                 tag="hc1a_shardmap")
    show(r, "hc1a qwen3 train shard_map")
elif which == "hc1b":  # + expert-resident (no FSDP on expert weights)
    run = RunConfig(arch="qwen3-moe-235b-a22b", shape="train_4k", mesh=mesh,
                    sharding_rules="train_ep_resident")
    r = run_cell("qwen3-moe-235b-a22b", "train_4k", False, force=True,
                 run=run, tag="hc1b_epresident")
    show(r, "hc1b qwen3 train shard_map+ep_resident")
elif which == "hc2a":  # smollm prefill with head_dim TP
    run = RunConfig(arch="smollm-360m", shape="prefill_32k", mesh=mesh,
                    sharding_rules="serve_hd")
    r = run_cell("smollm-360m", "prefill_32k", False, force=True,
                 run=run, tag="hc2a_hd")
    show(r, "hc2a smollm prefill head_dim TP")
elif which == "hc3a":  # dbrx decode with cache_seq over model
    run = RunConfig(arch="dbrx-132b", shape="decode_32k", mesh=mesh,
                    sharding_rules="serve_kvseq")
    r = run_cell("dbrx-132b", "decode_32k", False, force=True,
                 run=run, tag="hc3a_kvseq")
    show(r, "hc3a dbrx decode kvseq")
if which == "hc2b":  # smollm prefill with context parallelism
    run = RunConfig(arch="smollm-360m", shape="prefill_32k", mesh=mesh,
                    sharding_rules="serve_seq")
    r = run_cell("smollm-360m", "prefill_32k", False, force=True,
                 run=run, tag="hc2b_seq")
    show(r, "hc2b smollm prefill context-parallel")
if which == "hc1c":  # shard_map + no remat (recompute re-runs collectives)
    run = RunConfig(arch="qwen3-moe-235b-a22b", shape="train_4k", mesh=mesh,
                    remat_policy="none")
    r = run_cell("qwen3-moe-235b-a22b", "train_4k", False, force=True,
                 run=run, tag="hc1c_noremat")
    show(r, "hc1c qwen3 train shard_map+noremat")
if which == "hc3b":  # weight-stationary MoE decode
    run = RunConfig(arch="dbrx-132b", shape="decode_32k", mesh=mesh,
                    sharding_rules="serve_decode_moe")
    r = run_cell("dbrx-132b", "decode_32k", False, force=True,
                 run=run, tag="hc3b_fres")
    show(r, "hc3b dbrx decode weight-stationary moe")
