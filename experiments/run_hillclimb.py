"""Hill-climb drivers: roofline cells (hc*), the controller-adversarial
fault search (adv), and the adversarial-traffic search (advtraffic).

  python experiments/run_hillclimb.py hc1a
  python experiments/run_hillclimb.py adv --faults \\
      "proxy_crash:t0=300,duration=250,target=0;ckpt_storm_fleet"
  python experiments/run_hillclimb.py advtraffic --restarts 2 --iters 8

``adv`` evaluates every registered controller (plus the
``no_fault_signal`` ablation of each) under the SAME injected fault
schedule and ranks them by worst-case queue — the adversarial question
being "which control plane degrades least when this fault fires".
``--faults`` takes ';'-separated ``faults.parse_fault`` specs (',' is
the key=value separator inside one spec).

``advtraffic`` turns the question around: the fault schedule is empty
and the TRAFFIC is the adversary.  A hill-climb with random restarts
searches the ``AdversaryParams`` box (burst period / duty / hotset
shift / write mix / amplitude) per controller, maximizing the E4
oscillation rate (tie-broken by worst-case queue), and exports each
controller's worst discovered input as a ``trace_replay``-compatible
``.npz`` — the committed red-team fixture ``tests/data/
redteam_worst.npz`` is the hysteresis worst case found this way
(``tests/data/gen_redteam_trace.py`` regenerates it).
"""
import argparse
import dataclasses
import os
import sys
from pathlib import Path

_REPO_ROOT = Path(__file__).resolve().parents[1]


def adv_main(argv) -> None:
    ap = argparse.ArgumentParser(
        prog="run_hillclimb.py adv",
        description="controller-adversarial fault search")
    ap.add_argument(
        "--faults", default="proxy_crash:t0=300,duration=250,target=0",
        help="';'-separated fault specs (kind[:k=v,...])")
    ap.add_argument("--policy", default="midas")
    ap.add_argument("--T", type=int, default=900)
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument(
        "--devices", type=int, default=1,
        help="shard the seed axis over this many devices (on CPU needs "
        "XLA_FLAGS=--xla_force_host_platform_device_count)")
    ap.add_argument(
        "--out", default=None, metavar="DIR",
        help="also write an incremental JSON artifact "
        "(adv_fault_search.json) to DIR")
    args = ap.parse_args(argv)

    from repro.core import (SimConfig, SweepSpec, make_workload,
                            run_sweep)
    from repro.core import controllers as ctrl_lib
    from repro.core import faults as faults_lib

    art = _artifact("adv_fault_search.json", args.out)
    events = tuple(
        faults_lib.parse_fault(s)
        for s in args.faults.split(";") if s.strip()
    )
    wl = make_workload("bursty", T=args.T, m=8, seed=0, N=1024)
    seeds = tuple(range(args.seeds))
    rows = []
    doc = {
        "experiment": "adv_fault_search",
        "faults": [e.kind for e in events],
        "policy": args.policy, "T": args.T, "seeds": len(seeds),
        "controllers": {},
    }
    # one declarative spec per ablation: the whole controller registry
    # rides the spec's controllers axis (ablate lives in the config, so
    # it stays an outer loop)
    for ablate in ("", "no_fault_signal"):
        spec = SweepSpec(
            config=SimConfig(
                m=8, N=1024, policy=args.policy, ablate=ablate,
                middleware=("fleet_cache",), gossip_ms=100.0,
                faults=events,
            ),
            workloads=(wl,), policies=(args.policy,),
            controllers=ctrl_lib.available(), seeds=seeds,
            metrics="summary", devices=args.devices, do_warmup=False)
        res = run_sweep(spec)
        for ctrl in ctrl_lib.available():
            rs = res.rows(policy=args.policy, controller=ctrl)
            label = ctrl + (f"[{ablate}]" if ablate else "")
            rows.append((
                label,
                sum(r.mean_queue() for r in rs) / len(rs),
                max(r.max_queue() for r in rs),
                sum(r.worst_case_queue() for r in rs) / len(rs),
            ))
            doc["controllers"][label] = {
                "mean_queue": rows[-1][1],
                "max_queue": rows[-1][2],
                "worst_case_queue": rows[-1][3],
            }
            if art is not None:
                art.write(doc)
            print(f"ran {label}", flush=True)
    # rank by the p99.9 worst-case-queue column — the metric the header
    # documents this search as adversarial against
    rows.sort(key=lambda r: r[3])
    print(f"\nfaults={[e.kind for e in events]} policy={args.policy} "
          f"T={args.T} seeds={len(seeds)}")
    print(f"{'controller':28s} {'mean_q':>8s} {'max_q':>8s} {'p99.9':>8s}")
    for label, mq, xq, wq in rows:
        print(f"{label:28s} {mq:8.3f} {xq:8.1f} {wq:8.2f}")
    best, worst = rows[0][0], rows[-1][0]
    doc["ranking"] = [r[0] for r in rows]
    doc["best_under_fault"], doc["worst_under_fault"] = best, worst
    if art is not None:
        art.write(doc)
    print(f"\nbest-under-fault: {best}   worst: {worst}")


def _artifact(filename, out):
    """Incremental-JSON artifact (benchmarks.common idiom); ``out=None``
    skips artifact emission entirely (pure-stdout legacy mode)."""
    if out is None:
        return None
    sys.path.insert(0, str(_REPO_ROOT))
    from benchmarks.common import Artifact
    return Artifact(filename, out=Path(out))


def advtraffic_main(argv) -> None:
    ap = argparse.ArgumentParser(
        prog="run_hillclimb.py advtraffic",
        description="adversarial-traffic search: hill-climb the "
        "AdversaryParams box per controller, maximizing oscillation")
    ap.add_argument(
        "--controllers", default="hysteresis,aimd",
        help="comma-separated controllers to attack")
    ap.add_argument("--policy", default="midas")
    ap.add_argument("--T", type=int, default=1200)
    ap.add_argument("--restarts", type=int, default=2)
    ap.add_argument(
        "--iters", type=int, default=8,
        help="hill-climb steps per restart")
    ap.add_argument(
        "--seed", type=int, default=0,
        help="search rng seed (the traffic seed is fixed at 0 so the "
        "objective is deterministic per params)")
    ap.add_argument(
        "--out", default=None, metavar="DIR",
        help="artifact directory for advtraffic_search.json and the "
        "exported worst traces (default: experiments/sim)")
    args = ap.parse_args(argv)

    import numpy as np

    from repro.core import (SimConfig, SweepSpec, make_workload,
                            run_sweep)
    from repro.core import controllers as ctrl_lib
    from repro.core.workloads import adversary

    out_dir = (Path(args.out) if args.out
               else _REPO_ROOT / "experiments" / "sim")
    art = _artifact("advtraffic_search.json", out_dir)

    def evaluate(ctrl, params):
        wl = make_workload(
            "adversarial", T=args.T, m=8, seed=0, N=1024, params=params)
        spec = SweepSpec(
            config=SimConfig(
                m=8, N=1024, policy=args.policy, controller=ctrl),
            workloads=(wl,), seeds=(0,), metrics="summary",
            do_warmup=True)
        r = run_sweep(spec).row()
        st = ctrl_lib.trajectory_stats(
            r.d_timeline, r.delta_l_timeline, r.f_max_timeline,
            r.pressure, spec.config.dt_ms)
        osc = float(st["oscillation_per_min"])
        wcq = float(r.worst_case_queue())
        # oscillation is the headline; worst-case queue breaks ties so
        # the climb doesn't wander among equally-oscillatory inputs.
        # The weight keeps a fully saturating input (wcq ~700 at amp 4,
        # but d pinned at D_MAX so osc ~2) below a genuine limit cycle.
        return osc + 0.001 * wcq, osc, wcq

    doc = {"experiment": "advtraffic_search", "policy": args.policy,
           "T": args.T, "restarts": args.restarts, "iters": args.iters,
           "search": {}}
    controllers = [c.strip() for c in args.controllers.split(",") if c.strip()]
    for ctrl in controllers:
        rng = np.random.default_rng(args.seed)
        best = None  # (obj, osc, wcq, params)
        history = []
        for restart in range(args.restarts):
            # restart 0 starts at the hand-tuned default vector; later
            # restarts draw uniformly from the box
            cur = (adversary.AdversaryParams() if restart == 0
                   else adversary.random_params(rng))
            cur_obj, osc, wcq = evaluate(ctrl, cur)
            history.append({"restart": restart, "step": -1,
                            "objective": cur_obj, "oscillation_per_min":
                            osc, "worst_case_queue": wcq,
                            "params": dataclasses.asdict(cur)})
            if best is None or cur_obj > best[0]:
                best = (cur_obj, osc, wcq, cur)
            for step in range(args.iters):
                cand = adversary.perturb(cur, rng, scale=0.15)
                obj, osc, wcq = evaluate(ctrl, cand)
                if obj > cur_obj:
                    cur, cur_obj = cand, obj
                    history.append({
                        "restart": restart, "step": step,
                        "objective": obj,
                        "oscillation_per_min": osc,
                        "worst_case_queue": wcq,
                        "params": dataclasses.asdict(cand)})
                if obj > best[0]:
                    best = (obj, osc, wcq, cand)
                print(f"{ctrl} r{restart} s{step}: obj={obj:6.2f} "
                      f"(cur={cur_obj:6.2f} best={best[0]:6.2f})",
                      flush=True)
        obj, osc, wcq, params = best
        wl = make_workload(
            "adversarial", T=args.T, m=8, seed=0, N=1024, params=params)
        trace_path = out_dir / f"redteam_worst_{ctrl}.npz"
        adversary.save_trace(trace_path, wl)
        doc["search"][ctrl] = {
            "objective": obj, "oscillation_per_min": osc,
            "worst_case_queue": wcq,
            "best_params": dataclasses.asdict(params),
            "trace": trace_path.name, "history": history,
        }
        if art is not None:
            art.write(doc)
        print(f"\n{ctrl}: best osc/min={osc:.2f} wcq={wcq:.2f} "
              f"params={dataclasses.asdict(params)} -> {trace_path}",
              flush=True)


if len(sys.argv) > 1 and sys.argv[1] == "adv":
    adv_main(sys.argv[2:])
    sys.exit(0)
if len(sys.argv) > 1 and sys.argv[1] == "advtraffic":
    advtraffic_main(sys.argv[2:])
    sys.exit(0)

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.config import RunConfig, MeshConfig  # noqa: E402
from repro.launch.dryrun import run_cell  # noqa: E402

which = sys.argv[1]
mesh = MeshConfig(multi_pod=False)

def show(r, label):
    rf = r["roofline"]
    print(f"{label}: comp={rf['compute_s']:.3f}s mem={rf['memory_s']:.3f}s "
          f"coll={rf['collective_s']:.3f}s dom={rf['dominant']} "
          f"useful={rf['useful_flops_ratio']*100:.1f}% "
          f"state={r['state_bytes_per_device']/1e9:.2f}GB", flush=True)

if which == "hc1a":  # qwen3 + shard_map MoE dispatch (default path now)
    r = run_cell("qwen3-moe-235b-a22b", "train_4k", False, force=True,
                 tag="hc1a_shardmap")
    show(r, "hc1a qwen3 train shard_map")
elif which == "hc1b":  # + expert-resident (no FSDP on expert weights)
    run = RunConfig(arch="qwen3-moe-235b-a22b", shape="train_4k", mesh=mesh,
                    sharding_rules="train_ep_resident")
    r = run_cell("qwen3-moe-235b-a22b", "train_4k", False, force=True,
                 run=run, tag="hc1b_epresident")
    show(r, "hc1b qwen3 train shard_map+ep_resident")
elif which == "hc2a":  # smollm prefill with head_dim TP
    run = RunConfig(arch="smollm-360m", shape="prefill_32k", mesh=mesh,
                    sharding_rules="serve_hd")
    r = run_cell("smollm-360m", "prefill_32k", False, force=True,
                 run=run, tag="hc2a_hd")
    show(r, "hc2a smollm prefill head_dim TP")
elif which == "hc3a":  # dbrx decode with cache_seq over model
    run = RunConfig(arch="dbrx-132b", shape="decode_32k", mesh=mesh,
                    sharding_rules="serve_kvseq")
    r = run_cell("dbrx-132b", "decode_32k", False, force=True,
                 run=run, tag="hc3a_kvseq")
    show(r, "hc3a dbrx decode kvseq")
if which == "hc2b":  # smollm prefill with context parallelism
    run = RunConfig(arch="smollm-360m", shape="prefill_32k", mesh=mesh,
                    sharding_rules="serve_seq")
    r = run_cell("smollm-360m", "prefill_32k", False, force=True,
                 run=run, tag="hc2b_seq")
    show(r, "hc2b smollm prefill context-parallel")
if which == "hc1c":  # shard_map + no remat (recompute re-runs collectives)
    run = RunConfig(arch="qwen3-moe-235b-a22b", shape="train_4k", mesh=mesh,
                    remat_policy="none")
    r = run_cell("qwen3-moe-235b-a22b", "train_4k", False, force=True,
                 run=run, tag="hc1c_noremat")
    show(r, "hc1c qwen3 train shard_map+noremat")
if which == "hc3b":  # weight-stationary MoE decode
    run = RunConfig(arch="dbrx-132b", shape="decode_32k", mesh=mesh,
                    sharding_rules="serve_decode_moe")
    r = run_cell("dbrx-132b", "decode_32k", False, force=True,
                 run=run, tag="hc3b_fres")
    show(r, "hc3b dbrx decode weight-stationary moe")
