"""Checkpoint-storm demo: a real multi-GB-scale (scaled down for CPU)
model state is dumped through 4 writer lanes; MIDAS lane scheduling vs
static hash shows the paper's hotspot mitigation end-to-end, including
restart from the produced checkpoint.

  PYTHONPATH=src python examples/checkpoint_storm.py
"""
import tempfile
import time

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.config import RunConfig, get_smoke_arch
from repro.train.step import init_train_state


def main() -> None:
    cfg = get_smoke_arch("dbrx-132b")     # MoE: skewed leaf sizes
    run = RunConfig(arch="dbrx-132b")
    state = init_train_state(cfg, run, jax.random.PRNGKey(0))

    for policy in ("hash", "midas"):
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d, lanes=4, policy=policy)
            t0 = time.monotonic()
            cm.save(1, state)
            dt = time.monotonic() - t0
            import json
            manifest = json.loads(
                (cm.root / "step_00000001" / "manifest.json").read_text())
            lanes = np.asarray(manifest["lane_bytes"], np.float64)
            print(f"{policy:6s}: save {dt * 1e3:6.0f} ms  "
                  f"lane_bytes={np.round(lanes / 1e6, 2)}MB  "
                  f"cv={lanes.std() / lanes.mean():.3f}")
            # restart path: restore + checksum verify
            step, restored = cm.restore_latest(state)
            assert step == 1
            print(f"        restored step {step} OK (crc32 verified)")


if __name__ == "__main__":
    main()
