"""Quickstart: the MIDAS middleware on a bursty metadata workload.

Reproduces the paper's headline comparison (Lustre round-robin vs MIDAS
power-of-d) in ~1 minute on CPU, then shows the full self-stabilizing
stack (margins + pinning + leaky bucket + cooperative cache) and the
pluggable policy and workload registries (every policy in
``policies.available()`` and every scenario in ``workloads.available()``
— including third-party registrations — runs through the same engine).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (SimConfig, SweepSpec, make_workload, policies,
                        run_sweep, simulate, workloads)

T, M = 2400, 8  # 120 s of simulated time, 8 metadata servers


def main() -> None:
    wl = make_workload("bursty", T=T, m=M, seed=0)

    print("=== Lustre baseline: namespace round-robin ===")
    rr = simulate(SimConfig(m=M, policy="round_robin"), wl,
                  do_warmup=False)
    print(f"  mean queue      {rr.mean_queue():8.2f}")
    print(f"  worst-case q    {rr.worst_case_queue():8.1f}")
    print(f"  dispersion (CV) {rr.dispersion():8.3f}")

    print("=== MIDAS (power-of-d within feasible sets) ===")
    pod = simulate(SimConfig(m=M, policy="power_of_d"), wl,
                   do_warmup=False)
    print(f"  mean queue      {pod.mean_queue():8.2f}  "
          f"({(1 - pod.mean_queue() / rr.mean_queue()) * 100:+.0f}% "
          f"vs RR; paper: ~23% avg)")
    wc_gain = (1 - pod.worst_case_queue() / rr.worst_case_queue()) * 100
    print(f"  worst-case q    {pod.worst_case_queue():8.1f}  "
          f"({wc_gain:+.0f}% vs RR; paper: 50-80%)")
    print(f"  dispersion (CV) {pod.dispersion():8.3f}  (paper: <=0.43)")

    print("=== full MIDAS: + control loop + cooperative cache ===")
    full = simulate(SimConfig(m=M, policy="midas", middleware=("cache",),
                              cache_mode="lease"), wl)
    fc = full.final_cache
    print(f"  mean queue      {full.mean_queue():8.2f}")
    hit_rate = int(fc.hits) / max(int(fc.hits) + int(fc.misses), 1)
    print(f"  cache hit rate  {hit_rate:8.3f}")
    print(f"  stale serves    {int(fc.stale_serves):8d}  (lease coherence)")
    print(f"  steering d knob min/max: {full.d_timeline.min()}/"
          f"{full.d_timeline.max()}  (bounded 1..4)")
    steer_frac = full.steered.sum() / max(full.eligible.sum(), 1)
    print(f"  steered/eligible {steer_frac:.3f}"
          f"  (leaky-bucket cap 0.10)")

    print("=== policy registry: swap policies without touching the engine ===")
    print(f"  registered: {', '.join(policies.available())}")
    # one declarative sweep: jsq (d=m upper bound) and bounded-load
    # consistent hashing, each compiled once, vmapped over two seeds
    res = run_sweep(SweepSpec(config=SimConfig(m=M), workloads=wl,
                              policies=("jsq", "chbl"), seeds=(0, 1),
                              do_warmup=False))
    for name in ("jsq", "chbl"):
        rows = res.rows(policy=name)
        mq = np.mean([r.mean_queue() for r in rows])
        print(f"  {name:6s} mean queue {mq:8.2f}  (2-seed avg)")

    print("=== workload registry: scenarios compose from combinators ===")
    print(f"  registered: {', '.join(workloads.available())}")
    # composed scenarios (mix/concat/scale_rate/shift_hotset over other
    # registered workloads) batch onto one compiled scan per policy
    scen = [make_workload(n, T=T // 2, m=M, seed=0)
            for n in ("job_startup", "multi_tenant")]
    res = run_sweep(SweepSpec(config=SimConfig(m=M), workloads=scen,
                              policies=("round_robin", "power_of_d"),
                              do_warmup=False))
    for wl_name in ("job_startup", "multi_tenant"):
        rr_q = res.row(policy="round_robin", workload=wl_name).mean_queue()
        pod_q = res.row(policy="power_of_d", workload=wl_name).mean_queue()
        print(f"  {wl_name:12s} RR {rr_q:7.2f} -> MIDAS {pod_q:7.2f} "
              f"({(1 - pod_q / max(rr_q, 1e-9)) * 100:+.0f}%)")


if __name__ == "__main__":
    main()
