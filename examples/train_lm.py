"""End-to-end training driver: data pipeline -> jit train step ->
async MIDAS-scheduled checkpoints -> kill/resume, on any assigned arch.

Default: a ~100M-parameter llama-family model (SmolLM-360M at reduced
depth) for a few hundred steps on CPU.  Use --arch/--full-config to select
any of the 10 assigned architectures (full configs want the production
mesh; reduced configs run anywhere).

  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --arch dbrx-132b --steps 50
"""
import argparse
import dataclasses

from repro.config import RunConfig, get_arch, get_smoke_arch
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--hundred-m", action="store_true",
                    help="scale the smoke config up to ~100M params")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    if args.full_config:
        cfg = get_arch(args.arch)
    else:
        cfg = get_smoke_arch(args.arch)
        if args.hundred_m:
            # ~100M llama-family: 12 x 768 with the arch's own flavor
            cfg = dataclasses.replace(
                cfg, num_layers=12, d_model=768, num_heads=12,
                num_kv_heads=4, d_ff=2048, head_dim=64, vocab_size=32000)
    n = cfg.n_params()
    print(f"arch={cfg.name} params={n / 1e6:.1f}M steps={args.steps}")
    run = RunConfig(arch=args.arch)
    tc = TrainerConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                       ckpt_dir=args.ckpt_dir, ckpt_every=100,
                       log_every=10)
    state = Trainer(cfg, run, tc).train()
    print(f"finished at step {int(state.step)}; checkpoints in "
          f"{args.ckpt_dir} (re-run to resume)")


if __name__ == "__main__":
    main()
