"""Serving example: a real decode loop behind the MIDAS request router.

Eight replica 'servers' (one real model, eight queues — this container has
one CPU) serve zipf-distributed sessions.  Sessions are consistent-hashed
for KV affinity; hot sessions are steered by power-of-d; the cooperative
prefix cache absorbs repeated prompts.

  PYTHONPATH=src python examples/serve_midas.py --requests 64
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.config import RunConfig, get_smoke_arch
from repro.serve import MidasRouter
from repro.serve.step import make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--decode-len", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_arch(args.arch)
    run = RunConfig(arch=args.arch)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    serve_step = jax.jit(make_serve_step(cfg, run))
    router = MidasRouter(replicas=args.replicas, d=3, f_max=0.25)

    rng = np.random.default_rng(0)
    max_seq = 64
    caches = {}
    now = 0.0
    for req in range(args.requests):
        session = int(rng.zipf(1.4)) % 16          # hot sessions
        prompt_hash = session % 4                  # few distinct prompts
        replica, steered, hit = router.route(session, now,
                                             prefix_hash=prompt_hash)
        if replica not in caches:
            caches[replica] = models.init_decode_cache(
                cfg, 1, max_seq, dtype=jnp.float32)
        cache = caches[replica]
        token = jnp.asarray([[session % cfg.vocab_size]], jnp.int32)
        out = []
        for t in range(args.decode_len):
            pos = jnp.asarray([t], jnp.int32)
            token, cache = serve_step(params, cache, token, pos)
            token = token[:, None]
            out.append(int(token[0, 0]))
        caches[replica] = cache
        router.complete(replica)
        now += 50.0
        router.ingest_telemetry()
        flag = "steer" if steered else ("hit " if hit else "    ")
        if req < 10 or req % 16 == 0:
            print(f"req {req:3d} session {session:2d} -> replica "
                  f"{replica} [{flag}] tokens={out[:4]}...")
    s = router.stats()
    print(f"\nrouted={s.routed} steered={s.steered} "
          f"prefix_hits={s.cache_hits} "
          f"queue_cv={router.queue_dispersion():.3f}")


if __name__ == "__main__":
    main()
